"""GPipe-style pipeline parallelism over the "pod" axis.

The multi-pod mesh's default profile is DP-over-pods (DESIGN §5); this
module provides the alternative: layer groups are sharded over "pod" as
pipeline stages, microbatches stream through via collective_permute, and
the bubble is the usual (S-1)/(M+S-1).

Implemented for the homogeneous-stack forward (the 40-cell archs all scan
a uniform group); exercised by tests on a tiny (stages=2) mesh and by the
dry-run as an optional profile.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn: Callable, n_stages: int, n_micro: int,
                     mesh: Mesh, axis: str = "pod"):
    """Build fn(stage_params, x) running `stage_fn` as a GPipe pipeline.

    stage_params: pytree with leading axis n_stages (sharded over `axis`);
    x: [n_micro, micro_batch, ...] microbatched inputs (replicated);
    returns y: [n_micro, micro_batch, ...].

    stage_fn(params_slice, h) -> h  must be shape-preserving (the
    homogeneous-transformer case).
    """
    def fn(stage_params, x):
        def shard_body(params_local, xs):
            # params_local: [1, ...] this stage's slice; xs: full microbatches
            stage = jax.lax.axis_index(axis)
            p = jax.tree.map(lambda a: a[0], params_local)
            M = xs.shape[0]
            T = M + n_stages - 1
            h = jnp.zeros_like(xs[0])
            ys = jnp.zeros_like(xs)

            def tick(carry, t):
                h, ys = carry
                # stage 0 ingests microbatch t (if any)
                mb = jnp.clip(t, 0, M - 1)
                h_in = jnp.where(stage == 0, xs[mb], h)
                h_out = stage_fn(p, h_in)
                # last stage emits microbatch (t - (S-1))
                out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
                emit = (stage == n_stages - 1) & (t >= n_stages - 1)
                ys = jax.lax.cond(
                    emit,
                    lambda ys: jax.lax.dynamic_update_index_in_dim(
                        ys, h_out, out_idx, 0),
                    lambda ys: ys, ys)
                # send h_out to the next stage (ring; last→0 discarded)
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                h_next = jax.lax.ppermute(h_out, axis, perm)
                return (h_next, ys), None

            (h, ys), _ = jax.lax.scan(tick, (h, ys), jnp.arange(T))
            # only the last stage holds real outputs; broadcast via psum
            ys = jnp.where(stage == n_stages - 1, ys, jnp.zeros_like(ys))
            return jax.lax.psum(ys, axis)

        in_specs = (jax.tree.map(lambda _: P(axis), stage_params,
                                 is_leaf=lambda x: hasattr(x, "shape")),
                    P())
        return jax.shard_map(shard_body, mesh=mesh,
                             in_specs=in_specs, out_specs=P(),
                             check_vma=False)(stage_params, x)

    return fn
