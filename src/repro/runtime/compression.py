"""Gradient compression for the slow inter-pod (DCN) all-reduce.

Two composable schemes with error feedback (residual carry, Karimireddy
et al. '19 style):
  - int8 uniform quantization (4× over fp32, 2× over bf16)
  - top-k sparsification (magnitude), k as a fraction

`compressed_allreduce` wires them around a psum for use inside shard_map
over the "pod" axis; on this container it is exercised in tests via a tiny
mesh, and the dry-run's multi-pod profile can enable it per-config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- int8
def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------- top-k
def topk_compress(x: jax.Array, frac: float) -> jax.Array:
    """Keep the top `frac` fraction by magnitude (dense mask form — the
    wire format would transmit (indices, values); the mask form keeps the
    math identical and jit-friendly)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


# ------------------------------------------------------- error feedback
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Any

    @classmethod
    def init(cls, tree):
        return cls(residual=jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), tree))


def compressed_allreduce(grads, ef: ErrorFeedbackState, axis_name: str, *,
                         scheme: str = "int8", topk_frac: float = 0.05):
    """psum(grads) over `axis_name` with compression + error feedback.
    Call inside shard_map/pmap. Returns (mean_grads, new_ef)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if scheme == "int8":
            q, scale = compress_int8(gf)
            sent = decompress_int8(q, scale)
        elif scheme == "topk":
            sent = topk_compress(gf, topk_frac)
        elif scheme == "int8+topk":
            sent = topk_compress(gf, topk_frac)
            q, scale = compress_int8(sent)
            sent = decompress_int8(q, scale)
        else:
            sent = gf
        new_r = gf - sent
        reduced = jax.lax.pmean(sent, axis_name)
        return reduced.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, ErrorFeedbackState(residual=new_r)
