"""Straggler detection & mitigation.

Per-step host heartbeats feed a rolling deadline-quantile detector; hosts
consistently past the p95×slack deadline are flagged. Mitigation policies:
  - BackupStepPolicy: re-dispatch the straggler's shard to a hot spare
    (speculative execution, MapReduce-style) — modeled.
  - the VoS scheduler (core/) treats a persistent straggler as a failed
    node: checkpoint → recompose the VDC without it → elastic restart.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque, Dict, List, Optional, Tuple


@dataclasses.dataclass
class StragglerEvent:
    step: int
    host: int
    t_host: float
    deadline: float


class StragglerMonitor:
    def __init__(self, n_hosts: int, window: int = 20, slack: float = 1.5,
                 min_samples: int = 5):
        self.n_hosts = n_hosts
        self.window = window
        self.slack = slack
        self.min_samples = min_samples
        self.history: Deque[List[float]] = collections.deque(maxlen=window)
        self.events: List[StragglerEvent] = []
        self.flags: Dict[int, int] = collections.defaultdict(int)

    def record_step(self, step: int, host_times: List[float]
                    ) -> List[StragglerEvent]:
        """host_times[i] = wall seconds host i took for this step."""
        self.history.append(list(host_times))
        if len(self.history) < self.min_samples:
            return []
        all_t = [t for row in self.history for t in row]
        all_t.sort()
        # median-based deadline: robust to the stragglers themselves
        # polluting the window (a p95 deadline self-inflates)
        med = all_t[len(all_t) // 2]
        deadline = med * self.slack
        out = []
        for h, t in enumerate(host_times):
            if t > deadline:
                ev = StragglerEvent(step, h, t, deadline)
                self.events.append(ev)
                self.flags[h] += 1
                out.append(ev)
        return out

    def persistent_stragglers(self, threshold: int = 3) -> List[int]:
        return [h for h, n in self.flags.items() if n >= threshold]

    def slowdown_factor(self, host: int) -> float:
        """Estimated slowdown of `host` relative to the window median
        (>= 1.0): the mitigation knob a chaos-aware planner multiplies
        the host's serialization/step model by. Zero samples (an idle
        host) contribute nothing."""
        samples = [row[host] for row in self.history
                   if host < len(row) and row[host] > 0.0]
        if not samples:
            return 1.0
        all_t = sorted(t for row in self.history for t in row if t > 0.0)
        if not all_t:
            return 1.0
        med = all_t[len(all_t) // 2]
        if med <= 0.0:
            return 1.0
        return max(1.0, (sum(samples) / len(samples)) / med)


class BackupStepPolicy:
    """Speculative re-execution: when a host misses the deadline, its shard
    is re-dispatched to a spare; the step completes at the earlier of the
    two. Returns the effective step time under the policy."""

    def __init__(self, n_spares: int = 1, redispatch_cost: float = 0.1):
        self.n_spares = n_spares
        self.redispatch_cost = redispatch_cost
        self.saved_s = 0.0
        self.backups = 0

    def effective_step_time(self, host_times: List[float],
                            deadline: float, typical: float) -> float:
        """Step time = max over hosts, with up to n_spares stragglers
        replaced by (deadline + redispatch + typical)."""
        times = sorted(host_times, reverse=True)
        budget = self.n_spares
        eff = []
        for t in times:
            if t > deadline and budget > 0:
                budget -= 1
                self.backups += 1
                backup = deadline + self.redispatch_cost + typical
                saved = t - min(t, backup)
                self.saved_s += max(0.0, saved)
                eff.append(min(t, backup))
            else:
                eff.append(t)
        return max(eff)
