from repro.runtime.compression import (compress_int8, decompress_int8,
                                       topk_compress, ErrorFeedbackState,
                                       compressed_allreduce)
from repro.runtime.straggler import StragglerMonitor, BackupStepPolicy
