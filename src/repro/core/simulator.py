"""Discrete-event simulator for JITA-4DS (§4.2).

Events: task arrivals and VDC completions. At every event the active
heuristic maps pending tasks onto freshly composed VDCs; tasks whose
value has decayed to zero under every configuration are dropped
(oversubscription). Completion earns Eq. 1 value; Eq. 2 accumulates.

Two driving modes share one event loop:

  * ``run(trace)`` — the classic one-shot mode: the full trace is
    injected up front and the heap drained to completion.
  * the incremental event-feed API — ``begin()`` / ``inject(task)`` /
    ``run_until(t)`` / ``finalize()`` — lets a co-simulator submit tasks
    *while the simulation is in flight* (the edge→DC bridge produces DC
    tasks as upstream fires resolve), interleaving heap processing with
    external progress. Grid occupancy, pending backlog and the power cap
    persist between ``run_until`` calls, so late arrivals contend with
    the live VDC state instead of an optimistic estimate.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.heuristics import Heuristic
from repro.core.tasks import Task
from repro.core.value import task_value
from repro.core.vdc import PodGrid


@dataclasses.dataclass
class SimResult:
    heuristic: str
    vos: float                      # Eq. 2 total
    perf_value: float               # Σ γ w_p v_p
    energy_value: float             # Σ γ w_e v_e
    completed: int
    dropped: int
    total_energy_j: float
    makespan: float
    avg_utilization: float
    vos_normalized: float           # vos / Σ_j γ_j (w_p+w_e) v_max
    tasks: List[Task] = dataclasses.field(default_factory=list, repr=False)


class Simulator:
    def __init__(self, heuristic: Heuristic, cost: CostModel,
                 power_cap_w: Optional[float] = None,
                 grid: Optional[PodGrid] = None):
        self.heuristic = heuristic
        self.cost = cost
        self.power_cap_w = power_cap_w
        self.grid = grid or PodGrid()
        self._begun = False

    # ------------------------------------------------- incremental event feed
    def begin(self) -> "Simulator":
        """Reset the event loop for incremental feeding."""
        self._events: List[Tuple[float, int, str, object]] = []
        # pending queue: insertion-ordered, O(1) membership and removal
        # (keyed by object identity — the one-shot hot loop used to pay
        # an O(n) list.remove per scheduled task)
        self._pending: Dict[int, Task] = {}
        # per-task best-possible memo for _drop_dead: duration and
        # energy on the largest allowable config never change, so the
        # cost-model lookups happen once per task instead of once per
        # pending task per event
        self._bp: Dict[int, Tuple[float, float]] = {}
        self._seq = 0
        self._vos = self._perf_v = self._energy_v = 0.0
        self._tot_energy = 0.0
        self._completed = self._dropped = 0
        self._util_area = 0.0
        self._now = 0.0
        self._tasks: List[Task] = []
        self._begun = True
        return self

    @property
    def now(self) -> float:
        """Current simulation clock (last processed/advanced-to time)."""
        return self._now if self._begun else 0.0

    def inject(self, task: Task) -> None:
        """Feed one task into the live event heap. A task whose nominal
        ``arrival`` lies in the simulator's past (the feeder learned of it
        late) is admitted at the current clock — its *value* latency is
        still measured from the true ``arrival``, so late admission costs
        value rather than rewriting history."""
        if not self._begun:
            self.begin()
        self._tasks.append(task)
        heapq.heappush(self._events,
                       (max(task.arrival, self._now), self._seq,
                        "arrive", task))
        self._seq += 1

    def next_event_time(self) -> Optional[float]:
        return self._events[0][0] if self._begun and self._events else None

    def run_until(self, t: float) -> None:
        """Process every event with timestamp <= t, then advance the
        clock to t (idle time accrues zero utilization area)."""
        if not self._begun:
            self.begin()
        while self._events and self._events[0][0] <= t:
            self._step()
        if t > self._now:
            self._util_area += self.grid.used_chips * (t - self._now)
            self._now = t

    def drain(self) -> None:
        """Process every remaining event (no clock advance past the last)."""
        if not self._begun:
            self.begin()
        while self._events:
            self._step()

    def _step(self) -> None:
        now, _, kind, payload = heapq.heappop(self._events)
        self._util_area += self.grid.used_chips * (now - self._now)
        self._now = now
        if kind == "arrive":
            self._pending[id(payload)] = payload
        else:  # complete
            task, vdc = payload
            self.grid.release(vdc)
            latency = task.finish - task.arrival
            v_p = task.value.perf_curve.value(latency)
            v_e = task.value.energy_curve.value(task.energy_j)
            v = task_value(task.value, latency, task.energy_j)
            task.earned = v
            self._vos += v
            if v > 0:
                self._perf_v += task.value.gamma * task.value.w_p * v_p
                self._energy_v += task.value.gamma * task.value.w_e * v_e
            self._tot_energy += task.energy_j
            self._completed += 1

        self._drop_dead(now)
        for task, chips, f in self.heuristic.assign(
                list(self._pending.values()), self.grid, self.cost, now,
                self.power_cap_w):
            vdc = self.grid.compose(chips, f, task.tid)
            if vdc is None:
                continue
            del self._pending[id(task)]
            self._bp.pop(id(task), None)
            t_step = self.cost.time_per_step(task.ttype.arch,
                                             task.ttype.shape, chips, f)
            task.start = now
            task.finish = now + t_step * task.steps
            task.chips, task.dvfs_f = chips, f
            task.energy_j = self.cost.energy_per_step(
                task.ttype.arch, task.ttype.shape, chips, f) * task.steps
            self._seq += 1
            heapq.heappush(self._events,
                           (task.finish, self._seq, "complete", (task, vdc)))

    def _drop_dead(self, now: float) -> None:
        dead: List[int] = []
        for key, task in self._pending.items():
            memo = self._bp.get(key)
            if memo is None:
                best_chips = max(task.ttype.allowable_chips)
                t_step = self.cost.time_per_step(
                    task.ttype.arch, task.ttype.shape, best_chips, 1.0)
                energy = self.cost.energy_per_step(
                    task.ttype.arch, task.ttype.shape, best_chips,
                    1.0) * task.steps
                memo = (t_step * task.steps, energy)
                self._bp[key] = memo
            dur, energy = memo
            if task_value(task.value, (now - task.arrival) + dur,
                          energy) > 0.0:
                continue
            task.dropped = True
            self._dropped += 1
            dead.append(key)
        for key in dead:
            del self._pending[key]
            self._bp.pop(key, None)

    def finalize(self) -> SimResult:
        """Drain outstanding events and close the books. Tasks still
        pending earn nothing (counted dropped, like the one-shot mode)."""
        self.drain()
        dropped = self._dropped + len(self._pending)
        max_vos = sum(t.value.gamma * (t.value.w_p + t.value.w_e)
                      for t in self._tasks) or 1.0
        result = SimResult(
            heuristic=self.heuristic.name, vos=self._vos,
            perf_value=self._perf_v, energy_value=self._energy_v,
            completed=self._completed, dropped=dropped,
            total_energy_j=self._tot_energy, makespan=self._now,
            avg_utilization=self._util_area / max(self._now, 1e-9)
            / self.grid.total_chips,
            vos_normalized=self._vos / max_vos, tasks=self._tasks)
        self._begun = False
        return result

    def pending_tasks(self) -> List[Task]:
        """Tasks admitted but not yet scheduled (live view)."""
        return list(self._pending.values()) if self._begun else []

    def withdraw(self, task: Task) -> bool:
        """Cancel an admitted-but-unscheduled task (the feeder gave up on
        it — e.g. a starved offload with no event left to trigger its
        assignment). Counted as dropped."""
        if self._begun and id(task) in self._pending:
            del self._pending[id(task)]
            self._bp.pop(id(task), None)
            task.dropped = True
            self._dropped += 1
            return True
        return False

    # ------------------------------------------------------ one-shot driving
    def run(self, trace: List[Task]) -> SimResult:
        """Classic mode: inject the whole trace, drain, finalize. For a
        trace in (arrival, tid) order this is event-for-event identical
        to feeding the tasks incrementally."""
        self.begin()
        for t in trace:
            self.inject(t)
        return self.finalize()


def _best_possible(task: Task, cost: CostModel, now: float, chips: int):
    """Optimistic value if started right now on the largest config."""
    t_step = cost.time_per_step(task.ttype.arch, task.ttype.shape, chips, 1.0)
    dur = t_step * task.steps
    latency = (now - task.arrival) + dur
    energy = cost.energy_per_step(task.ttype.arch, task.ttype.shape,
                                  chips, 1.0) * task.steps
    return task_value(task.value, latency, energy), dur, energy


def compare_heuristics(heuristics, cost: CostModel, trace_fn,
                       n_traces: int = 5,
                       power_cap_w: Optional[float] = None
                       ) -> Dict[str, List[SimResult]]:
    """Run each heuristic over n fresh traces (same seeds across heuristics)."""
    import copy
    out: Dict[str, List[SimResult]] = {h.name: [] for h in heuristics}
    for i in range(n_traces):
        base_trace = trace_fn(i)
        for h in heuristics:
            trace = copy.deepcopy(base_trace)
            sim = Simulator(h, cost, power_cap_w=power_cap_w)
            out[h.name].append(sim.run(trace))
    return out
