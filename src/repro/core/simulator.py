"""Discrete-event simulator for JITA-4DS (§4.2).

Events: task arrivals (from a trace) and VDC completions. At every event
the active heuristic maps pending tasks onto freshly composed VDCs; tasks
whose value has decayed to zero under every configuration are dropped
(oversubscription). Completion earns Eq. 1 value; Eq. 2 accumulates.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.heuristics import Heuristic
from repro.core.tasks import Task
from repro.core.value import task_value
from repro.core.vdc import PodGrid


@dataclasses.dataclass
class SimResult:
    heuristic: str
    vos: float                      # Eq. 2 total
    perf_value: float               # Σ γ w_p v_p
    energy_value: float             # Σ γ w_e v_e
    completed: int
    dropped: int
    total_energy_j: float
    makespan: float
    avg_utilization: float
    vos_normalized: float           # vos / Σ_j γ_j (w_p+w_e) v_max
    tasks: List[Task] = dataclasses.field(default_factory=list, repr=False)


class Simulator:
    def __init__(self, heuristic: Heuristic, cost: CostModel,
                 power_cap_w: Optional[float] = None,
                 grid: Optional[PodGrid] = None):
        self.heuristic = heuristic
        self.cost = cost
        self.power_cap_w = power_cap_w
        self.grid = grid or PodGrid()

    def run(self, trace: List[Task]) -> SimResult:
        grid, cost = self.grid, self.cost
        events: List[Tuple[float, int, str, object]] = []
        for t in trace:
            heapq.heappush(events, (t.arrival, t.tid, "arrive", t))
        pending: List[Task] = []
        running: Dict[int, Tuple[Task, object]] = {}
        seq = len(trace)
        vos = perf_v = energy_v = tot_energy = 0.0
        completed = dropped = 0
        util_area = 0.0
        last_t = 0.0

        def drop_dead(now: float):
            nonlocal dropped
            alive = []
            for task in pending:
                best_chips = max(task.ttype.allowable_chips)
                v, _, _ = _best_possible(task, cost, now, best_chips)
                if v <= 0.0:
                    task.dropped = True
                    dropped += 1
                else:
                    alive.append(task)
            pending[:] = alive

        while events:
            now, _, kind, payload = heapq.heappop(events)
            util_area += grid.used_chips * (now - last_t)
            last_t = now
            if kind == "arrive":
                pending.append(payload)
            else:  # complete
                task, vdc = payload
                grid.release(vdc)
                latency = task.finish - task.arrival
                v_p = task.value.perf_curve.value(latency)
                v_e = task.value.energy_curve.value(task.energy_j)
                v = task_value(task.value, latency, task.energy_j)
                task.earned = v
                vos += v
                if v > 0:
                    perf_v += task.value.gamma * task.value.w_p * v_p
                    energy_v += task.value.gamma * task.value.w_e * v_e
                tot_energy += task.energy_j
                completed += 1

            drop_dead(now)
            for task, chips, f in self.heuristic.assign(
                    pending, grid, cost, now, self.power_cap_w):
                vdc = grid.compose(chips, f, task.tid)
                if vdc is None:
                    continue
                pending.remove(task)
                t_step = cost.time_per_step(task.ttype.arch,
                                            task.ttype.shape, chips, f)
                task.start = now
                task.finish = now + t_step * task.steps
                task.chips, task.dvfs_f = chips, f
                task.energy_j = cost.energy_per_step(
                    task.ttype.arch, task.ttype.shape, chips, f) * task.steps
                seq += 1
                heapq.heappush(events,
                               (task.finish, seq, "complete", (task, vdc)))

        # anything still pending at the end earned nothing
        dropped += len(pending)
        max_vos = sum(t.value.gamma * (t.value.w_p + t.value.w_e)
                      for t in trace) or 1.0
        return SimResult(
            heuristic=self.heuristic.name, vos=vos, perf_value=perf_v,
            energy_value=energy_v, completed=completed, dropped=dropped,
            total_energy_j=tot_energy, makespan=last_t,
            avg_utilization=util_area / max(last_t, 1e-9)
            / self.grid.total_chips,
            vos_normalized=vos / max_vos, tasks=trace)


def _best_possible(task: Task, cost: CostModel, now: float, chips: int):
    """Optimistic value if started right now on the largest config."""
    t_step = cost.time_per_step(task.ttype.arch, task.ttype.shape, chips, 1.0)
    dur = t_step * task.steps
    latency = (now - task.arrival) + dur
    energy = cost.energy_per_step(task.ttype.arch, task.ttype.shape,
                                  chips, 1.0) * task.steps
    return task_value(task.value, latency, energy), dur, energy


def compare_heuristics(heuristics, cost: CostModel, trace_fn,
                       n_traces: int = 5,
                       power_cap_w: Optional[float] = None
                       ) -> Dict[str, List[SimResult]]:
    """Run each heuristic over n fresh traces (same seeds across heuristics)."""
    import copy
    out: Dict[str, List[SimResult]] = {h.name: [] for h in heuristics}
    for i in range(n_traces):
        base_trace = trace_fn(i)
        for h in heuristics:
            trace = copy.deepcopy(base_trace)
            sim = Simulator(h, cost, power_cap_w=power_cap_w)
            out[h.name].append(sim.run(trace))
    return out
