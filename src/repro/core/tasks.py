"""Tasks and workload traces for the JITA-4DS scheduler.

A task = "run K steps of an (architecture × shape) cell under an SLO".
The assigned archs are the job mix (the paper's NPB benchmark analogue).
Traces follow §4.2: jobs in arrival order, each with max value, problem
size (steps), allowable resource configs, soft/hard thresholds; sampled so
the system is oversubscribed, with an optional peak period (§4.1's
experiment starts during peak usage).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.value import TaskValueSpec, ValueCurve


# Frozen workload regime calibrated so the VPTR-vs-Simple gains land in the
# paper's reported band (Fig. 4: ≈+50% energy value, ≈+40% perf value, up to
# +71% normalized VoS) — see EXPERIMENTS.md §Fig4.
PAPER_REGIME = dict(mean_interarrival_s=50.0, soft_range=(2.0, 6.0),
                    hard_mult_range=(2.0, 6.0), peak=True)


@dataclasses.dataclass(frozen=True)
class TaskType:
    arch: str
    shape: str
    # resource configs the job may run under (chip counts, power-of-two tiles)
    allowable_chips: Tuple[int, ...] = (16, 32, 64, 128, 256)

    @property
    def name(self) -> str:
        return f"{self.arch}×{self.shape}"


@dataclasses.dataclass
class Task:
    tid: int
    ttype: TaskType
    steps: int
    arrival: float                # seconds
    value: TaskValueSpec
    hbm_bytes: float = 0.0        # total working set (params+opt+cache)
    # runtime bookkeeping
    start: Optional[float] = None
    finish: Optional[float] = None
    chips: int = 0
    dvfs_f: float = 1.0
    energy_j: float = 0.0
    earned: float = 0.0
    dropped: bool = False


class WorkloadGenerator:
    """Synthetic oversubscribed traces (paper §4.2: 50 traces × 1000 jobs)."""

    def __init__(self, task_types: Sequence[TaskType], cost_model,
                 seed: int = 0, peak: bool = True,
                 mean_interarrival_s: float = 60.0,
                 soft_range: Tuple[float, float] = (1.2, 3.0),
                 hard_mult_range: Tuple[float, float] = (1.5, 4.0),
                 curve_shape: str = "linear"):
        self.task_types = list(task_types)
        self.cost = cost_model
        self.rng = random.Random(seed)
        self.peak = peak
        self.mean_ia = mean_interarrival_s
        self.soft_range = soft_range
        self.hard_mult_range = hard_mult_range
        self.curve_shape = curve_shape  # linear | exponential (Fig.3 allows
                                        # other decay shapes — ablated)

    def _thresholds(self, t_ref: float) -> Tuple[float, float]:
        """Soft/hard thresholds relative to the best-case latency."""
        soft = t_ref * self.rng.uniform(*self.soft_range)
        hard = soft * self.rng.uniform(*self.hard_mult_range)
        return soft, hard

    def make_task(self, tid: int, arrival: float) -> Task:
        tt = self.rng.choice(self.task_types)
        steps = self.rng.choice([50, 100, 200, 400])
        best_chips = max(tt.allowable_chips)
        t_best = self.cost.time_per_step(tt.arch, tt.shape, best_chips) * steps
        e_best = self.cost.energy_per_step(
            tt.arch, tt.shape, best_chips, 1.0) * steps
        s_lat, h_lat = self._thresholds(t_best)
        s_e, h_e = self._thresholds(e_best)
        gamma = self.rng.choice([1.0, 2.0, 4.0, 8.0])
        w_p = self.rng.uniform(0.3, 0.7)
        spec = TaskValueSpec(
            gamma=gamma, w_p=w_p, w_e=1.0 - w_p,
            perf_curve=ValueCurve(1.0, 0.1, s_lat, h_lat, self.curve_shape),
            energy_curve=ValueCurve(1.0, 0.1, s_e * 2, h_e * 4,
                                    self.curve_shape))
        return Task(tid=tid, ttype=tt, steps=steps, arrival=arrival,
                    value=spec, hbm_bytes=self.cost.hbm_bytes(tt.arch, tt.shape))

    def trace(self, n_jobs: int) -> List[Task]:
        tasks, t = [], 0.0
        for i in range(n_jobs):
            # peak period: first third of the trace arrives 4× faster
            rate = self.mean_ia / 4 if (self.peak and i < n_jobs // 3) \
                else self.mean_ia
            t += self.rng.expovariate(1.0 / rate)
            tasks.append(self.make_task(i, t))
        return tasks
