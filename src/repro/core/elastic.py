"""Cross-VDC elastic reallocation (§4.2 Discussion).

The paper raises re-dividing the shared fixed pool across VDCs online,
without disturbing running applications. Here: a running job can be
checkpointed, its VDC released, and resumed on a different submesh —
`repro.checkpoint` re-shards the state onto the new mesh. The policy below
decides *when* growing a starved high-value job is worth the migration
overhead, using the same VoS calculus as admission.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Mapping, Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.tasks import Task
from repro.core.value import task_value
from repro.core.vdc import PodGrid, VDC

MIGRATION_OVERHEAD_S = 30.0  # checkpoint + re-shard + restart (modeled)

# Relocating a *stream operator* between sites is far lighter than
# re-sharding a training job: the operator's buffered window state is
# shipped, then the operator warms back up (re-subscribes, rebuilds its
# scheduler state) before it may fire again.
SERVICE_WARMUP_S = 2.0


@dataclasses.dataclass
class Migration:
    task: Task
    old_chips: int
    new_chips: int
    gain: float


def plan_regrow(running: List[Tuple[Task, VDC]], grid: PodGrid,
                cost: CostModel, now: float) -> Optional[Migration]:
    """Propose the single best grow-migration, if any yields VoS gain.

    A job migrates to a larger free tile when the value recovered by
    finishing earlier exceeds what the migration pause costs.
    """
    best: Optional[Migration] = None
    for task, vdc in running:
        done_frac = 0.0
        if task.start is not None and task.finish and task.finish > task.start:
            done_frac = min(1.0, (now - task.start)
                            / (task.finish - task.start))
        steps_left = max(1, int(task.steps * (1 - done_frac)))
        for chips in task.ttype.allowable_chips:
            if chips <= vdc.chips or chips - vdc.chips > grid.free_chips:
                continue
            t_old = cost.time_per_step(task.ttype.arch, task.ttype.shape,
                                       vdc.chips, vdc.dvfs_f)
            t_new = cost.time_per_step(task.ttype.arch, task.ttype.shape,
                                       chips, vdc.dvfs_f)
            finish_old = now + steps_left * t_old
            finish_new = now + MIGRATION_OVERHEAD_S + steps_left * t_new
            e_old = task.energy_j
            v_old = task_value(task.value, finish_old - task.arrival, e_old)
            v_new = task_value(task.value, finish_new - task.arrival, e_old)
            gain = v_new - v_old
            if gain > 0 and (best is None or gain > best.gain):
                best = Migration(task, vdc.chips, chips, gain)
    return best


# ---------------------------------------------------------------------------
# Service re-placement (online controller)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServiceMigration:
    """One stream service relocating between sites under a new placement
    plan: its buffered operator state ships over the network, then the
    operator stalls for a warm-up before it may fire at the new site."""
    service: str
    src: str
    dst: str
    state_bytes: float
    transfer_s: float
    warmup_s: float = SERVICE_WARMUP_S

    @property
    def stall_s(self) -> float:
        return self.transfer_s + self.warmup_s


def plan_replacement(old: Mapping[str, object], new: Mapping[str, object],
                     state_bytes_fn: Callable[[str], float],
                     transfer_time_fn: Callable[[str, str, float], float],
                     warmup_s: float = SERVICE_WARMUP_S
                     ) -> List[ServiceMigration]:
    """Diff two placement assignments (service -> placement with a
    ``site`` attribute) into the migrations the switch requires. Only
    site moves ship state; a DC service changing its VDC chips/DVFS hint
    composes differently on its *next* fire for free (VDCs are built
    just-in-time per task, there is nothing resident to move)."""
    out: List[ServiceMigration] = []
    for name in sorted(new):
        np_, op = new[name], old.get(name)
        if op is None or op.site == np_.site:
            continue
        sb = state_bytes_fn(name)
        out.append(ServiceMigration(
            service=name, src=op.site, dst=np_.site, state_bytes=sb,
            transfer_s=transfer_time_fn(op.site, np_.site, sb),
            warmup_s=warmup_s))
    return out
