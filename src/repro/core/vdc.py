"""Composable Virtual Data Centers on a TPU pod grid.

The paper's disaggregated resource pool is the 16×16 chip grid; a VDC is a
rectangular submesh tile composed just-in-time for one task and released
(or re-composed — see elastic.py) when the task finishes. Allocation is a
buddy scheme over power-of-two tiles so every VDC is a contiguous ICI
rectangle (collectives stay on-torus).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro import hardware as hw

MIN_VDC_CHIPS = 4


def is_valid_vdc_size(chips: int) -> bool:
    """The single source of truth for composable VDC sizes: a power of
    two of at least MIN_VDC_CHIPS (shared by PodGrid.compose and the
    placement plan validation)."""
    return chips >= MIN_VDC_CHIPS and not (chips & (chips - 1))


@dataclasses.dataclass(frozen=True)
class Tile:
    x: int
    y: int
    w: int
    h: int

    @property
    def chips(self) -> int:
        return self.w * self.h


@dataclasses.dataclass
class VDC:
    """A composed virtual data center: tile + DVFS operating point + job."""
    vdc_id: int
    tile: Tile
    dvfs_f: float
    task_id: int

    @property
    def chips(self) -> int:
        return self.tile.chips


class PodGrid:
    """Buddy allocator over the pod's chip grid (power-of-two tiles)."""

    def __init__(self, width: int = hw.POD_X, height: int = hw.POD_Y):
        self.width, self.height = width, height
        self.free: List[Tile] = [Tile(0, 0, width, height)]
        self.used: Dict[int, VDC] = {}
        self._next_id = 0

    @property
    def total_chips(self) -> int:
        return self.width * self.height

    @property
    def free_chips(self) -> int:
        return sum(t.chips for t in self.free)

    @property
    def used_chips(self) -> int:
        return self.total_chips - self.free_chips

    def _split_to(self, tile: Tile, chips: int) -> Tile:
        """Split `tile` (in the free list context) until it has `chips`."""
        while tile.chips > chips:
            if tile.w >= tile.h:  # split along x
                half = tile.w // 2
                a = Tile(tile.x, tile.y, half, tile.h)
                b = Tile(tile.x + half, tile.y, tile.w - half, tile.h)
            else:
                half = tile.h // 2
                a = Tile(tile.x, tile.y, tile.w, half)
                b = Tile(tile.x, tile.y + half, tile.w, tile.h - half)
            self.free.append(b)
            tile = a
        return tile

    def compose(self, chips: int, dvfs_f: float, task_id: int
                ) -> Optional[VDC]:
        """Compose a VDC of `chips` (power of two ≥4); None if fragmented."""
        if not is_valid_vdc_size(chips):
            raise ValueError(f"VDC sizes must be powers of two >= "
                             f"{MIN_VDC_CHIPS}, got {chips}")
        candidates = sorted([t for t in self.free if t.chips >= chips],
                            key=lambda t: t.chips)
        if not candidates:
            return None
        tile = candidates[0]
        self.free.remove(tile)
        tile = self._split_to(tile, chips)
        vdc = VDC(self._next_id, tile, dvfs_f, task_id)
        self._next_id += 1
        self.used[vdc.vdc_id] = vdc
        return vdc

    def release(self, vdc: VDC) -> None:
        del self.used[vdc.vdc_id]
        self.free.append(vdc.tile)
        self._coalesce()

    def _coalesce(self) -> None:
        """Merge free BUDDIES only (strict buddy scheme: a merge must
        reconstruct the exact parent tile of the split that created the
        pair, alignment included) so every free tile keeps a power-of-two
        area and splits always land exactly on the requested size."""
        merged = True
        while merged:
            merged = False
            self.free.sort(key=lambda t: (t.y, t.x))
            for i, a in enumerate(self.free):
                for j in range(i + 1, len(self.free)):
                    b = self.free[j]
                    if a.w != b.w or a.h != b.h:
                        continue
                    # (w == h) was produced by a y-split of (w, 2h)
                    if (a.w == a.h and a.x == b.x and b.y == a.y + a.h
                            and a.y % (2 * a.h) == 0):
                        self.free[i] = Tile(a.x, a.y, a.w, 2 * a.h)
                        del self.free[j]
                        merged = True
                        break
                    # (h == 2w) was produced by an x-split of (2w, h)
                    if (a.h == 2 * a.w and a.y == b.y and b.x == a.x + a.w
                            and a.x % (2 * a.w) == 0):
                        self.free[i] = Tile(a.x, a.y, 2 * a.w, a.h)
                        del self.free[j]
                        merged = True
                        break
                if merged:
                    break

    def power_w(self, cost_model) -> float:
        """Current power draw of all composed VDCs (idle chips draw static)."""
        p = sum(cost_model.power_w(v.chips, v.dvfs_f)
                for v in self.used.values())
        p += self.free_chips * hw.CHIP_STATIC_W
        return p
