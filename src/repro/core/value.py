"""Value-of-Service: the paper's Fig. 3 curves and Eq. 1-2.

A task earns maximum value v_max while the objective (completion time or
energy) is below a soft threshold, decays to v_min at the hard threshold
(linearly by default; the paper notes other shapes are admissible — an
exponential option is provided and exercised in an ablation), and earns
zero beyond it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable


@dataclasses.dataclass(frozen=True)
class ValueCurve:
    v_max: float
    v_min: float
    th_soft: float
    th_hard: float
    shape: str = "linear"  # linear | exponential

    def __post_init__(self):
        if self.th_hard < self.th_soft:
            raise ValueError("hard threshold must be >= soft threshold")
        if self.v_min > self.v_max:
            raise ValueError("v_min must be <= v_max")

    def value(self, x: float) -> float:
        if x <= self.th_soft:
            return self.v_max
        if x > self.th_hard:
            return 0.0
        if self.th_hard == self.th_soft:
            return self.v_min
        frac = (x - self.th_soft) / (self.th_hard - self.th_soft)
        if self.shape == "exponential":
            # decays by e-folds towards v_min
            return self.v_min + (self.v_max - self.v_min) * math.exp(-3 * frac)
        return self.v_max - frac * (self.v_max - self.v_min)

    def value_array(self, x):
        """Vectorized :meth:`value` over a numpy array (same piecewise
        shape, kept next to the scalar so the curves cannot drift —
        the tier-1 plan screen evaluates these over whole fire/plan
        matrices)."""
        import numpy as np
        out = np.zeros(x.shape)
        out[x <= self.th_soft] = self.v_max
        mid = (x > self.th_soft) & (x <= self.th_hard)
        if self.th_hard > self.th_soft:
            frac = (x[mid] - self.th_soft) / (self.th_hard - self.th_soft)
            if self.shape == "exponential":
                out[mid] = (self.v_min
                            + (self.v_max - self.v_min) * np.exp(-3 * frac))
            else:
                out[mid] = self.v_max - frac * (self.v_max - self.v_min)
        else:
            out[mid] = self.v_min
        return out


@dataclasses.dataclass(frozen=True)
class TaskValueSpec:
    """Eq. 1 parameters: γ importance, objective weights, per-objective curves."""
    gamma: float
    w_p: float
    w_e: float
    perf_curve: ValueCurve      # objective: completion latency (s)
    energy_curve: ValueCurve    # objective: energy consumed (J)


def task_value(spec: TaskValueSpec, completion_latency: float,
               energy_j: float) -> float:
    """V(Task_j, t) = γ_j (w_p v_p + w_e v_e); zero if either component is
    zero (paper: 'If either the performance function or energy function is
    0, then the VoS is 0')."""
    v_p = spec.perf_curve.value(completion_latency)
    v_e = spec.energy_curve.value(energy_j)
    if v_p == 0.0 or v_e == 0.0:
        return 0.0
    return spec.gamma * (spec.w_p * v_p + spec.w_e * v_e)


def vos_total(values: Iterable[float]) -> float:
    """Eq. 2: VoS(t) = Σ_j V(Task_j, t)."""
    return float(sum(values))
