"""Per-task execution-time & energy models for the VoS scheduler.

The paper predicts task time/energy per resource configuration with offline
regression models ([10-12]); here the predictor is the three-term roofline
derived from the compiled dry-run of the very binaries being scheduled
(EXPERIMENTS.md §Roofline). DVFS scales the compute term by 1/f and dynamic
power by f³ (DESIGN §2).

Scaling model from the 256-chip reference to an n-chip VDC:
  compute, memory ∝ 256/n   (batch/model dims re-shard onto fewer chips)
  collective      ≈ const   (per-device ring traffic; slightly ↓ with n)
plus a fixed efficiency factor for small slices.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, Optional, Tuple

from repro import hardware as hw
from repro.configs import SHAPES, get_arch


@dataclasses.dataclass(frozen=True)
class CellCost:
    t_compute: float
    t_memory: float
    t_collective: float
    hbm_bytes: float

    def step_time(self, chips: int, dvfs_f: float = 1.0,
                  ref_chips: int = 256) -> float:
        s = ref_chips / max(1, chips)
        tc = self.t_compute * s / dvfs_f
        tm = self.t_memory * s
        tx = self.t_collective
        return max(tc, tm, tx)


class CostModel:
    """Cost cells are immutable, so the per-config queries are pure —
    they are memoized per (arch, shape, chips, f) because the DES hot
    loop (heuristic assignment + drop scans) issues the same handful of
    lookups millions of times per co-simulation."""

    def __init__(self, cells: Dict[Tuple[str, str], CellCost]):
        self.cells = cells
        self._time_cache: Dict[Tuple[str, str, int, float], float] = {}
        self._power_cache: Dict[Tuple[int, float], float] = {}

    # ------------------------------------------------------------------ build
    @classmethod
    def from_reports(cls, report_dir: str) -> "CostModel":
        cells = {}
        for fn in glob.glob(os.path.join(report_dir, "*__16x16.json")):
            with open(fn) as f:
                d = json.load(f)
            if "t_compute" not in d:
                continue
            cells[(d["arch"], d["shape"])] = CellCost(
                d["t_compute"], d["t_memory"], d["t_collective"],
                d["arg_bytes"] * 256.0)
        return cls(cells)

    @classmethod
    def analytic(cls, archs=None, shapes=None) -> "CostModel":
        """Fallback: roofline terms from parameter counts (tests / before a
        dry-run has been recorded)."""
        from repro.roofline import model_flops
        cells = {}
        archs = archs or [a for a in _default_archs()]
        shapes = shapes or list(SHAPES)
        for a in archs:
            cfg = get_arch(a)
            counts = cfg.param_counts()
            for s in shapes:
                shape = SHAPES[s]
                mf = model_flops(cfg, shape)
                chips = 256
                t_c = mf / (chips * hw.PEAK_FLOPS_BF16) / 0.5  # 50% MXU eff
                wbytes = counts["total"] * (12 if shape.kind == "train" else 2)
                reads = 3 if shape.kind == "train" else 1
                t_m = reads * wbytes / (chips * hw.HBM_BW)
                t_x = 0.2 * t_c + wbytes / chips / hw.ICI_LINK_BW * 0.05
                cells[(a, s)] = CellCost(t_c, t_m, t_x, wbytes)
        return cls(cells)

    # ------------------------------------------------------------------ query
    def _cell(self, arch: str, shape: str) -> CellCost:
        key = (arch, shape)
        if key not in self.cells:
            raise KeyError(f"no cost cell for {key}")
        return self.cells[key]

    def has(self, arch: str, shape: str) -> bool:
        return (arch, shape) in self.cells

    def time_per_step(self, arch: str, shape: str, chips: int,
                      dvfs_f: float = 1.0) -> float:
        key = (arch, shape, chips, dvfs_f)
        t = self._time_cache.get(key)
        if t is None:
            t = self._cell(arch, shape).step_time(chips, dvfs_f)
            self._time_cache[key] = t
        return t

    def power_w(self, chips: int, dvfs_f: float = 1.0) -> float:
        key = (chips, dvfs_f)
        p = self._power_cache.get(key)
        if p is None:
            per_chip = (hw.CHIP_STATIC_W
                        + (hw.CHIP_TDP_W - hw.CHIP_STATIC_W) * dvfs_f ** 3)
            hosts = max(1, chips // hw.CHIPS_PER_HOST)
            p = chips * per_chip + hosts * hw.HOST_POWER_W
            self._power_cache[key] = p
        return p

    def energy_per_step(self, arch: str, shape: str, chips: int,
                        dvfs_f: float = 1.0) -> float:
        t = self.time_per_step(arch, shape, chips, dvfs_f)
        return t * self.power_w(chips, dvfs_f)

    def hbm_bytes(self, arch: str, shape: str) -> float:
        return self._cell(arch, shape).hbm_bytes

    def min_chips(self, arch: str, shape: str) -> int:
        """Smallest power-of-two slice whose HBM fits the working set."""
        need = self.hbm_bytes(arch, shape)
        chips = 4
        while chips < 256 and chips * hw.HBM_BYTES < need:
            chips *= 2
        return chips


def _default_archs():
    from repro.configs import list_archs
    return list_archs()
