"""Resource-management heuristics (§4.1-4.2).

All heuristics share one interface: given the pending queue, the pod grid,
the cost model and the power budget, return assignments
``[(task, chips, dvfs_f), ...]`` to start now.

  Simple    — FCFS, max allowable config, nominal frequency, no value
              awareness, strict queue order (the paper's baseline).
  VPT       — greedy max value-per-time.
  VPTR      — greedy max Value-Per-Total-Resources (Eq. 3):
              TaR = TeD × (%chips + %HBM).
  VPT-CPC   — VPT under a COMMON power-cap frequency for every new VDC.
  VPT-JSPC  — VPT with a job-specific frequency chosen per assignment.
  Hybrid    — JSPC freedom for high-importance jobs (γ ≥ 4), CPC for the rest.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro import hardware as hw
from repro.core.costmodel import CostModel
from repro.core.tasks import Task
from repro.core.value import task_value
from repro.core.vdc import PodGrid

Assignment = Tuple[Task, int, float]  # (task, chips, dvfs_f)
DVFS_FS = tuple(d.f for d in hw.DVFS_LADDER)


def _feasible_chips(task: Task, grid: PodGrid, cost: CostModel) -> List[int]:
    lo = cost.min_chips(task.ttype.arch, task.ttype.shape)
    return [c for c in task.ttype.allowable_chips
            if c >= lo and c <= grid.total_chips]


def _value_if(task: Task, cost: CostModel, now: float, chips: int,
              f: float) -> Tuple[float, float, float]:
    """(value, exec_duration, energy) if started now on (chips, f)."""
    t_step = cost.time_per_step(task.ttype.arch, task.ttype.shape, chips, f)
    dur = t_step * task.steps
    latency = (now - task.arrival) + dur
    energy = cost.energy_per_step(task.ttype.arch, task.ttype.shape,
                                  chips, f) * task.steps
    return task_value(task.value, latency, energy), dur, energy


class Heuristic:
    name = "base"
    # The system power cap is a HARD constraint enforced on every heuristic
    # (the paper's §4.2 runs all heuristics under the same cap); only the
    # *-CPC/JSPC/Hybrid variants may trade frequency for parallelism.
    can_scale_f = False

    def assign(self, pending: List[Task], grid: PodGrid, cost: CostModel,
               now: float, power_cap_w: Optional[float] = None
               ) -> List[Assignment]:
        raise NotImplementedError

    # -- power helpers ------------------------------------------------------
    def _headroom(self, grid: PodGrid, cost: CostModel,
                  power_cap_w: Optional[float], extra: float = 0.0) -> float:
        if power_cap_w is None:
            return float("inf")
        return power_cap_w - grid.power_w(cost) - extra


class SimpleHeuristic(Heuristic):
    name = "Simple"

    def assign(self, pending, grid, cost, now, power_cap_w=None):
        out = []
        for task in sorted(pending, key=lambda t: t.arrival):
            chips_opts = _feasible_chips(task, grid, cost)
            if not chips_opts:
                continue
            chips = max(chips_opts)
            if chips > grid.free_chips:
                break  # strict FIFO: head-of-line blocks the queue
            out.append((task, chips, 1.0))
            grid_free = grid.free_chips  # noqa: simple bookkeeping below
            # reserve virtually (the simulator composes for real)
            if not self._reserve(grid, chips):
                break
        self._unreserve_all(grid)
        return out

    # Simple keeps a virtual reservation list so multiple FIFO heads can
    # start in one scheduling round.
    def _reserve(self, grid, chips):
        self._res = getattr(self, "_res", 0) + chips
        return self._res <= grid.free_chips

    def _unreserve_all(self, grid):
        self._res = 0


class _GreedyValue(Heuristic):
    """Shared greedy loop: repeatedly pick the argmax-objective assignment."""
    name = "greedy"

    def objective(self, task, value, dur, energy, chips, grid) -> float:
        raise NotImplementedError

    def _freqs(self, task, headroom_fn) -> Tuple[float, ...]:
        return (1.0,)

    def assign(self, pending, grid, cost, now, power_cap_w=None):
        out: List[Assignment] = []
        free = grid.free_chips
        budget = self._headroom(grid, cost, power_cap_w)
        remaining = [t for t in pending]
        while remaining:
            best = None
            for task in remaining:
                for chips in _feasible_chips(task, grid, cost):
                    if chips > free:
                        continue
                    for f in self._freqs(task, None):
                        v, dur, energy = _value_if(task, cost, now, chips, f)
                        if v <= 0:
                            continue
                        if cost.power_w(chips, f) > budget:
                            continue  # hard cap: wait instead of violating
                        obj = self.objective(task, v, dur, energy, chips, grid)
                        if best is None or obj > best[0]:
                            best = (obj, task, chips, f)
            if best is None:
                break
            _, task, chips, f = best
            out.append((task, chips, f))
            remaining.remove(task)
            free -= chips
            budget -= cost.power_w(chips, f)
        return out


class VPTHeuristic(_GreedyValue):
    name = "VPT"

    def objective(self, task, value, dur, energy, chips, grid):
        return value / max(dur, 1e-9)


class VPTRHeuristic(_GreedyValue):
    """Maximum Value-Per-Total-Resources (Eq. 3)."""
    name = "VPTR"

    def objective(self, task, value, dur, energy, chips, grid):
        pct_chips = chips / grid.total_chips
        pct_hbm = min(1.0, task.hbm_bytes /
                      (grid.total_chips * hw.HBM_BYTES))
        tar = dur * (pct_chips + pct_hbm)
        return value / max(tar, 1e-9)


class VPTCPCHeuristic(VPTHeuristic):
    """VPT under a Common Power Cap: one frequency for every new VDC,
    the highest ladder step whose projected total power fits the cap."""
    name = "VPT-CPC"
    can_scale_f = True

    def assign(self, pending, grid, cost, now, power_cap_w=None):
        if power_cap_w is None:
            return super().assign(pending, grid, cost, now, None)
        best, best_n = [], -1
        for f in DVFS_FS:  # highest first
            self._common_f = f
            out = super().assign(pending, grid, cost, now, power_cap_w)
            if len(out) > best_n:
                best, best_n = out, len(out)
        return best

    def _freqs(self, task, headroom_fn):
        return (getattr(self, "_common_f", 1.0),)


class VPTJSPCHeuristic(VPTHeuristic):
    """VPT with Job-Specific Power Capping: frequency chosen per job."""
    name = "VPT-JSPC"
    can_scale_f = True

    def _freqs(self, task, headroom_fn):
        return DVFS_FS


class HybridHeuristic(VPTHeuristic):
    """CPC baseline with JSPC freedom for high-importance jobs ([10,11])."""
    name = "Hybrid"
    can_scale_f = True
    gamma_cut = 4.0

    def _freqs(self, task, headroom_fn):
        if task.value.gamma >= self.gamma_cut:
            return DVFS_FS
        return (0.7,)  # conservative common cap frequency


HEURISTICS = {h.name: h for h in (
    SimpleHeuristic(), VPTHeuristic(), VPTRHeuristic(),
    VPTCPCHeuristic(), VPTJSPCHeuristic(), HybridHeuristic())}
