"""JITA-4DS core: the paper's contribution.

Value-of-Service metric (Fig. 3 / Eq. 1-2), VPTR & VPT-family heuristics
(§4.1-4.2), composable VDC submesh allocation, the discrete-event simulator
and its emulation-based validation."""
from repro.core.value import ValueCurve, TaskValueSpec, task_value, vos_total
from repro.core.tasks import Task, TaskType, WorkloadGenerator
from repro.core.costmodel import CostModel
from repro.core.vdc import PodGrid, VDC
from repro.core.heuristics import (HEURISTICS, SimpleHeuristic, VPTHeuristic,
                                   VPTRHeuristic, VPTCPCHeuristic,
                                   VPTJSPCHeuristic, HybridHeuristic)
from repro.core.simulator import Simulator, SimResult
