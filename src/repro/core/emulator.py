"""Emulation-based validation of the simulator (§4.2, Fig. 5 methodology).

The paper validates its simulator against an emulation on real hardware
(64 Ivy-Bridge nodes, RAPL). Our analogue: the *emulator* measures real
wall-clock step times of the reduced-config models executing on this host
(actual JAX execution, actual XLA scheduling noise), builds a measured cost
model from them, and replays the same traces through the same heuristics.
The simulator uses the analytic/roofline model instead. Agreement in the
heuristic *ranking pattern* across power caps — not magnitudes — is the
validation criterion, exactly as in the paper ("we observe a similarity in
the pattern ... even though normalised earnings are higher in simulation").
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_arch
from repro.core.costmodel import CellCost, CostModel
from repro.models import model as M


def measure_step_time(arch: str, kind: str = "train", seq: int = 64,
                      batch: int = 2, iters: int = 3) -> float:
    """Wall-clock seconds per train/prefill step of the REDUCED config."""
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch_d = {"tokens": jnp.zeros((batch, seq), jnp.int32),
               "labels": jnp.zeros((batch, seq), jnp.int32)}
    if cfg.frontend == "patch_stub":
        batch_d["patches"] = jnp.zeros((batch, cfg.n_prefix_tokens,
                                        cfg.d_model))
    if cfg.enc_dec is not None:
        batch_d["frames"] = jnp.zeros((batch, cfg.enc_dec.enc_seq,
                                       cfg.d_model))
    if kind == "train":
        fn = jax.jit(jax.grad(lambda p, b: M.loss_fn(cfg, p, b)[0]))
    else:
        fn = jax.jit(lambda p, b: M.forward(cfg, p, b)[0])
    out = fn(params, batch_d)  # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(params, batch_d)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def measured_cost_model(archs: List[str], shapes: Optional[List[str]] = None,
                        scale: float = 1.0) -> CostModel:
    """CostModel whose compute term comes from real measured step times.

    `scale` maps host-seconds to modeled-chip-seconds so the workload
    regime (oversubscription level) matches the simulator's.
    """
    base = CostModel.analytic(archs, shapes)
    shapes = shapes or list(SHAPES)
    cells = {}
    for a in archs:
        t_train = measure_step_time(a, "train")
        for s in shapes:
            ref = base.cells[(a, s)]
            kind = SHAPES[s].kind
            mult = {"train": 1.0, "prefill": 0.4, "decode": 0.02}[kind]
            t = t_train * mult * scale
            # measured time replaces the dominant term; keep analytic ratios
            total_ref = max(ref.t_compute, ref.t_memory, ref.t_collective)
            f = t / total_ref if total_ref > 0 else 1.0
            cells[(a, s)] = CellCost(ref.t_compute * f, ref.t_memory * f,
                                     ref.t_collective * f, ref.hbm_bytes)
    return CostModel(cells)
