"""Three-term roofline analysis from compiled dry-run artifacts.

  compute    = HLO_FLOPs(per-device) / (peak_FLOP/s · f_DVFS)
  memory     = HLO_bytes(per-device) / HBM_bw
  collective = collective_bytes(per-device, ring model) / link_bw

cost_analysis() is already per-partition under SPMD, and the compiled HLO
shapes are per-device, so no extra division by chip count is needed.
MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) is the *useful* compute;
MODEL/HLO ratio flags remat or dispatch waste.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro import hardware as hw
from repro.configs import ArchConfig, ShapeSpec
from repro.utils.hlo import CollectiveStats, parse_collectives


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # raw per-device quantities
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_counts: Dict[str, int]
    # derived terms (seconds)
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    # usefulness
    model_flops_global: float
    useful_ratio: float          # MODEL_FLOPS / (HLO_FLOPs · chips)
    roofline_fraction: float     # t_bound / t_total-ish: max-term / sum proxy
    # memory fit
    arg_bytes: int = 0
    temp_bytes: int = 0
    out_bytes: int = 0
    fits_hbm: bool = True
    note: str = ""

    def to_dict(self):
        return dataclasses.asdict(self)

    @property
    def t_step(self) -> float:
        """Roofline step-time estimate: the dominant term (perfect overlap)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_step_serial(self) -> float:
        """No-overlap upper bound."""
        return self.t_compute + self.t_memory + self.t_collective


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6·N_active·D for train (fwd+bwd); 2·N_active·D for inference."""
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def raw_costs(compiled, hlo_text: Optional[str] = None):
    """(flops, bytes, collective_bytes, collective_counts) per device.

    NOTE: XLA cost analysis counts while-loop bodies ONCE; callers must use
    fully-unrolled modules (dry-run cost variants) or correct for trips.
    """
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return flops, nbytes, coll.total_bytes, dict(coll.counts)


def analyze_costs(flops: float, nbytes: float, coll_bytes: float,
                  coll_counts: Dict[str, int], cfg: ArchConfig,
                  shape: ShapeSpec, mesh_name: str, chips: int, *,
                  dvfs_f: float = 1.0, mem=None, note: str = ""
                  ) -> RooflineReport:
    t_c = flops / (hw.PEAK_FLOPS_BF16 * dvfs_f)
    t_m = nbytes / hw.HBM_BW
    t_x = coll_bytes / hw.ICI_LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    useful = mf / max(1.0, flops * chips)
    # roofline fraction: useful-compute time over the dominant-term time —
    # "how close does the useful work run to the hardware bound".
    t_useful = mf / (chips * hw.PEAK_FLOPS_BF16 * dvfs_f)
    frac = t_useful / max(terms.values()) if max(terms.values()) > 0 else 0.0

    arg_b, temp_b, out_b = mem if mem else (0, 0, 0)
    fits = (arg_b + temp_b) <= hw.HBM_BYTES

    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes,
        collective_bytes=coll_bytes,
        collective_counts={k: v for k, v in coll_counts.items() if v},
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops_global=mf, useful_ratio=useful,
        roofline_fraction=frac, arg_bytes=arg_b, temp_bytes=temp_b,
        out_bytes=out_b, fits_hbm=fits, note=note)


def analyze(compiled, cfg: ArchConfig, shape: ShapeSpec, mesh_name: str,
            chips: int, *, dvfs_f: float = 1.0,
            hlo_text: Optional[str] = None, note: str = "") -> RooflineReport:
    flops, nbytes, coll_b, counts = raw_costs(compiled, hlo_text)
    try:
        ma = compiled.memory_analysis()
        mem = (ma.argument_size_in_bytes, ma.temp_size_in_bytes,
               ma.output_size_in_bytes)
    except Exception:  # pragma: no cover
        mem = None
    return analyze_costs(flops, nbytes, coll_b, counts, cfg, shape,
                         mesh_name, chips, dvfs_f=dvfs_f, mem=mem, note=note)


def format_table(reports) -> str:
    head = (f"{'arch':24s} {'shape':12s} {'mesh':9s} "
            f"{'t_comp(s)':>10s} {'t_mem(s)':>10s} {'t_coll(s)':>10s} "
            f"{'bound':>10s} {'useful':>7s} {'roofl%':>7s} "
            f"{'HBM(GiB)':>9s} fit")
    lines = [head, "-" * len(head)]
    for r in reports:
        hbm = (r.arg_bytes + r.temp_bytes) / 2**30
        lines.append(
            f"{r.arch:24s} {r.shape:12s} {r.mesh:9s} "
            f"{r.t_compute:10.4f} {r.t_memory:10.4f} {r.t_collective:10.4f} "
            f"{r.bottleneck:>10s} {r.useful_ratio:7.3f} "
            f"{100*r.roofline_fraction:6.1f}% {hbm:9.2f} "
            f"{'Y' if r.fits_hbm else 'OVER'}")
    return "\n".join(lines)


def save_reports(reports, path: str):
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in reports], f, indent=1)
