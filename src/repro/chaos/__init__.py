from repro.chaos.spec import (ChaosSpec, SiteCrash, Partition,
                              LinkStraggle)
from repro.chaos.inject import ChaosTimeline, FaultObservation
from repro.chaos.migrate import ChaosMigration, plan_chaos_migrations


def __getattr__(name):
    # ChaosController pulls in the whole online/search stack; lazy so
    # `scenario.spec -> chaos.spec` never re-enters a partially
    # initialized `repro.scenario` through `online.controller`.
    if name == "ChaosController":
        from repro.chaos.controller import ChaosController
        return ChaosController
    raise AttributeError(name)
