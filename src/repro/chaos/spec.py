"""Declarative fault injection: the chaos layer of a scenario.

A :class:`ChaosSpec` rides on :class:`~repro.scenario.spec.ScenarioSpec`
and declares the *unplanned* part of the world: site crashes, network
partitions and straggling links. Unlike the spec's ``outages`` (which
are forecastable maintenance windows every controller may read through
``down_oracle``), chaos events are invisible to planning — the engine
realizes them physically (fires defer, transfers stall, links slow) and
the controller only observes them through realized telemetry after they
fire (``down_now`` / ``partitioned_now`` / ``link_secs_window``).

The taxonomy:

==============  ==========================  ===========================
fault           device                      link
==============  ==========================  ===========================
crash           dead until recovery         dead until recovery
partition       alive (local exec works)    dead until heal
straggle        alive                       serialization × ``factor``
==============  ==========================  ===========================

The spec also fixes the *migration semantics* the engine applies when a
controller re-places mid-epoch around a fault:

* ``migration="cold"`` — drop in-flight state; the destination restores
  the last checkpoint (``checkpoint_every`` fires between saves, the
  :class:`~repro.checkpoint.ckpt.CheckpointManager` ``save_every``
  cadence) and replays the records covered since. Checkpoint size
  (``checkpoint_bytes_per_record``), not raw state bytes, crosses the
  uplink; a dead source is restored from the DC replica instead.
* ``migration="live"`` — pre-copy the full operator state while the
  source keeps serving, then stall only for the dirty delta + warm-up.
  A dead source forces a cold restore (there is nothing to pre-copy).

``ledger_mode`` picks the delivery guarantee of a cold cutover:
``exactly_once`` drains the source's in-flight work before switching
(slower cutover, zero duplicates); ``at_least_once`` cuts over
immediately and the replayed records are processed twice — the ledger
accounts them exactly in ``duplicates``, never silently lost.

``p_crash``/``seed`` sample additional random crashes through the
step-keyed :class:`~repro.checkpoint.failure.FailureInjector`, so a
chaos schedule is deterministic and replay-stable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Sequence, Tuple

MIGRATION_MODES = ("cold", "live")
LEDGER_MODES = ("exactly_once", "at_least_once")


@dataclasses.dataclass(frozen=True)
class SiteCrash:
    """Unplanned site crash: device and link dead until ``recover_s``."""
    site: str
    at_s: float
    recover_s: float


@dataclasses.dataclass(frozen=True)
class Partition:
    """Network partition: the site's link is dead until ``heal_s`` but
    the device keeps executing — local work proceeds, transfers stall."""
    site: str
    at_s: float
    heal_s: float


@dataclasses.dataclass(frozen=True)
class LinkStraggle:
    """Straggling link: every serialization through the site's uplink
    is inflated by ``factor`` while the window is active."""
    site: str
    at_s: float
    until_s: float
    factor: float = 4.0


@dataclasses.dataclass(frozen=True)
class ChaosSpec:
    """The whole fault schedule plus the migration/ledger semantics."""
    crashes: Tuple[SiteCrash, ...] = ()
    partitions: Tuple[Partition, ...] = ()
    straggles: Tuple[LinkStraggle, ...] = ()
    migration: str = "cold"             # cold | live
    ledger_mode: str = "exactly_once"   # exactly_once | at_least_once
    # fires between checkpoints (CheckpointManager.save_every semantics:
    # a checkpoint exists at fire counts 0, N, 2N, ...)
    checkpoint_every: int = 4
    # wire footprint of one checkpointed record (compacted partial
    # aggregates — smaller than the live operator state)
    checkpoint_bytes_per_record: float = 8.0
    p_crash: float = 0.0                # random per-(site, epoch) crash
    seed: int = 0

    def validate(self, site_names: Sequence[str]) -> None:
        known = set(site_names)
        if self.migration not in MIGRATION_MODES:
            raise ValueError(f"migration {self.migration!r} not in "
                             f"{MIGRATION_MODES}")
        if self.ledger_mode not in LEDGER_MODES:
            raise ValueError(f"ledger_mode {self.ledger_mode!r} not in "
                             f"{LEDGER_MODES}")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        for c in self.crashes:
            if c.site not in known:
                raise ValueError(f"crash for unknown site {c.site!r}")
            if c.recover_s <= c.at_s:
                raise ValueError(f"crash on {c.site!r}: empty window")
        for p in self.partitions:
            if p.site not in known:
                raise ValueError(f"partition for unknown site {p.site!r}")
            if p.heal_s <= p.at_s:
                raise ValueError(f"partition on {p.site!r}: empty window")
        for s in self.straggles:
            if s.site not in known:
                raise ValueError(f"straggle for unknown site {s.site!r}")
            if s.until_s <= s.at_s:
                raise ValueError(f"straggle on {s.site!r}: empty window")
            if s.factor < 1.0:
                raise ValueError(f"straggle on {s.site!r}: factor < 1")

    # ------------------------------------------------------------------ JSON
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "ChaosSpec":
        return cls(
            crashes=tuple(SiteCrash(**c) for c in d.get("crashes", ())),
            partitions=tuple(Partition(**p)
                             for p in d.get("partitions", ())),
            straggles=tuple(LinkStraggle(**s)
                            for s in d.get("straggles", ())),
            migration=d.get("migration", "cold"),
            ledger_mode=d.get("ledger_mode", "exactly_once"),
            checkpoint_every=d.get("checkpoint_every", 4),
            checkpoint_bytes_per_record=d.get(
                "checkpoint_bytes_per_record", 8.0),
            p_crash=d.get("p_crash", 0.0),
            seed=d.get("seed", 0))
