"""Live vs cold migration semantics for mid-epoch re-placement.

The legacy epoch-boundary path (:func:`repro.core.elastic.plan_replacement`)
charges a single analytic cost: raw state bytes over the uplink plus a
warm-up stall. Under chaos that model is wrong twice over — a crashed
source cannot ship anything, and real systems do not ship raw operator
state. This module implements the checkpoint-aware semantics:

**cold** — drop in-flight state. The destination restores the newest
checkpoint (cadence: every ``checkpoint_every`` fires, the
``CheckpointManager.save_every`` policy) and *replays* the records the
source covered since that checkpoint. Checkpoint bytes — not raw state
bytes — cross the uplink. If the source site is dead (crashed or
partitioned) the checkpoint is fetched from the DC replica instead; if
the destination is where the service's input records originate, nothing
crosses the network at all (the local record log is replayed).

**live** — pre-copy the full operator state while the source keeps
serving, then stall only for the dirty delta (records that arrived
during the pre-copy, re-shipped) plus warm-up. A dead source forces a
cold restore — there is nothing left to pre-copy.

**ledger modes** — ``exactly_once`` drains the source's in-flight work
before cutover (the drain time is added to the stall; nothing is
double-processed). ``at_least_once`` cuts over immediately: the replayed
records are processed twice, and every one of them is accounted in the
migration's ``duplicates`` — duplicates are counted, never silently
lost.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Mapping

from repro.chaos.spec import ChaosSpec

SERVICE_WARMUP_S = 2.0


@dataclasses.dataclass
class ChaosMigration:
    """One service moved mid-epoch, with the full cost decomposition."""
    service: str
    src: str
    dst: str
    kind: str                 # "live" | "cold" | "cold-restore" | "cold-local"
    wire_bytes: float         # what actually crossed the network
    transfer_s: float
    replay_records: int = 0
    replay_s: float = 0.0
    drain_s: float = 0.0
    warmup_s: float = SERVICE_WARMUP_S
    duplicates: int = 0       # replayed records double-processed

    @property
    def stall_s(self) -> float:
        return self.transfer_s + self.replay_s + self.drain_s + self.warmup_s

    def digest(self) -> Dict:
        return {"service": self.service, "src": self.src, "dst": self.dst,
                "kind": self.kind, "wire_bytes": round(self.wire_bytes, 3),
                "transfer_s": round(self.transfer_s, 6),
                "replay_records": self.replay_records,
                "replay_s": round(self.replay_s, 6),
                "drain_s": round(self.drain_s, 6),
                "duplicates": self.duplicates,
                "stall_s": round(self.stall_s, 6)}


def plan_chaos_migrations(
        chaos: ChaosSpec,
        old: Mapping[str, object], new: Mapping[str, object],
        t: float, *,
        src_dead: Callable[[str], bool],
        ship: Callable[[str, str, float, float], float],
        state_bytes: Callable[[str], float],
        ckpt_bytes: Callable[[str], float],
        replay_records: Callable[[str], int],
        replay_time: Callable[[str, int, str], float],
        rate_rps: Callable[[str], float],
        drain_s: Callable[[str], float],
        dc_site: str,
        local_origin: Callable[[str, str], bool],
        warmup_s: float = SERVICE_WARMUP_S,
        charge: bool = True) -> List[ChaosMigration]:
    """Plan the migrations taking `old` assignments to `new` at time `t`.

    `ship(src, dst, nbytes, t) -> arrival_ts` charges the real FIFO
    (pass a no-op arrival when `charge` is false — screening). All other
    callables are keyed by service; `local_origin(svc, dst)` is true when
    the service's input records originate at `dst` (replay needs no
    network). `src_dead(site)` is the realized crash/partition state of
    a site's *link* at `t`.
    """
    migs: List[ChaosMigration] = []
    exactly_once = chaos.ledger_mode == "exactly_once"
    for svc in sorted(new):
        asg_new = new[svc]
        asg_old = old.get(svc)
        if asg_old is None or asg_old.site == asg_new.site:
            continue
        src, dst = asg_old.site, asg_new.site
        dead = src_dead(src)
        live = chaos.migration == "live" and not dead

        if live:
            nbytes = state_bytes(svc)
            arrive = ship(src, dst, nbytes, t) if charge else t
            pre_copy = max(0.0, arrive - t)
            # dirty delta: records that landed during the pre-copy must
            # be re-shipped before cutover; bounded by the full state
            dirty = min(nbytes,
                        rate_rps(svc) * pre_copy
                        * chaos.checkpoint_bytes_per_record)
            frac = dirty / nbytes if nbytes > 0 else 0.0
            m = ChaosMigration(
                service=svc, src=src, dst=dst, kind="live",
                wire_bytes=nbytes + dirty,
                transfer_s=pre_copy * frac,   # only the delta stalls
                drain_s=drain_s(svc) if exactly_once else 0.0,
                warmup_s=warmup_s)
            migs.append(m)
            continue

        # cold path: restore the newest checkpoint, replay the gap
        n_replay = replay_records(svc)
        if local_origin(svc, dst):
            # the records live where we are going — replay the local log
            kind, nbytes, arrive = "cold-local", 0.0, t
        elif dead:
            # source is unreachable: fetch the checkpoint replica
            # that the DC keeps (every save crosses the uplink anyway)
            kind = "cold-restore"
            nbytes = ckpt_bytes(svc)
            arrive = ship(dc_site, dst, nbytes, t) if charge else t
        else:
            kind = "cold"
            nbytes = ckpt_bytes(svc)
            arrive = ship(src, dst, nbytes, t) if charge else t
        m = ChaosMigration(
            service=svc, src=src, dst=dst, kind=kind,
            wire_bytes=nbytes,
            transfer_s=max(0.0, arrive - t),
            replay_records=n_replay,
            replay_s=replay_time(svc, n_replay, dst) if n_replay else 0.0,
            # a dead source has nothing to drain; exactly-once dedups
            # the replay instead of double-counting it
            drain_s=drain_s(svc) if (exactly_once and not dead) else 0.0,
            warmup_s=warmup_s,
            duplicates=0 if exactly_once else n_replay)
        migs.append(m)
    return migs
