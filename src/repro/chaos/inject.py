"""Compile a :class:`ChaosSpec` into a queryable fault timeline.

The timeline is the *ground truth* the engine injects physically:
per-site crash windows (device + link dead), partition windows (link
dead, device alive) and straggle windows (serialization × factor).
Random crashes are sampled through the step-keyed
:class:`~repro.checkpoint.failure.FailureInjector` keyed by
(site, epoch), so two compilations of the same spec over the same
epoch grid produce the identical schedule — replay-stable chaos.

Controllers never see this object. They see only what the fleet
realizes: ``down_now`` flips once a crash fires, ``partitioned_now``
once a partition fires, and straggles surface as inflated per-transfer
link seconds in ``link_secs_window``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.checkpoint.failure import FailureInjector
from repro.chaos.spec import ChaosSpec

_EPS = 1e-9
# step-key stride separating sites in the FailureInjector key space
_SITE_STRIDE = 100_003


@dataclasses.dataclass(frozen=True)
class FaultObservation:
    """What a controller is shown at a mid-epoch chaos boundary: the
    *realized* world at time ``t`` — never the schedule itself."""
    t: float
    epoch: int
    down_now: Dict[str, bool]
    partitioned_now: Dict[str, bool]
    straggle_now: Dict[str, float]
    events: List[Dict] = dataclasses.field(default_factory=list)


class ChaosTimeline:
    """Per-site fault windows compiled from a ChaosSpec."""

    def __init__(self, crash: Dict[str, List[Tuple[float, float]]],
                 partition: Dict[str, List[Tuple[float, float]]],
                 straggle: Dict[str, List[Tuple[float, float, float]]]):
        self._crash = {s: sorted(w) for s, w in crash.items() if w}
        self._partition = {s: sorted(w) for s, w in partition.items() if w}
        self._straggle = {s: sorted(w) for s, w in straggle.items() if w}

    @classmethod
    def compile(cls, spec: ChaosSpec, site_names: Sequence[str],
                horizon_s: float,
                epochs: Sequence[Tuple[float, float]]) -> "ChaosTimeline":
        crash: Dict[str, List[Tuple[float, float]]] = {}
        partition: Dict[str, List[Tuple[float, float]]] = {}
        straggle: Dict[str, List[Tuple[float, float, float]]] = {}
        for c in spec.crashes:
            crash.setdefault(c.site, []).append((c.at_s, c.recover_s))
        for p in spec.partitions:
            partition.setdefault(p.site, []).append((p.at_s, p.heal_s))
        for s in spec.straggles:
            straggle.setdefault(s.site, []).append(
                (s.at_s, s.until_s, s.factor))
        if spec.p_crash > 0.0:
            # deterministic random crashes: one step-keyed coin per
            # (site, epoch); onset mid-epoch (unforecastable by
            # construction), recovery one epoch later
            inj = FailureInjector(p_fail=spec.p_crash, seed=spec.seed)
            for si, site in enumerate(sorted(site_names)):
                for k, (t0, t1) in enumerate(epochs):
                    if inj.should_fail(si * _SITE_STRIDE + k):
                        mid = 0.5 * (t0 + t1)
                        crash.setdefault(site, []).append(
                            (mid, min(horizon_s, t1 + (t1 - t0))))
        return cls(crash, partition, straggle)

    # ------------------------------------------------------------- per-site
    def crash_windows(self, site: str) -> Tuple[Tuple[float, float], ...]:
        return tuple(self._crash.get(site, ()))

    def partition_windows(self, site: str) -> Tuple[Tuple[float, float], ...]:
        return tuple(self._partition.get(site, ()))

    def straggle_windows(self, site: str) \
            -> Tuple[Tuple[float, float, float], ...]:
        return tuple(self._straggle.get(site, ()))

    # -------------------------------------------------------------- queries
    def crashed(self, site: str, t: float) -> bool:
        return any(lo <= t < hi for lo, hi in self._crash.get(site, ()))

    def partitioned(self, site: str, t: float) -> bool:
        return any(lo <= t < hi for lo, hi in self._partition.get(site, ()))

    def straggle_factor(self, site: str, t: float) -> float:
        f = 1.0
        for lo, hi, fac in self._straggle.get(site, ()):
            if lo <= t < hi:
                f = max(f, fac)
        return f

    def boundaries(self, t0: float, t1: float) -> List[float]:
        """Fault onset/heal instants strictly inside (t0, t1) — the
        engine cuts the epoch here so a controller can react mid-epoch."""
        pts = set()
        for wins in self._crash.values():
            for lo, hi in wins:
                pts.update((lo, hi))
        for wins in self._partition.values():
            for lo, hi in wins:
                pts.update((lo, hi))
        for wins in self._straggle.values():
            for lo, hi, _ in wins:
                pts.update((lo, hi))
        return sorted(p for p in pts if t0 + _EPS < p < t1 - _EPS)

    def events_at(self, t: float) -> List[Dict]:
        """Faults whose onset or heal coincides with `t` (the trigger a
        FaultObservation carries, for telemetry — sites only, no
        future schedule)."""
        out = []
        for kind, table in (("crash", self._crash),
                            ("partition", self._partition)):
            for site, wins in sorted(table.items()):
                for lo, hi in wins:
                    if abs(lo - t) < _EPS:
                        out.append({"kind": kind, "site": site})
                    elif abs(hi - t) < _EPS:
                        out.append({"kind": f"{kind}-heal", "site": site})
        for site, wins in sorted(self._straggle.items()):
            for lo, hi, fac in wins:
                if abs(lo - t) < _EPS:
                    out.append({"kind": "straggle", "site": site})
                elif abs(hi - t) < _EPS:
                    out.append({"kind": "straggle-heal", "site": site})
        return out

    def any_faults(self) -> bool:
        return bool(self._crash or self._partition or self._straggle)
