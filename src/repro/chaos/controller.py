"""Chaos-aware re-placement: react to faults the plan never foresaw.

:class:`ChaosController` is the honest :class:`~repro.online.controller.
OnlineController` plus two abilities, both fed exclusively by *realized*
telemetry (it reads neither the chaos schedule nor the oracle fields):

1. **Telemetry-steered forecasting.** Partitions observed at an epoch
   boundary (``partitioned_now``) mark links dead in the forecast model;
   per-transfer uplink seconds (``link_secs_window``) feed the
   :class:`~repro.runtime.straggler.StragglerMonitor`, and a flagged
   site's last-to-baseline serialization ratio inflates its
   serialization terms — the plan search routes around sick links.

2. **Emergency mid-epoch re-planning.** The engine cuts the epoch at
   each realized fault boundary and calls :meth:`decide_fault`. When the
   live plan is hit (hosting site crashed, feeding link partitioned, or
   simply beatable under the post-fault world), the controller re-runs
   the placement search against the updated model and returns the new
   plan — the engine applies checkpoint-aware migrations and adopts it
   immediately instead of waiting for the boundary.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.online.controller import ForecastModel, OnlineController
from repro.placement.plan import PlacementPlan
from repro.placement.search import Evaluator, search_placement
from repro.runtime.straggler import StragglerMonitor
from repro.scenario.observe import BridgeInfo, EpochObservation
from repro.chaos.inject import FaultObservation


class ChaosController(OnlineController):
    """Online controller hardened for unforecastable faults."""
    label = "chaos"

    def __init__(self, *args, replan_margin: float = 0.0,
                 straggle_threshold: float = 2.0,
                 straggle_window: int = 8, **kw):
        super().__init__(*args, **kw)
        self.label = "chaos" + ("-cal" if self.calibrate else "")
        self.replan_margin = float(replan_margin)
        self.straggle_threshold = float(straggle_threshold)
        self.straggle_window = int(straggle_window)

    def bind(self, info: BridgeInfo) -> None:
        super().bind(info)
        self._site_order: List[str] = list(info.fleet.site_names)
        self._monitor = StragglerMonitor(
            len(self._site_order), window=self.straggle_window,
            slack=self.straggle_threshold, min_samples=2)
        # per-site clean-serialization floor and freshest sample: the
        # slowdown estimate is each link's own last/baseline ratio, so
        # it survives the window median drifting up when every active
        # link straggles at once (the monitor's flags stay the
        # persistence gate; the ratio stays the magnitude)
        self._link_base: Dict[str, float] = {}
        self._link_last: Dict[str, float] = {}
        self._slowdown: Dict[str, float] = {}
        self._partitioned: Dict[str, bool] = {}
        self._last_rates: Optional[Dict[str, float]] = None
        self._seen_link_epochs = 0
        self.fault_log: List[Dict] = []

    # ----------------------------------------------------- model steering
    def _make_model(self, rates, down, corr) -> ForecastModel:
        self._last_rates = dict(rates)
        return ForecastModel(self.info, rates, down, corrections=corr,
                             link_slowdown=self._slowdown,
                             link_dead=self._partitioned)

    def _model_fingerprint(self, rates, down, corr) -> Tuple:
        base = super()._model_fingerprint(rates, down, corr)
        return base + (
            tuple(sorted((s, round(f, 6))
                         for s, f in self._slowdown.items())),
            tuple(sorted(s for s, v in self._partitioned.items() if v)))

    def _absorb_link_telemetry(self, obs: EpochObservation) -> None:
        """Feed each newly completed epoch's per-site mean serialization
        seconds per transfer into the straggler monitor; flagged sites
        get a slowdown estimate the forecast model plans around."""
        window = getattr(obs, "link_secs_window", None) or []
        for k in range(self._seen_link_epochs, len(window)):
            row = [window[k].get(s, 0.0) for s in self._site_order]
            for s, t in zip(self._site_order, row):
                if t > 0.0:
                    self._link_last[s] = t
                    self._link_base[s] = min(
                        t, self._link_base.get(s, t))
            active = sorted(t for t in row if t > 0.0)
            if not active:
                continue
            # idle sites contribute their own last-known seconds (so a
            # lone straggling link stays an outlier against its stable
            # peers); a never-observed site falls back to the median of
            # the active ones so it never reads as artificially fast
            med = active[len(active) // 2]
            self._monitor.record_step(
                k, [t if t > 0.0
                    else self._link_last.get(s, med)
                    for s, t in zip(self._site_order, row)])
        self._seen_link_epochs = len(window)
        self._slowdown = {}
        for h in self._monitor.persistent_stragglers(threshold=2):
            s = self._site_order[h]
            base = self._link_base.get(s, 0.0)
            if base <= 0.0:
                continue
            f = self._link_last.get(s, base) / base
            if f >= self.straggle_threshold:
                self._slowdown[s] = round(f, 3)

    # --------------------------------------------------------- epoch path
    def decide(self, obs: EpochObservation) -> PlacementPlan:
        self._partitioned = {
            s: bool(v)
            for s, v in (getattr(obs, "partitioned_now", None) or {}).items()
            if v}
        self._absorb_link_telemetry(obs)
        return super().decide(obs)

    # ------------------------------------------------------ mid-epoch path
    def _plan_is_hit(self, fobs: FaultObservation) -> bool:
        """Does any event touch a site the live plan depends on — as a
        host, or as the farm site feeding a hosted service? Heal events
        count too: capacity coming back mid-epoch is worth re-planning
        for."""
        if self.current is None:
            return True
        if not fobs.events:
            return False
        touched = {e["site"] for e in fobs.events}
        hosting = {self.current.site(s) for s in self.info.topology}
        feeding = {self.info.fleet.farm_site(self.info.services[s].queue)
                   for s in self.info.topology}
        if touched & (hosting | feeding):
            return True
        # a heal re-opens sites the plan might want back
        return any(e["kind"].endswith("-heal") for e in fobs.events)

    def decide_fault(self, fobs: FaultObservation
                     ) -> Optional[PlacementPlan]:
        """Emergency re-plan at a realized fault boundary. Returns the
        new plan to adopt mid-epoch, or None to ride out the epoch."""
        self._partitioned = {s: True for s, v in fobs.partitioned_now.items()
                             if v}
        down = {s: bool(v) for s, v in fobs.down_now.items()}
        rates = dict(self._last_rates) if self._last_rates else (
            dict(self.prior_rates) if self.prior_rates
            else {s: 1.0 for s in self.info.topology})
        corr = (self.calibration.corrections()
                if self.calibration is not None else None)
        if not self._plan_is_hit(fobs):
            return None
        model = self._make_model(rates, down, corr)
        cur = model.run(self.current) if self.current is not None else None
        fp = self._model_fingerprint(rates, down, corr) \
            + ("fault", round(fobs.t, 6))
        up = tuple(s for s in self.info.fleet.site_names if not down.get(s))
        ev = Evaluator(model, cache=self._xcache, key_prefix=fp)
        sr = search_placement(model, self.chips_options, self.dvfs_options,
                              seed=self.seed,
                              edge_sites=up or self.info.fleet.site_names,
                              warm_start=self.current, evaluator=ev)
        new = model.run(sr.plan)
        entry = {"t": round(fobs.t, 3), "epoch": fobs.epoch,
                 "events": list(fobs.events),
                 "cur_vos": (round(cur.vos, 4)
                             if cur is not None and cur.feasible else None),
                 "new_vos": round(new.vos, 4) if new.feasible else None,
                 "switched": False}
        must = cur is None or not cur.feasible
        better = (new.feasible and cur is not None and cur.feasible
                  and new.vos > cur.vos * (1.0 + self.replan_margin) + 1e-9)
        if new.feasible and (must or better) and (
                self.current is None
                or sr.plan.key() != self.current.key()):
            self.current = sr.plan
            entry["switched"] = True
            self.fault_log.append(entry)
            return sr.plan
        self.fault_log.append(entry)
        return None
