"""Mamba-2 SSD chunk scan, TPU Pallas (arXiv:2405.21060).

The state-space-duality chunking maps onto the MXU as three GEMMs per
chunk — C·Bᵀ (scores), M·X (diagonal term), Xᵀ·B̃ (state update) — with
the O(1)-size recurrent state h [P, N] carried across the sequential
chunk grid dimension in VMEM scratch. Grid: (B·H, n_chunks), chunk dim
"arbitrary".

Layouts (per b·h): x [BH, L, P], dt/da [BH, L], B/C [BH, L, N] (groups
broadcast to heads by ops.py's index_map arithmetic; G=1 in all assigned
configs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the TPU compile options TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref, o_ref, h_ref, *,
                chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)      # [Q]
    da = da_ref[0].astype(jnp.float32)      # [Q]  (= dt · A, negative)
    Bm = b_ref[0].astype(jnp.float32)       # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)       # [Q, N]
    Q = x.shape[0]

    cum = jnp.cumsum(da)                    # [Q]
    seg = cum[:, None] - cum[None, :]       # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    mask = jj <= ii

    # diagonal (within-chunk) term: (C Bᵀ ⊙ decay ⊙ dt_j) X
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    M = jnp.where(mask, cb * jnp.exp(seg) * dt[None, :], 0.0)
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # [Q, P]

    # carry-in term: (C ⊙ e^cum) hᵀ
    h = h_ref[...]                           # [P, N]
    Cin = Cm * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(Cin, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: h' = e^{cum_Q} h + Xᵀ (B ⊙ dt ⊙ e^{cum_Q − cum})
    total = cum[-1]
    wB = Bm * (dt * jnp.exp(total - cum))[:, None]                # [Q, N]
    h_new = (jnp.exp(total) * h
             + jax.lax.dot_general(x, wB, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    h_ref[...] = h_new
    o_ref[0] = y.astype(o_ref.dtype)


def ssd_scan_bhl(x: jax.Array, dt: jax.Array, da: jax.Array, B_: jax.Array,
                 C: jax.Array, *, chunk: int = 128,
                 interpret: bool = True) -> jax.Array:
    """x: [BH, L, P]; dt/da: [BH, L]; B_/C: [BH, L, N]. L % chunk == 0."""
    BH, L, P = x.shape
    N = B_.shape[-1]
    nc = L // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, chunk), lambda bh, j: (bh, j)),
            pl.BlockSpec((1, chunk), lambda bh, j: (bh, j)),
            pl.BlockSpec((1, chunk, N), lambda bh, j: (bh, j, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, j: (bh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, P), lambda bh, j: (bh, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, da, B_, C)
