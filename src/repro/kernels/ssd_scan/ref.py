"""Pure-jnp oracle for the SSD scan kernel: the sequential (non-chunked)
state-space recurrence, O(L) steps — slow but unambiguous."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_reference(x, dt, A, B_, C):
    """x [B,L,H,P]; dt [B,L,H]; A [H]; B_/C [B,L,G,N] → y [B,L,H,P].

    h_t = exp(dt_t A) h_{t-1} + dt_t · (B_t ⊗ x_t);  y_t = C_t · h_t
    """
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2).astype(jnp.float32)   # [B,L,H,N]
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                     # [B,H,P],[B,H],[B,H,N]
        decay = jnp.exp(dt_t * A)[..., None, None]    # [B,H,1,1]
        dBx = (dt_t[..., None, None] * b_t[:, :, None, :]
               * x_t[..., None])                      # [B,H,P,N]
        h = h * decay + dBx
        y = jnp.einsum("bhpn,bhn->bhp", h, c_t)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)     # [B,L,H,P]
