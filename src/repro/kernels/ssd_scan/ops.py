"""jit'd wrapper for the SSD scan kernel: model layout → kernel layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_bhl


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool = True) -> jax.Array:
    """Model layout (matches models/ssm.ssd_chunked):
    x [B, L, H, P]; dt [B, L, H] (post-softplus); A [H] (negative);
    B_/C [B, L, G, N] (G groups broadcast over H). Returns y [B, L, H, P]
    (without the D·x skip, which the caller adds)."""
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G

    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad

    xb = x.transpose(0, 2, 1, 3).reshape(Bb * H, Lp, P)
    dtb = dt.transpose(0, 2, 1).reshape(Bb * H, Lp)
    dab = dtb * jnp.tile(A, Bb)[:, None]   # da[b·H+h, l] = dt · A_h
    Bq = jnp.repeat(B_.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        Bb * H, Lp, N)
    Cq = jnp.repeat(C.transpose(0, 2, 1, 3), rep, axis=1).reshape(
        Bb * H, Lp, N)

    y = ssd_scan_bhl(xb, dtb, dab, Bq, Cq, chunk=chunk, interpret=interpret)
    y = y.reshape(Bb, H, Lp, P).transpose(0, 2, 1, 3)
    return y[:, :L]
