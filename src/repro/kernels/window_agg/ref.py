"""Pure-jnp oracle for sliding-window aggregation."""
from __future__ import annotations

import jax.numpy as jnp


def window_aggregate_reference(x, *, agg: str, window: int, stride: int):
    T, C = x.shape
    n_out = (T - window) // stride + 1
    outs = []
    for o in range(n_out):
        w = x[o * stride: o * stride + window].astype(jnp.float32)
        outs.append({"max": jnp.max, "min": jnp.min, "sum": jnp.sum,
                     "mean": jnp.mean}[agg](w, axis=0))
    return jnp.stack(outs).astype(x.dtype)
