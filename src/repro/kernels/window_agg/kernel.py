"""Segment reduction, TPU Pallas — the hot loop of the paper's stream
services ("EVERY 60s compute the max of download_speed over the last 3
minutes", §3).

TPU adaptation (DESIGN §2): a sliding window with stride s and width w=m·s
factors into (1) a dense reduction of the raw stream into s-sized
segments — this kernel, where all the bytes move — and (2) a combine of m
consecutive segment aggregates per output (ops.py, trivially vectorized).
Phase 1 is perfectly Blocked for Pallas: each grid cell owns
(block_o · stride) rows × 128 lanes of VMEM and reduces on the VPU.

Aggregations must be decomposable (max/min/sum/mean — the paper's
services, Fig. 2); mean combines as sum/width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the TPU compile options TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

INIT = {"max": -3.4e38, "min": 3.4e38, "sum": 0.0}


def _segment_kernel(x_ref, o_ref, *, agg: str, stride: int, block_o: int):
    """x_ref: [block_o·stride, block_c] → o_ref: [block_o, block_c]."""
    block_c = o_ref.shape[1]
    x = x_ref[...].astype(jnp.float32)
    x = x.reshape(block_o, stride, block_c)
    if agg == "max":
        r = jnp.max(x, axis=1)
    elif agg == "min":
        r = jnp.min(x, axis=1)
    else:
        r = jnp.sum(x, axis=1)
    o_ref[...] = r.astype(o_ref.dtype)


def segment_reduce_tc(x: jax.Array, *, agg: str, stride: int,
                      block_o: int = 8, block_c: int = 128,
                      interpret: bool = True) -> jax.Array:
    """x: [T, C] → [T//stride, C]; T % (block_o·stride) == 0, C % block_c == 0
    (ops.py pads). agg ∈ {max, min, sum}."""
    T, C = x.shape
    n_seg = T // stride
    assert T % (block_o * stride) == 0 and C % block_c == 0, (T, C)

    kernel = functools.partial(_segment_kernel, agg=agg, stride=stride,
                               block_o=block_o)
    return pl.pallas_call(
        kernel,
        grid=(n_seg // block_o, C // block_c),
        in_specs=[pl.BlockSpec((block_o * stride, block_c),
                               lambda o, c: (o, c))],
        out_specs=pl.BlockSpec((block_o, block_c), lambda o, c: (o, c)),
        out_shape=jax.ShapeDtypeStruct((n_seg, C), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
