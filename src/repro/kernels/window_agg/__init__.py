from repro.kernels.window_agg.ops import window_aggregate
from repro.kernels.window_agg.ref import window_aggregate_reference
