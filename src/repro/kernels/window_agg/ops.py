"""jit'd wrapper: sliding-window aggregation = Pallas segment reduce +
vectorized combine of window//stride consecutive segments."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.window_agg.kernel import INIT, segment_reduce_tc


@functools.partial(jax.jit, static_argnames=("agg", "window", "stride",
                                             "interpret"))
def window_aggregate(x: jax.Array, *, agg: str, window: int, stride: int,
                     interpret: bool = True) -> jax.Array:
    """x: [T, C] → [n_out, C] with out[o] = agg(x[o·stride : o·stride+window]).

    window must be a multiple of stride (the paper's queries are:
    180 s / 60 s, 120 d / 5 min). n_out = (T - window)//stride + 1.
    """
    if window % stride:
        raise ValueError("window must be a multiple of stride")
    T, C = x.shape
    if T < window:
        raise ValueError("series shorter than window")
    m = window // stride
    base = "sum" if agg == "mean" else agg

    # pad T to a block multiple, C to the 128-lane register width
    n_out_est = (T - window) // stride + 1
    block_o, block_c = min(8, n_out_est), 128
    pad_t = (-T) % (block_o * stride)
    pad_c = (-C) % block_c
    fill = INIT[base]
    xp = jnp.pad(x, ((0, pad_t), (0, pad_c)), constant_values=fill)

    seg = segment_reduce_tc(xp, agg=base, stride=stride, block_o=block_o,
                            block_c=block_c, interpret=interpret)
    seg = seg[:, :C]
    n_seg_valid = T // stride

    # combine m consecutive segments per output (cheap: n_seg × C)
    n_out = (T - window) // stride + 1
    parts = jnp.stack([seg[i:i + n_out] for i in range(m)])
    if base == "max":
        out = jnp.max(parts, axis=0)
    elif base == "min":
        out = jnp.min(parts, axis=0)
    else:
        out = jnp.sum(parts, axis=0)
    if agg == "mean":
        out = out / window
    return out
