"""Causal flash attention, TPU Pallas (pl.pallas_call + BlockSpec).

Canonical online-softmax formulation (FlashAttention-2, arXiv:2307.08691)
tiled for the TPU memory hierarchy: q/k/v stream HBM→VMEM in MXU-aligned
(block_q × d) / (block_k × d) tiles; the running (m, l, acc) state lives in
VMEM scratch across the sequential k-block grid dimension. GQA is handled
in the kv index_map (no repeated-KV materialization in HBM).

Grid: (batch·q_heads, n_q_blocks, n_k_blocks), k-dim "arbitrary"
(sequential) so scratch carries across it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 names the TPU compile options TPUCompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  n_k_blocks: int, seq_kv: int, q_offset: int):
    i = pl.program_id(1)   # q block
    j = pl.program_id(2)   # k block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k

    def _body():
        q = q_ref[0].astype(jnp.float32)            # [block_q, d]
        k = k_ref[0].astype(jnp.float32)            # [block_k, d]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # [bq, bk]

        kv_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = kv_pos < seq_kv
        if causal:
            # right-aligned causal (query i sees kv ≤ i + q_offset, the
            # continuation/decode convention when Skv > Sq)
            q_pos = (q_start + q_offset
                     + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
            valid = valid & (kv_pos <= q_pos)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]                          # [bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(valid, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    if causal:
        # skip k blocks strictly after the last query of this q block
        pl.when(k_start <= q_start + q_offset + block_q - 1)(_body)
    else:
        _body()

    @pl.when(j == n_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = True,
                         rep: int = 1, seq_kv_valid: int | None = None,
                         seq_q_valid: int | None = None) -> jax.Array:
    """q: [BH, Sq, d]; k/v: [B·KV, Skv, d]; rep = H // KV (GQA).

    Sq/Skv must be multiples of block_q/block_k (ops.py pads);
    seq_kv_valid masks right-padded kv rows (defaults to Skv).
    """
    BH, Sq, d = q.shape
    _, Skv, _ = k.shape
    nq = Sq // block_q
    nk = Skv // block_k
    scale = 1.0 / math.sqrt(d)

    svalid = Skv if seq_kv_valid is None else seq_kv_valid
    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, n_k_blocks=nk, seq_kv=svalid,
        q_offset=svalid - (Sq if seq_q_valid is None else seq_q_valid))

    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh // rep, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda bh, i, j: (bh // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
