"""jit'd public wrapper for the flash attention kernel: layout, GQA,
padding to MXU-aligned blocks, and the interpret/TPU switch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True) -> jax.Array:
    """q: [B, Sq, H, d]; k/v: [B, Skv, KV, d] (GQA) → [B, Sq, H, d].

    Pads sequence dims up to block multiples (padded kv masked inside the
    kernel via seq_kv; padded q rows discarded on return).
    """
    B, Sq, H, d = q.shape
    _, Skv, KV, _ = k.shape
    rep = H // KV

    pad_q = (-Sq) % block_q
    pad_k = (-Skv) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qb = q.transpose(0, 2, 1, 3).reshape(B * H, Sq + pad_q, d)
    kb = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv + pad_k, d)
    vb = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv + pad_k, d)

    ob = flash_attention_bhsd(qb, kb, vb, causal=causal, block_q=block_q,
                              block_k=block_k, interpret=interpret, rep=rep,
                              seq_kv_valid=Skv, seq_q_valid=Sq)
    out = ob.reshape(B, H, Sq + pad_q, d).transpose(0, 2, 1, 3)
    return out[:, :Sq]
