"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_reference(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True) -> jax.Array:
    """q: [B, Sq, H, d]; k/v: [B, Skv, KV, d] (GQA). Exact softmax attention."""
    B, Sq, H, d = q.shape
    _, Skv, KV, _ = k.shape
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Skv), bool), k=Skv - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
