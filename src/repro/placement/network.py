"""Edge↔DC network model.

Every cut edge in a placement plan (an edge-resident service feeding a
DC-resident one, or vice versa) pays a network hop: half-RTT plus
serialization at the link bandwidth, and NIC/radio energy per byte on
the edge side. Records can optionally be compressed before the uplink
(the paper's pipelines ship pre-aggregated or delta-coded measurements;
``compression`` is the resulting size factor).

Results flowing DC→edge are single aggregate records, so the downlink
is dominated by RTT rather than bandwidth.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """Defaults ≈ a fixed-wireless uplink from an edge site to a DC."""
    uplink_bps: float = 20e6          # edge → DC
    downlink_bps: float = 100e6       # DC → edge
    rtt_s: float = 0.040
    record_bytes: float = 64.0        # wire size of one raw record
    result_bytes: float = 64.0        # wire size of one aggregate result
    compression: float = 1.0          # uplink size factor in (0, 1]
    energy_per_byte_j: float = 40e-9  # edge NIC/radio energy


class NetworkModel:
    """Transfer-time and energy accounting over one edge↔DC link."""

    def __init__(self, spec: LinkSpec):
        if not 0.0 < spec.compression <= 1.0:
            raise ValueError("compression must be in (0, 1]")
        self.spec = spec
        self.bytes_up = 0.0
        self.bytes_down = 0.0
        self.energy_j = 0.0

    def uplink_wire_bytes(self, n_records: int) -> float:
        return n_records * self.spec.record_bytes * self.spec.compression

    def uplink_serialization_s(self, n_records: int) -> float:
        """Time the uplink pipe is *occupied* by this transfer (excludes
        propagation) — what a contended shared uplink serializes on."""
        return self.uplink_wire_bytes(n_records) / self.spec.uplink_bps

    def uplink_time(self, n_records: int) -> float:
        return self.spec.rtt_s / 2 + self.uplink_serialization_s(n_records)

    def downlink_time(self, n_results: int = 1) -> float:
        wire = n_results * self.spec.result_bytes
        return self.spec.rtt_s / 2 + wire / self.spec.downlink_bps

    def uplink(self, n_records: int) -> float:
        """Ship `n_records` edge→DC; returns transfer time, accounts
        bytes and edge-side energy."""
        wire = n_records * self.spec.record_bytes * self.spec.compression
        self.bytes_up += wire
        self.energy_j += wire * self.spec.energy_per_byte_j
        return self.uplink_time(n_records)

    def downlink(self, n_results: int = 1) -> float:
        """Return `n_results` aggregates DC→edge."""
        wire = n_results * self.spec.result_bytes
        self.bytes_down += wire
        self.energy_j += wire * self.spec.energy_per_byte_j
        return self.downlink_time(n_results)

    def downlink_records(self, n_records: int) -> float:
        """Raw records arriving over this site's downlink (site→site
        routing relays through the backhaul: src uplink, then the dst
        site's downlink). Record-sized wire, not aggregate-sized."""
        wire = n_records * self.spec.record_bytes
        self.bytes_down += wire
        self.energy_j += wire * self.spec.energy_per_byte_j
        return self.spec.rtt_s / 2 + wire / self.spec.downlink_bps
