"""Edge↔DC co-simulator: one placement plan, one end-to-end run.

Bridges the repo's two halves. The functional dataflow always executes
in-process through the real :class:`~repro.pipeline.composition.Pipeline`
(brokers, buffers, stores — exact record accounting); the *timing and
energy* of every service fire are then co-simulated against the chosen
placement:

  * edge-placed fires execute on an :class:`~repro.placement.edge.EdgeNode`
    (serial device, queueing + energy accounting);
  * DC-placed fires ship their new records over the
    :class:`~repro.placement.network.NetworkModel`, become
    :class:`~repro.core.tasks.Task`s whose value curves are the service
    SLO shifted by the accumulated upstream + transfer delay, and are
    submitted to the existing JITA-4DS :class:`~repro.core.simulator.
    Simulator` on a fresh :class:`~repro.core.vdc.PodGrid` — so DC fires
    contend for VDC composition exactly like any other job, may be
    queued behind other jobs, or dropped when their value decays to
    zero.

Network hops are paid at placement cuts only: a DC task's uplink ships
the newly covered records of *edge* origin (farm records and results of
edge-placed upstreams; results that a DC-placed upstream produced never
left the DC), DC→DC handoffs traverse no link, and every completed DC
fire pays one downlink because its aggregate surfaces edge-side for the
user — that downlink gates edge-placed consumers and the user-visible
latency, but not downstream DC compute.

Two timing passes run: pass 1 collects the DC task trace using
optimistic completion estimates for DC→DC handoffs (a pipelined
submission model); after the DC simulation, pass 2 re-runs the timing
with the *actual* VDC completion times to produce final end-to-end
latencies, the edge/network/DC energy split and the Eq. 2 VoS.

Record conservation is tracked per service with exact set partitions:
every record published into a service's input queue ends up exactly one
of {queue-overflow, unread, edge-processed, DC-processed, DC-dropped,
DC-in-flight, buffered, evicted-to-store, evicted-lost}.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import hardware as hw
from repro.core.costmodel import CellCost, CostModel
from repro.core.heuristics import HEURISTICS, VPTRHeuristic
from repro.core.simulator import SimResult, Simulator
from repro.core.tasks import Task, TaskType
from repro.core.value import TaskValueSpec, ValueCurve, task_value
from repro.core.vdc import PodGrid
from repro.pipeline.composition import Pipeline
from repro.placement.edge import EdgeNode, EdgeSpec
from repro.placement.network import LinkSpec, NetworkModel
from repro.placement.plan import PlacementPlan

_EPS = 1e-6


# ---------------------------------------------------------------------------
# Per-service workload + SLO description
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ServiceSLO:
    """Fig. 3 value curves for one service's fires: full value while the
    end-to-end latency (energy) stays under the soft threshold, decaying
    to zero at the hard threshold."""
    soft_latency_s: float
    hard_latency_s: float
    soft_energy_j: float = 50.0
    hard_energy_j: float = 500.0
    gamma: float = 1.0
    w_p: float = 0.7
    shape: str = "linear"

    def value_spec(self, shift_s: float = 0.0) -> TaskValueSpec:
        """SLO as Eq. 1 parameters; `shift_s` moves the latency curve
        left by the delay already accumulated before DC execution starts,
        so a DC task's (finish − arrival) is scored on the *end-to-end*
        deadline. The shifted soft threshold may go negative: a task
        whose upstream+transfer delay already exceeded the soft deadline
        starts *inside* the decay ramp (clamping it to ~0 would re-spread
        the whole decay over the remaining budget and over-credit slow
        offloads)."""
        soft = self.soft_latency_s - shift_s
        hard = max(self.hard_latency_s - shift_s, soft)
        return TaskValueSpec(
            gamma=self.gamma, w_p=self.w_p, w_e=1.0 - self.w_p,
            perf_curve=ValueCurve(1.0, 0.1, soft, hard, self.shape),
            energy_curve=ValueCurve(1.0, 0.1, self.soft_energy_j,
                                    self.hard_energy_j, self.shape))

    @property
    def max_value(self) -> float:
        return self.gamma * 1.0  # w_p·v_max + w_e·v_max with v_max = 1


@dataclasses.dataclass(frozen=True)
class ServiceProfile:
    """What one fire of this service costs, plus its SLO."""
    slo: ServiceSLO
    flops_per_record: float = 1e3    # operator work per window value
    bytes_per_record: float = 8.0    # working-set bytes per window value


@dataclasses.dataclass
class CoSimConfig:
    edge: EdgeSpec = dataclasses.field(default_factory=EdgeSpec)
    link: LinkSpec = dataclasses.field(default_factory=LinkSpec)
    horizon_s: float = 600.0
    epoch_s: Optional[float] = None   # drive granularity; None -> min slide
    heuristic: str = "hinted"         # "hinted" or a HEURISTICS name
    power_cap_w: Optional[float] = None
    records_per_step: int = 5_000     # records one DC task step consumes
    dc_step_floor_s: float = 1e-3     # VDC kernel launch + ICI sync floor
    mxu_efficiency: float = 0.5
    grid_shape: Tuple[int, int] = (hw.POD_X, hw.POD_Y)


# ---------------------------------------------------------------------------
# Record-conservation ledger
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServiceLedger:
    """Exact per-service record accounting (set partitions, not tallies)."""
    service: str
    queue: str = ""           # input queue (shared queues fan out)
    produced: int = 0         # published into the service's input queue
    overflow: int = 0         # queue capacity drops, never fetched
    unread: int = 0           # still sitting in the queue
    fetched: int = 0
    processed_edge: int = 0   # covered by a fire executed on the edge
    processed_dc: int = 0     # covered by a fire whose DC task completed
    dropped_dc: int = 0       # shipped, but the DC scheduler dropped it
    inflight_dc: int = 0      # shipped, task still pending at the horizon
    buffered: int = 0         # in the service buffer, not yet covered
    evicted_stored: int = 0   # spilled to the post-mortem store (retained)
    evicted_lost: int = 0     # evicted with no store attached

    @property
    def covered(self) -> int:
        return (self.processed_edge + self.processed_dc
                + self.dropped_dc + self.inflight_dc)

    @property
    def in_flight(self) -> int:
        return (self.unread + self.buffered + self.inflight_dc
                + self.evicted_stored)

    @property
    def dropped(self) -> int:
        return self.overflow + self.dropped_dc + self.evicted_lost

    def conserved(self) -> bool:
        return (self.produced == self.overflow + self.unread + self.fetched
                and self.fetched == self.covered + self.buffered
                + self.evicted_stored + self.evicted_lost)


@dataclasses.dataclass
class RecordLedger:
    services: Dict[str, ServiceLedger] = dataclasses.field(default_factory=dict)

    def conserved(self) -> bool:
        return all(s.conserved() for s in self.services.values())

    def totals(self) -> Dict[str, int]:
        """Rolled-up counts. Queue-level keys (produced/overflow/unread)
        are deduplicated per queue so shared queues are not counted once
        per consumer; the remaining keys are per-consumer deliveries and
        may legitimately exceed `produced` when a queue fans out."""
        consumer_keys = ("fetched", "processed_edge", "processed_dc",
                         "dropped_dc", "inflight_dc", "buffered",
                         "evicted_stored", "evicted_lost")
        out = {k: sum(getattr(s, k) for s in self.services.values())
               for k in consumer_keys}
        seen = set()
        for k in ("produced", "overflow", "unread"):
            out[k] = 0
        for s in self.services.values():
            if s.queue in seen:
                continue
            seen.add(s.queue)
            for k in ("produced", "overflow", "unread"):
                out[k] += getattr(s, k)
        return out


class _PublisherContext:
    """Which service's fire is currently publishing (None = a producer
    farm). Lets queue taps attribute each record to its origin, which
    the uplink model needs to tell edge-origin records from results that
    never left the DC."""
    current: Optional[str] = None


class _QueueTap:
    """Instruments one broker queue: identity and origin of every
    published, dropped and per-consumer fetched record."""

    def __init__(self, q, ctx: _PublisherContext):
        self.q = q
        self.pub_refs: List[object] = []
        self.drop_refs: List[object] = []
        self.origin: Dict[int, Optional[str]] = {}
        self.fetched: Dict[str, Dict[int, object]] = {}
        orig_pub, orig_fetch = q.publish, q.fetch

        def publish(rec):
            # detect overflow from the queue's own counter (drop-oldest:
            # the victim is the head snapshotted before the publish)
            oldest = q.buf[0] if q.buf else None
            before = q.dropped
            orig_pub(rec)
            if q.dropped > before:
                self.drop_refs.append(oldest)
            self.pub_refs.append(rec)
            self.origin[id(rec)] = ctx.current

        def fetch(consumer, max_n=1 << 30):
            recs = orig_fetch(consumer, max_n)
            got = self.fetched.setdefault(consumer, {})
            for r in recs:
                got[id(r)] = r
            return recs

        q.publish, q.fetch = publish, fetch


@dataclasses.dataclass
class FireRec:
    """One recorded service fire."""
    ts: float
    n_window: int   # values the operator aggregated (incl. store history)
    n_new: int      # records newly covered by this fire (first coverage)
    # n_new split by origin: None = farm/source, else producing service
    origins: Dict[Optional[str], int] = dataclasses.field(default_factory=dict)


class _ServiceTap:
    """Wraps StreamService.fire to log fires, first-coverage counts and
    per-origin attribution; marks the service as publisher while its
    sinks run."""

    def __init__(self, svc, qtap: _QueueTap, ctx: _PublisherContext):
        self.svc = svc
        self.fires: List[FireRec] = []
        self.covered: Dict[int, object] = {}
        orig_fire = svc.fire

        def fire(now):
            n_new = 0
            origins: Dict[Optional[str], int] = {}
            for r in svc.buffer:
                if id(r) not in self.covered and r.ts < now:
                    self.covered[id(r)] = r
                    n_new += 1
                    o = qtap.origin.get(id(r))
                    origins[o] = origins.get(o, 0) + 1
            prev = ctx.current
            ctx.current = svc.cfg.name
            try:
                res = orig_fire(now)
            finally:
                ctx.current = prev
            self.fires.append(FireRec(ts=now, n_window=res["n"],
                                      n_new=n_new, origins=origins))
            return res

        svc.fire = fire


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CoSimResult:
    plan_label: str
    feasible: bool
    vos: float
    vos_normalized: float
    fires_total: int
    fires_completed: int
    fires_dropped: int       # DC scheduler drops (value decayed to zero)
    fires_inflight: int      # DC tasks the horizon truncated mid-queue
    latency_p50: float
    latency_p95: float
    latency_p99: float
    edge_energy_j: float
    network_energy_j: float
    dc_energy_j: float
    bytes_up: float
    bytes_down: float
    ledger: RecordLedger = dataclasses.field(default_factory=RecordLedger)
    dc: Optional[SimResult] = None
    per_service: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    infeasible_reason: str = ""

    @property
    def energy_total_j(self) -> float:
        return self.edge_energy_j + self.network_energy_j + self.dc_energy_j

    def summary(self) -> Dict:
        """JSON-safe digest for benchmark output (strict RFC 8259: NaN
        percentiles of infeasible/fire-less runs become null)."""
        def _num(x):
            return None if math.isnan(x) or math.isinf(x) else round(x, 4)
        return {
            "plan": self.plan_label,
            "feasible": self.feasible,
            "vos": None if not self.feasible else round(self.vos, 4),
            "vos_normalized": None if not self.feasible
            else round(self.vos_normalized, 4),
            "fires": {"total": self.fires_total,
                      "completed": self.fires_completed,
                      "dropped": self.fires_dropped,
                      "inflight": self.fires_inflight},
            "latency_s": {"p50": _num(self.latency_p50),
                          "p95": _num(self.latency_p95),
                          "p99": _num(self.latency_p99)},
            "energy_j": {"edge": round(self.edge_energy_j, 2),
                         "network": round(self.network_energy_j, 2),
                         "dc": round(self.dc_energy_j, 2)},
            "bytes": {"up": int(self.bytes_up), "down": int(self.bytes_down)},
            "records": self.ledger.totals(),
            "infeasible_reason": self.infeasible_reason,
        }


def _infeasible(plan: PlacementPlan, reason: str) -> CoSimResult:
    return CoSimResult(plan_label=plan.label, feasible=False,
                       vos=float("-inf"), vos_normalized=float("-inf"),
                       fires_total=0, fires_completed=0, fires_dropped=0,
                       fires_inflight=0,
                       latency_p50=float("nan"), latency_p95=float("nan"),
                       latency_p99=float("nan"), edge_energy_j=0.0,
                       network_energy_j=0.0, dc_energy_j=0.0,
                       bytes_up=0.0, bytes_down=0.0,
                       infeasible_reason=reason)


# ---------------------------------------------------------------------------
# DC-side glue: analytics cost cells + hint-honouring heuristic
# ---------------------------------------------------------------------------
def analytics_cost_model(profiles: Dict[str, ServiceProfile],
                         cfg: CoSimConfig) -> CostModel:
    """One roofline cell per service: a DC task step processes
    ``records_per_step`` window values of that service's operator. The
    collective term models the VDC composition / kernel-launch floor, so
    tiny windows don't pretend to finish in nanoseconds."""
    cells = {}
    ref = 256
    for name, prof in profiles.items():
        r = cfg.records_per_step
        t_c = (r * prof.flops_per_record
               / (ref * hw.PEAK_FLOPS_BF16 * cfg.mxu_efficiency))
        t_m = r * prof.bytes_per_record / (ref * hw.HBM_BW)
        cells[(f"svc:{name}", "window")] = CellCost(
            t_c, t_m, cfg.dc_step_floor_s, r * prof.bytes_per_record)
    return CostModel(cells)


class HintedVPTR(VPTRHeuristic):
    """VPTR that honours the placement plan's per-task DVFS hint."""
    name = "VPTR-hint"
    can_scale_f = True

    def _freqs(self, task, headroom_fn):
        return (getattr(task, "dvfs_hint", 1.0),)


def _fresh_heuristic(name: str):
    if name == "hinted":
        return HintedVPTR()
    return type(HEURISTICS[name])()


# ---------------------------------------------------------------------------
# Fire-level timing graph
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Fire:
    svc: str
    idx: int
    ts: float
    n_window: int
    n_new: int
    site: str = "edge"
    origins: Dict[Optional[str], int] = dataclasses.field(default_factory=dict)
    ready_out: Optional[float] = None   # result availability (None = never)
    start: float = 0.0
    energy_j: float = 0.0
    value: float = 0.0
    dropped: bool = False    # DC scheduler dropped the task (value decayed)
    pending: bool = False    # task still queued/running at the horizon


def _topo_order(topology: Dict[str, List[str]],
                insertion: Sequence[str]) -> List[str]:
    """Kahn's algorithm, stable w.r.t. pipeline insertion order."""
    for n, ups in topology.items():
        for u in ups:
            if u not in topology:
                raise ValueError(
                    f"upstream {u!r} of {n!r} was connect()ed but never "
                    "add_service()d to the pipeline")
    indeg = {n: len(ups) for n, ups in topology.items()}
    order, ready = [], [n for n in insertion if indeg[n] == 0]
    while ready:
        n = ready.pop(0)
        order.append(n)
        for m in insertion:
            if n in topology[m]:
                indeg[m] -= topology[m].count(n)
                if indeg[m] == 0 and m not in order and m not in ready:
                    ready.append(m)
    if len(order) != len(topology):
        raise ValueError("pipeline topology has a cycle")
    return order


class CoSimulator:
    """Evaluates placement plans for one pipeline scenario.

    ``build`` must return a *fresh* Pipeline (broker, farms, services,
    connections) on every call. The functional dataflow is driven once
    and cached — it does not depend on the plan — so a search over many
    plans only pays the per-plan timing/DC simulation. Mutating ``cfg``
    fields that shape the dataflow (``horizon_s``, ``epoch_s``) or the
    DC cost cells (``records_per_step``, ``dc_step_floor_s``,
    ``mxu_efficiency``) after construction requires a new CoSimulator;
    edge/link/heuristic fields may be swapped between runs.
    """

    def __init__(self, build: Callable[[], Pipeline],
                 profiles: Dict[str, ServiceProfile],
                 cfg: Optional[CoSimConfig] = None):
        self.build = build
        self.profiles = dict(profiles)
        self.cfg = cfg or CoSimConfig()
        pipe = build()
        self.topology = pipe.topology()
        self.service_names = [s.cfg.name for s in pipe.services]
        if len(set(self.service_names)) != len(self.service_names):
            raise ValueError("duplicate service names in pipeline: "
                             f"{self.service_names} — co-sim accounting is "
                             "keyed by name")
        missing = set(self.topology) - set(self.profiles)
        if missing:
            raise ValueError(f"no ServiceProfile for {sorted(missing)}")
        # plan-independent state, computed once (snapshot the cfg fields
        # the cost cells bake in, so later cfg mutation can't desync the
        # step count from the per-step time model)
        self.order = _topo_order(self.topology, self.service_names)
        self.rank = {s: i for i, s in enumerate(self.order)}
        self.cost = analytics_cost_model(self.profiles, self.cfg)
        self._records_per_step = self.cfg.records_per_step
        # The functional dataflow is plan-independent, so it is driven
        # once (lazily, reusing the pipeline built above) and shared
        # across every plan evaluation; only the timing/placement state
        # is rebuilt per run().
        self._fresh_pipe: Optional[Pipeline] = pipe
        self._driven: Optional[Tuple[Pipeline, Dict[str, _ServiceTap],
                                     Dict[str, _QueueTap]]] = None

    def _ensure_driven(self) -> Tuple[Pipeline, Dict[str, "_ServiceTap"],
                                      Dict[str, "_QueueTap"]]:
        if self._driven is None:
            pipe, self._fresh_pipe = self._fresh_pipe or self.build(), None
            staps, qtaps = self._drive(pipe)
            self._driven = (pipe, staps, qtaps)
        return self._driven

    # -------------------------------------------------------------- driving
    def _drive(self, pipe: Pipeline
               ) -> Tuple[Dict[str, _ServiceTap], Dict[str, _QueueTap]]:
        cfg = self.cfg
        ctx = _PublisherContext()
        qtaps: Dict[int, _QueueTap] = {}
        for s in pipe.services:
            if id(s.q) not in qtaps:
                qtaps[id(s.q)] = _QueueTap(s.q, ctx)
        staps = {s.cfg.name: _ServiceTap(s, qtaps[id(s.q)], ctx)
                 for s in pipe.services}
        by_service = {s.cfg.name: qtaps[id(s.q)] for s in pipe.services}
        epoch = cfg.epoch_s or min(s.cfg.window.slide_s for s in pipe.services)
        t, horizon = 0.0, cfg.horizon_s
        while t < horizon - _EPS:
            t = min(t + epoch, horizon)
            pipe.advance_to(t)
        return staps, by_service

    # ------------------------------------------------------------- plumbing
    def _edge_ram_needed(self, pipe: Pipeline, plan: PlacementPlan) -> float:
        return self.cfg.edge.ram_required(
            sum(s.cfg.buffer_budget for s in pipe.services
                if plan.is_edge(s.cfg.name)))

    @staticmethod
    def _uplink_records(plan: PlacementPlan, f: "_Fire") -> int:
        """Records a DC-placed fire must ship edge→DC: exactly the newly
        covered records of edge origin (farm records and results of
        edge-placed upstreams); results a DC-placed upstream produced
        never left the DC."""
        return sum(c for o, c in f.origins.items()
                   if o is None or plan.is_edge(o))

    # ---------------------------------------------------------- timing pass
    def _timing_pass(self, plan: PlacementPlan,
                     fires: Dict[str, List[_Fire]],
                     dc_ready: Optional[Dict[Tuple[str, int],
                                             Tuple[str, Optional[float]]]],
                     ) -> Tuple[EdgeNode, NetworkModel, List[Task],
                                Dict[int, Tuple[str, int]]]:
        """One pass over the fire DAG in readiness order.

        With ``dc_ready is None`` (pass 1) DC fires resolve to optimistic
        completion estimates and the DC task trace is collected; with the
        post-simulation status map (pass 2) they resolve to actual
        completions — ("done", finish) | ("dropped", None) for scheduler
        drops | ("pending", None) for tasks the horizon truncated."""
        cfg = self.cfg
        rank, cost = self.rank, self.cost
        edge = EdgeNode(cfg.edge)
        net = NetworkModel(cfg.link)
        tasks: List[Task] = []
        tid_map: Dict[int, Tuple[str, int]] = {}
        ts_lists = {s: [f.ts for f in fl] for s, fl in fires.items()}
        done: Dict[str, int] = {s: 0 for s in fires}   # resolved prefix len
        # pmax[s][j] = max finite ready_out over the resolved prefix
        # fires[s][:j+1] — lets _dep_ready answer prefix-max queries in
        # O(1) instead of rescanning every upstream fire (O(F²) overall)
        pmax: Dict[str, List[float]] = {s: [] for s in fires}
        pending_edge: List[Tuple[float, float, int, str, int]] = []
        n_total = sum(len(fl) for fl in fires.values())
        n_done = 0
        dl_time = net.downlink_time(1)
        neg_inf = float("-inf")

        def _mark_done(svc: str, f: "_Fire") -> None:
            nonlocal n_done
            prev = pmax[svc][-1] if pmax[svc] else neg_inf
            val = f.ready_out if f.ready_out is not None else neg_inf
            pmax[svc].append(max(prev, val))
            done[svc] += 1
            n_done += 1

        def _dep_ready(svc: str, ts: float) -> Optional[float]:
            """Readiness contribution of the upstreams of a fire at `ts`:
            the fire's window aggregates every upstream result produced
            strictly before `ts`, so it waits for ALL of them to arrive
            (a straggler result finishing late gates the fire even when a
            newer one is already in). A DC upstream's result reaches an
            edge-placed consumer one downlink later; a DC→DC handoff pays
            no hop. Dropped upstream fires contribute nothing — their
            value loss is charged upstream. None while some upstream fire
            strictly before `ts` is still unresolved."""
            t = ts
            edge_here = plan.is_edge(svc)
            for u in self.topology[svc]:
                k = bisect.bisect_left(ts_lists[u], ts)
                if done[u] < k:
                    return None
                if k and pmax[u][k - 1] != neg_inf:
                    hop = (dl_time if edge_here and not plan.is_edge(u)
                           else 0.0)
                    t = max(t, pmax[u][k - 1] + hop)
            return t

        def _resolve_ready() -> None:
            """Resolve every fire whose dependencies are settled: DC fires
            immediately, edge fires into the device queue."""
            nonlocal n_done
            progress = True
            while progress:
                progress = False
                for svc in fires:
                    i = done[svc]
                    while i < len(fires[svc]):
                        f = fires[svc][i]
                        if f.site == "edge" and any(
                                p[3] == svc and p[4] == i
                                for p in pending_edge):
                            break  # queued on the device, not finished
                        in_ready = _dep_ready(svc, f.ts)
                        if in_ready is None:
                            break
                        f.start = in_ready
                        if f.site == "edge":
                            pending_edge.append(
                                (in_ready, f.ts, rank[svc], svc, i))
                            break
                        # ---- DC fire ----
                        # ship only edge-origin records over the uplink
                        n_ship = self._uplink_records(plan, f)
                        xfer = net.uplink(n_ship) if n_ship else 0.0
                        arrival = in_ready + xfer
                        if dc_ready is None:
                            # SLO scored on the user-visible result, which
                            # surfaces edge-side one downlink after finish
                            shift = (arrival - f.ts) + dl_time
                            p = plan.placement(svc)
                            prof = self.profiles[svc]
                            steps = max(1, math.ceil(
                                f.n_window / self._records_per_step))
                            tt = TaskType(f"svc:{svc}", "window",
                                          allowable_chips=(p.chips,))
                            task = Task(tid=len(tasks), ttype=tt, steps=steps,
                                        arrival=arrival,
                                        value=prof.slo.value_spec(shift),
                                        hbm_bytes=cost.hbm_bytes(
                                            f"svc:{svc}", "window"))
                            task.dvfs_hint = p.dvfs_f
                            tid_map[task.tid] = (svc, i)
                            tasks.append(task)
                            est = steps * cost.time_per_step(
                                f"svc:{svc}", "window", p.chips, p.dvfs_f)
                            f.ready_out = arrival + est
                        else:
                            status, r = dc_ready.get((svc, i),
                                                     ("pending", None))
                            if status == "done":
                                # ready_out is the in-DC completion; the
                                # edge-surfacing downlink is charged here
                                # and added at edge consumers / scoring
                                f.ready_out = r
                                net.downlink(1)
                            else:           # no result ever arrives
                                f.ready_out = None
                                f.dropped = status == "dropped"
                                f.pending = status == "pending"
                        _mark_done(svc, f)
                        i = done[svc]
                        progress = True

        _resolve_ready()
        while n_done < n_total or pending_edge:
            if not pending_edge:
                raise RuntimeError("co-sim deadlock: unresolved fires with "
                                   "an idle edge device")
            pending_edge.sort()
            in_ready, _, _, svc, i = pending_edge.pop(0)
            f = fires[svc][i]
            prof = self.profiles[svc]
            ex = edge.execute_fire(in_ready, f.n_window,
                                   prof.flops_per_record)
            f.start, f.ready_out, f.energy_j = ex.start, ex.finish, ex.energy_j
            _mark_done(svc, f)
            _resolve_ready()
        return edge, net, tasks, tid_map

    # ------------------------------------------------------------------ run
    def run(self, plan: PlacementPlan) -> CoSimResult:
        cfg = self.cfg
        plan.validate(self.topology,
                      grid_chips=cfg.grid_shape[0] * cfg.grid_shape[1])
        pipe, staps, qtaps = self._ensure_driven()
        ram = self._edge_ram_needed(pipe, plan)
        if ram > cfg.edge.ram_bytes:
            return _infeasible(
                plan, f"edge RAM: need {ram/2**20:.0f} MiB buffer budget, "
                      f"device has {cfg.edge.ram_bytes/2**20:.0f} MiB")
        order, cost = self.order, self.cost
        fires = {s: [_Fire(svc=s, idx=i, ts=fr.ts, n_window=fr.n_window,
                           n_new=fr.n_new, site=plan.site(s),
                           origins=fr.origins)
                     for i, fr in enumerate(staps[s].fires)]
                 for s in order}

        # pass 1: optimistic DC handoffs → task trace
        _, _, tasks, tid_map = self._timing_pass(plan, fires, dc_ready=None)
        for fl in fires.values():       # reset fire state between passes
            for f in fl:
                f.ready_out, f.start, f.energy_j = None, 0.0, 0.0
                f.dropped = f.pending = False

        sim_result: Optional[SimResult] = None
        dc_ready: Dict[Tuple[str, int], Tuple[str, Optional[float]]] = {}
        if tasks:
            grid = PodGrid(*cfg.grid_shape)
            sim = Simulator(_fresh_heuristic(cfg.heuristic), cost,
                            power_cap_w=cfg.power_cap_w, grid=grid)
            trace = sorted(tasks, key=lambda t: (t.arrival, t.tid))
            sim_result = sim.run(trace)
            for t in trace:
                key = tid_map[t.tid]
                if t.finish is not None and not t.dropped:
                    dc_ready[key] = ("done", t.finish)
                elif t.dropped:
                    dc_ready[key] = ("dropped", None)
                else:
                    # still pending when the event loop drained: a task
                    # whose value is already zero under its own hinted
                    # config will never run (the simulator's drop check
                    # is optimistic, f=1.0) — that is a drop, not a
                    # horizon truncation
                    chips = t.ttype.allowable_chips[0]
                    f_hint = getattr(t, "dvfs_hint", 1.0)
                    dur = t.steps * cost.time_per_step(
                        t.ttype.arch, t.ttype.shape, chips, f_hint)
                    energy = t.steps * cost.energy_per_step(
                        t.ttype.arch, t.ttype.shape, chips, f_hint)
                    latency = (sim_result.makespan - t.arrival) + dur
                    v = task_value(t.value, latency, energy)
                    dc_ready[key] = (("pending", None) if v > 0
                                     else ("dropped", None))

        # pass 2: actual DC completions → final latencies & energy split
        edge, net, _, _ = self._timing_pass(plan, fires, dc_ready=dc_ready)
        dl_time = net.downlink_time(1)   # DC results surface edge-side

        # ---- score fires -------------------------------------------------
        vos = 0.0
        max_vos = 0.0
        latencies: List[float] = []
        completed = dropped = inflight = 0
        per_service: Dict[str, Dict] = {}
        task_by_key = {tid_map[t.tid]: t for t in tasks}
        for svc in order:
            prof = self.profiles[svc]
            spec = prof.slo.value_spec()
            s_lat: List[float] = []
            s_done = s_drop = s_wait = 0
            for f in fires[svc]:
                max_vos += prof.slo.max_value
                if f.site == "edge":
                    lat = f.ready_out - f.ts
                    f.value = task_value(spec, lat, f.energy_j)
                    s_done += 1
                    s_lat.append(lat)
                elif f.dropped:
                    f.value = 0.0
                    s_drop += 1
                elif f.pending:
                    f.value = 0.0
                    s_wait += 1
                else:
                    f.value = task_by_key[(svc, f.idx)].earned
                    s_done += 1
                    s_lat.append(f.ready_out + dl_time - f.ts)
            s_vos = sum(f.value for f in fires[svc])
            vos += s_vos
            completed += s_done
            dropped += s_drop
            inflight += s_wait
            latencies.extend(s_lat)
            per_service[svc] = {
                "site": plan.placement(svc).label,
                "fires": len(fires[svc]), "completed": s_done,
                "dropped": s_drop, "inflight": s_wait,
                "vos": round(s_vos, 4),
                "latency_p95": round(float(np.percentile(s_lat, 95)), 4)
                if s_lat else float("nan"),
            }

        ledger = self._ledger(pipe, plan, staps, qtaps, fires)
        lat = np.asarray(latencies) if latencies else np.asarray([float("nan")])
        dc_energy = sim_result.total_energy_j if sim_result else 0.0
        return CoSimResult(
            plan_label=plan.label, feasible=True, vos=vos,
            vos_normalized=vos / max(max_vos, _EPS),
            fires_total=sum(len(fl) for fl in fires.values()),
            fires_completed=completed, fires_dropped=dropped,
            fires_inflight=inflight,
            latency_p50=float(np.percentile(lat, 50)),
            latency_p95=float(np.percentile(lat, 95)),
            latency_p99=float(np.percentile(lat, 99)),
            edge_energy_j=edge.energy_j, network_energy_j=net.energy_j,
            dc_energy_j=dc_energy, bytes_up=net.bytes_up,
            bytes_down=net.bytes_down, ledger=ledger, dc=sim_result,
            per_service=per_service)

    # ----------------------------------------------------------- accounting
    def _ledger(self, pipe: Pipeline, plan: PlacementPlan,
                staps: Dict[str, "_ServiceTap"],
                qtaps: Dict[str, "_QueueTap"],
                fires: Dict[str, List[_Fire]]) -> RecordLedger:
        ledger = RecordLedger()
        for svc_obj in pipe.services:
            name = svc_obj.cfg.name
            tap, qtap = staps[name], qtaps[name]
            fetched = qtap.fetched.get(name, {})
            covered = tap.covered
            buf_ids = {id(r) for r in svc_obj.buffer}
            drop_ids = {id(r) for r in qtap.drop_refs}
            sl = ServiceLedger(service=name, queue=svc_obj.cfg.queue)
            sl.produced = len(qtap.pub_refs)
            sl.overflow = len(drop_ids - set(fetched))
            sl.unread = sum(1 for r in svc_obj.q.buf if id(r) not in fetched)
            sl.fetched = len(fetched)
            sl.buffered = len(buf_ids - set(covered))
            evicted_unc = set(fetched) - buf_ids - set(covered)
            if svc_obj.cfg.store is not None:
                sl.evicted_stored = len(evicted_unc)
            else:
                sl.evicted_lost = len(evicted_unc)
            # split covered records by fire outcome
            for f in fires[name]:
                if f.site == "edge":
                    sl.processed_edge += f.n_new
                elif f.dropped:
                    sl.dropped_dc += f.n_new
                elif f.pending:         # never finished before the horizon
                    sl.inflight_dc += f.n_new
                else:
                    sl.processed_dc += f.n_new
            ledger.services[name] = sl
        return ledger
