"""DEPRECATED shim — the single-site co-simulator now runs on the
unified DES-bridged engine (``repro.scenario.engine``).

This module used to implement a *two-pass* timing scheme: pass 1
collected the DC task trace with optimistic completion estimates for
DC→DC handoffs, pass 2 re-ran the timing with the simulated completion
times. That estimation path is retired: :class:`CoSimulator` below is a
thin adapter that submits every DC-placed fire *incrementally* into one
persistent JITA-4DS :class:`~repro.core.simulator.Simulator` via
:class:`~repro.scenario.engine.ScenarioEngine` — completions, scheduler
drops, VDC composition pressure and power-cap contention are
co-simulated, never estimated, for the single-gateway case exactly as
for multi-site fleets.

New code should use the Scenario API directly::

    from repro.scenario import scenario, ScenarioSpec
    engine = spec.compile()
    result = engine.run_plan(plan)        # == CoSimulator(...).run(plan)

Everything re-exported here (`ServiceSLO`, `ServiceProfile`, the
ledgers, `analytics_cost_model`, `CoSimResult`) lives in
``repro.scenario`` now; the names remain importable from this module for
backward compatibility.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, Optional, Tuple

from repro import hardware as hw
from repro.pipeline.composition import Pipeline
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.plan import PlacementPlan
from repro.scenario.engine import (CoSimResult, EngineConfig,  # noqa: F401
                                   HintedVPTR, ScenarioEngine,
                                   _fresh_heuristic, _infeasible,
                                   analytics_cost_model, single_site_fleet)
from repro.scenario.ledger import (FireRec, RecordLedger,  # noqa: F401
                                   ServiceLedger, _PublisherContext,
                                   _QueueTap, _ServiceTap, _topo_order)
from repro.scenario.profiles import ServiceProfile, ServiceSLO  # noqa: F401

_EPS = 1e-6


@dataclasses.dataclass
class CoSimConfig:
    """Single-gateway engine knobs (legacy surface). ``epoch_s`` here is
    the *drive* granularity of the functional dataflow — the whole
    horizon is always one placement epoch."""
    edge: EdgeSpec = dataclasses.field(default_factory=EdgeSpec)
    link: LinkSpec = dataclasses.field(default_factory=LinkSpec)
    horizon_s: float = 600.0
    epoch_s: Optional[float] = None   # drive granularity; None -> min slide
    heuristic: str = "hinted"         # "hinted" or a HEURISTICS name
    power_cap_w: Optional[float] = None
    records_per_step: int = 5_000     # records one DC task step consumes
    dc_step_floor_s: float = 1e-3     # VDC kernel launch + ICI sync floor
    mxu_efficiency: float = 0.5
    grid_shape: Tuple[int, int] = (hw.POD_X, hw.POD_Y)


class CoSimulator:
    """DEPRECATED: evaluates placement plans for one single-gateway
    pipeline scenario by delegating to the unified
    :class:`~repro.scenario.engine.ScenarioEngine`.

    ``build`` must return a *fresh* Pipeline (broker, farms, services,
    connections) on every call. The functional dataflow is driven once
    and cached — it does not depend on the plan — so a search over many
    plans only pays the per-plan timing/DC simulation. Mutating ``cfg``
    fields that shape the dataflow (``horizon_s``, ``epoch_s``) or the
    DC cost cells (``records_per_step``, ``dc_step_floor_s``,
    ``mxu_efficiency``) after construction requires a new CoSimulator;
    edge/link/heuristic fields may be swapped between runs.
    """

    # cfg fields baked into the cached drive / cost cells at construction
    _FROZEN = ("horizon_s", "epoch_s", "records_per_step",
               "dc_step_floor_s", "mxu_efficiency")

    def __init__(self, build: Callable[[], Pipeline],
                 profiles: Dict[str, ServiceProfile],
                 cfg: Optional[CoSimConfig] = None):
        warnings.warn(
            "repro.placement.cosim.CoSimulator is deprecated and will be "
            "removed in v0.9 (2026-12-01); use the Scenario API instead: "
            "spec.compile().run_plan(plan) (see README, Migration table)",
            DeprecationWarning, stacklevel=2)
        self.build = build
        self.profiles = dict(profiles)
        self.cfg = cfg or CoSimConfig()
        self._frozen = {k: getattr(self.cfg, k) for k in self._FROZEN}
        self._engine = ScenarioEngine(build, self.profiles,
                                      self._engine_config())
        self.topology = self._engine.topology
        self.service_names = list(self._engine.order)
        self.order = self._engine.order
        self.rank = self._engine.rank
        self.cost = self._engine.cost

    def _engine_config(self) -> EngineConfig:
        cfg = self.cfg
        return EngineConfig(
            fleet=single_site_fleet(cfg.edge, cfg.link),
            horizon_s=cfg.horizon_s, epoch_s=None,
            drive_step_s=cfg.epoch_s, heuristic=cfg.heuristic,
            power_cap_w=cfg.power_cap_w,
            records_per_step=cfg.records_per_step,
            dc_step_floor_s=cfg.dc_step_floor_s,
            mxu_efficiency=cfg.mxu_efficiency, grid_shape=cfg.grid_shape)

    def _sync_engine(self) -> ScenarioEngine:
        """Refresh the swappable cfg fields (edge/link/heuristic/power
        cap) on the long-lived engine; the cached functional drive and
        cost cells are untouched — they don't depend on them. Mutating a
        drive/cost-shaping field after construction fails loudly instead
        of silently simulating the stale value."""
        stale = {k: (self._frozen[k], getattr(self.cfg, k))
                 for k in self._FROZEN
                 if getattr(self.cfg, k) != self._frozen[k]}
        if stale:
            raise ValueError(
                "CoSimulator cfg fields baked in at construction were "
                f"mutated (old -> new): {stale}; build a new CoSimulator "
                "(or use the Scenario API: dataclasses.replace(spec, ...)"
                ".compile())")
        e = self._engine
        ecfg = e.cfg
        ecfg.fleet = single_site_fleet(self.cfg.edge, self.cfg.link)
        ecfg.heuristic = self.cfg.heuristic
        ecfg.power_cap_w = self.cfg.power_cap_w
        ecfg.grid_shape = self.cfg.grid_shape
        return e

    def run(self, plan: PlacementPlan) -> CoSimResult:
        return self._sync_engine().run_plan(plan)
