"""SLO-optimal placement search.

Maximizes the Eq. 2 VoS reported by the co-simulator over per-service
edge|dc assignments (plus the DC chips/DVFS hints), subject to the
constraints the co-simulator enforces (edge RAM, DC power cap —
infeasible plans score −inf).

Small plan spaces are searched exhaustively; larger ones fall back to a
greedy descent from the better of the all-edge / all-DC anchors,
polished with seeded random-restart hill climbing. All evaluations are
memoized on the plan's canonical key, and every step is deterministic
for a fixed seed.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Tuple

from repro.placement.cosim import CoSimResult, CoSimulator
from repro.placement.plan import (PlacementPlan, ServicePlacement, SITE_EDGE,
                                  enumerate_plans, service_options)


@dataclasses.dataclass
class SearchResult:
    plan: PlacementPlan
    result: CoSimResult
    method: str
    evaluations: int
    history: List[Tuple[str, float]]   # (plan label, vos) in eval order


class Evaluator:
    """Memoized plan evaluation; share one instance between baseline
    runs and a search to avoid re-co-simulating identical plans.

    Accepts anything that quacks like a plan scorer: the unified
    :class:`~repro.scenario.engine.ScenarioEngine` (via ``run_plan``),
    the deprecated ``CoSimulator`` shim, or an analytic stand-in like
    the online controller's ``ForecastModel`` (via ``run``)."""

    def __init__(self, cosim: CoSimulator):
        self.cosim = cosim
        self._run = getattr(cosim, "run_plan", None) or cosim.run
        self.cache: Dict[Tuple, CoSimResult] = {}
        self.history: List[Tuple[str, float]] = []

    def __call__(self, plan: PlacementPlan) -> CoSimResult:
        key = plan.key()
        if key not in self.cache:
            res = self._run(plan)
            self.cache[key] = res
            self.history.append((plan.label, res.vos))
        return self.cache[key]

    @property
    def evaluations(self) -> int:
        return len(self.cache)


def _score(res: CoSimResult) -> float:
    return res.vos if res.feasible else float("-inf")


def exhaustive_search(cosim: CoSimulator,
                      chips_options: Sequence[int] = (4, 8, 16),
                      dvfs_options: Sequence[float] = (1.0,),
                      evaluator: Optional[Evaluator] = None,
                      edge_sites: Sequence[str] = (SITE_EDGE,),
                      ) -> SearchResult:
    ev = evaluator or Evaluator(cosim)
    names = list(cosim.topology)
    best_plan: Optional[PlacementPlan] = None
    best: Optional[CoSimResult] = None
    for plan in enumerate_plans(names, chips_options, dvfs_options,
                                edge_sites):
        res = ev(plan)
        if best is None or _score(res) > _score(best):
            best_plan, best = plan, res
    assert best_plan is not None and best is not None
    return SearchResult(best_plan, best, "exhaustive", ev.evaluations,
                        ev.history)


def _greedy(ev: Evaluator, start: PlacementPlan,
            options: List[ServicePlacement]) -> PlacementPlan:
    """First-improvement single-service descent: sweep the services,
    accept any improving move immediately, repeat until a full sweep
    finds none (a local optimum of the single-flip neighborhood)."""
    current, score = start, _score(ev(start))
    improved = True
    while improved:
        improved = False
        for name in sorted(current.assignments):
            for opt in options:
                if opt == current.assignments[name]:
                    continue
                cand = current.with_placement(name, opt)
                s = _score(ev(cand))
                if s > score:
                    current, score = cand, s
                    improved = True
    return current


def _hill_climb(ev: Evaluator, start: PlacementPlan,
                options: List[ServicePlacement], rng: random.Random,
                iters: int) -> PlacementPlan:
    """Seeded stochastic single-flip climb (escapes plateau ties)."""
    names = sorted(start.assignments)
    current, score = start, _score(ev(start))
    for _ in range(iters):
        name = rng.choice(names)
        opt = rng.choice(options)
        if opt == current.assignments[name]:
            continue
        cand = current.with_placement(name, opt)
        s = _score(ev(cand))
        # accept improvements and sideways moves (plateau escape); cand
        # always differs from current (identity options are skipped above)
        if s >= score:
            current, score = cand, s
    return current


def greedy_search(cosim: CoSimulator,
                  chips_options: Sequence[int] = (4, 8, 16),
                  dvfs_options: Sequence[float] = (1.0,),
                  seed: int = 0, restarts: int = 2,
                  climb_iters: int = 64,
                  evaluator: Optional[Evaluator] = None,
                  edge_sites: Sequence[str] = (SITE_EDGE,)) -> SearchResult:
    ev = evaluator or Evaluator(cosim)
    names = list(cosim.topology)
    options = service_options(chips_options, dvfs_options, edge_sites)
    rng = random.Random(seed)

    anchors = [PlacementPlan.all_edge(names, site=s) for s in edge_sites]
    for c in chips_options:
        anchors.append(PlacementPlan.all_dc(names, chips=c,
                                            dvfs_f=dvfs_options[0]))
    for _ in range(restarts):
        anchors.append(PlacementPlan(
            {n: rng.choice(options) for n in names}))

    best_plan: Optional[PlacementPlan] = None
    for anchor in anchors:
        local = _greedy(ev, anchor, options)
        local = _hill_climb(ev, local, options, rng, climb_iters)
        if best_plan is None or _score(ev(local)) > _score(ev(best_plan)):
            best_plan = local
    assert best_plan is not None
    return SearchResult(best_plan, ev(best_plan), "greedy+hillclimb",
                        ev.evaluations, ev.history)


def search_placement(cosim: CoSimulator,
                     chips_options: Sequence[int] = (4, 8, 16),
                     dvfs_options: Sequence[float] = (1.0,),
                     exhaustive_limit: int = 1024,
                     seed: int = 0,
                     evaluator: Optional[Evaluator] = None,
                     edge_sites: Sequence[str] = (SITE_EDGE,)) -> SearchResult:
    """Front door: exhaustive when the plan space fits under
    `exhaustive_limit` evaluations, greedy + hill-climb otherwise.
    ``edge_sites`` widens the per-service choice set to a multi-gateway
    fleet; the evaluator must understand those site names (the online
    controller's forecast model does)."""
    n_opts = len(edge_sites) + len(chips_options) * len(dvfs_options)
    space = n_opts ** len(cosim.topology)
    if space <= exhaustive_limit:
        return exhaustive_search(cosim, chips_options, dvfs_options,
                                 evaluator=evaluator, edge_sites=edge_sites)
    return greedy_search(cosim, chips_options, dvfs_options, seed=seed,
                         evaluator=evaluator, edge_sites=edge_sites)
