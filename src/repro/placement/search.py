"""SLO-optimal placement search.

Maximizes the Eq. 2 VoS reported by the co-simulator over per-service
edge|dc assignments (plus the DC chips/DVFS hints), subject to the
constraints the co-simulator enforces (edge RAM, DC power cap —
infeasible plans score −inf).

Two evaluation tiers:

  * **Screened** (the fast path, used whenever the scorer exposes a
    ``screening_model`` — i.e. the unified ``ScenarioEngine``): the
    whole candidate space (or a seeded sample + vectorized hill climb
    for fleet-scale spaces) is scored in batched numpy passes by
    :class:`repro.scenario.screen.ScreeningModel`; the exact DES replay
    runs only on the top-K screened survivors plus the anchor plans, so
    a search pays a handful of co-simulations instead of hundreds.
  * **Exact** (the legacy path, and the only one for analytic scorers
    like the online controller's ``ForecastModel``): small plan spaces
    exhaustively, larger ones greedy descent from the all-edge / all-DC
    anchors polished with seeded random-restart hill climbing.

All exact evaluations are memoized on the plan's canonical key, and
every step — screening included — is deterministic for a fixed seed.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.placement.cosim import CoSimResult, CoSimulator
from repro.placement.plan import (PlacementPlan, ServicePlacement, SITE_EDGE,
                                  enumerate_plans, service_options)


@dataclasses.dataclass
class SearchResult:
    plan: PlacementPlan
    result: CoSimResult
    method: str
    evaluations: int                   # fresh exact co-sims THIS search ran
    history: List[Tuple[str, float]]   # (plan label, vos) in eval order
    screen: Optional[Dict] = None      # tier-1 screening stats (if used)
    cache_hits: int = 0                # evaluator cache hits during search
    cache_misses: int = 0              # fresh exact runs during search

    def stats(self) -> Dict:
        """JSON-safe digest for benchmark reports."""
        out = {"method": self.method, "evaluations": self.evaluations,
               "cache_hits": self.cache_hits,
               "cache_misses": self.cache_misses}
        if self.screen is not None:
            out["screen"] = dict(self.screen)
        return out


class Evaluator:
    """Memoized plan evaluation; share one instance between baseline
    runs and a search to avoid re-co-simulating identical plans.

    Accepts anything that quacks like a plan scorer: the unified
    :class:`~repro.scenario.engine.ScenarioEngine` (via ``run_plan``),
    the deprecated ``CoSimulator`` shim, or an analytic stand-in like
    the online controller's ``ForecastModel`` (via ``run``).

    Counters: ``hits`` / ``misses`` split cached from fresh exact runs
    (``evaluations`` alone used to conflate them); ``screened`` counts
    plans scored by the tier-1 vectorized screen (never co-simulated
    unless they survive into the top-K).

    ``cache`` lets callers share one memo dict across evaluators (the
    online controller keeps a single cross-epoch cache); ``key_prefix``
    namespaces its entries by scorer identity — a ``ForecastModel``
    changes with every epoch's rate estimate, so a shared cache keyed
    on the plan alone would serve stale scores from a different
    model."""

    def __init__(self, cosim: CoSimulator, screener=None,
                 cache: Optional[Dict[Tuple, CoSimResult]] = None,
                 key_prefix: Optional[Tuple] = None):
        self.cosim = cosim
        self._run = getattr(cosim, "run_plan", None) or cosim.run
        self.cache: Dict[Tuple, CoSimResult] = (cache if cache is not None
                                                else {})
        self._prefix = key_prefix
        self.history: List[Tuple[str, float]] = []
        self.hits = 0
        self.misses = 0
        self.screened = 0
        self._screener = screener

    def _key(self, plan: PlacementPlan) -> Tuple:
        k = plan.key()
        return (self._prefix, k) if self._prefix is not None else k

    def __call__(self, plan: PlacementPlan) -> CoSimResult:
        key = self._key(plan)
        if key not in self.cache:
            self.misses += 1
            res = self._run(plan)
            self.cache[key] = res
            self.history.append((plan.label, res.vos))
        else:
            self.hits += 1
        return self.cache[key]

    def evaluate_batch(self, plans: Sequence[PlacementPlan]
                       ) -> List[CoSimResult]:
        """Evaluate many plans; results in submission order. The base
        evaluator runs them serially — :class:`~repro.placement.
        parallel.ParallelEvaluator` overrides this to fan uncached
        plans across a process pool while keeping cache, history and
        counters bit-identical to this loop."""
        return [self(p) for p in plans]

    @property
    def screener(self):
        """Tier-1 batch screener, if the scorer can build one."""
        if self._screener is None:
            make = getattr(self.cosim, "screening_model", None)
            if make is not None:
                self._screener = make()
        return self._screener

    def screen_batch(self, plans: Sequence[PlacementPlan]) -> np.ndarray:
        s = self.screener
        if s is None:
            raise ValueError(f"{type(self.cosim).__name__} has no "
                             "screening model")
        self.screened += len(plans)
        return s.score_batch(plans)

    def screen_matrix(self, P: np.ndarray, options) -> np.ndarray:
        """Index-matrix twin of :meth:`screen_batch` (what the sampled
        hill-climbing search uses); same counter, same screener."""
        s = self.screener
        if s is None:
            raise ValueError(f"{type(self.cosim).__name__} has no "
                             "screening model")
        self.screened += len(P)
        return s.score_matrix(P, options)

    def screen_block(self, P: np.ndarray, cols: Sequence[int],
                     options) -> np.ndarray:
        """Delta-aware twin of :meth:`screen_matrix` for block-
        coordinate batches where only ``cols`` vary across rows (the
        decomposed region search). Bit-identical scores; falls back to
        the dense pass on screeners without ``score_block`` or when the
        block does not decompose cleanly."""
        s = self.screener
        if s is None:
            raise ValueError(f"{type(self.cosim).__name__} has no "
                             "screening model")
        self.screened += len(P)
        block = getattr(s, "score_block", None)
        if block is None:
            return s.score_matrix(P, options)
        return block(P, cols, options)

    def stats(self) -> Dict:
        return {"evaluations": self.evaluations, "cache_hits": self.hits,
                "cache_misses": self.misses, "screened": self.screened}

    @property
    def evaluations(self) -> int:
        return len(self.cache)


def _score(res: CoSimResult) -> float:
    return res.vos if res.feasible else float("-inf")


def exhaustive_search(cosim: CoSimulator,
                      chips_options: Sequence[int] = (4, 8, 16),
                      dvfs_options: Sequence[float] = (1.0,),
                      evaluator: Optional[Evaluator] = None,
                      edge_sites: Sequence[str] = (SITE_EDGE,),
                      ) -> SearchResult:
    ev = evaluator or Evaluator(cosim)
    hits0, misses0 = ev.hits, ev.misses
    names = list(cosim.topology)
    best_plan: Optional[PlacementPlan] = None
    best: Optional[CoSimResult] = None
    for plan in enumerate_plans(names, chips_options, dvfs_options,
                                edge_sites):
        res = ev(plan)
        if best is None or _score(res) > _score(best):
            best_plan, best = plan, res
    assert best_plan is not None and best is not None
    return SearchResult(best_plan, best, "exhaustive", ev.misses - misses0,
                        ev.history, cache_hits=ev.hits - hits0,
                        cache_misses=ev.misses - misses0)


def _greedy(ev: Evaluator, start: PlacementPlan,
            options: List[ServicePlacement]) -> PlacementPlan:
    """First-improvement single-service descent: sweep the services,
    accept any improving move immediately, repeat until a full sweep
    finds none (a local optimum of the single-flip neighborhood)."""
    current, score = start, _score(ev(start))
    improved = True
    while improved:
        improved = False
        for name in sorted(current.assignments):
            for opt in options:
                if opt == current.assignments[name]:
                    continue
                cand = current.with_placement(name, opt)
                s = _score(ev(cand))
                if s > score:
                    current, score = cand, s
                    improved = True
    return current


def _hill_climb(ev: Evaluator, start: PlacementPlan,
                options: List[ServicePlacement], rng: random.Random,
                iters: int) -> PlacementPlan:
    """Seeded stochastic single-flip climb (escapes plateau ties)."""
    names = sorted(start.assignments)
    current, score = start, _score(ev(start))
    for _ in range(iters):
        name = rng.choice(names)
        opt = rng.choice(options)
        if opt == current.assignments[name]:
            continue
        cand = current.with_placement(name, opt)
        s = _score(ev(cand))
        # accept improvements and sideways moves (plateau escape); cand
        # always differs from current (identity options are skipped above)
        if s >= score:
            current, score = cand, s
    return current


def greedy_search(cosim: CoSimulator,
                  chips_options: Sequence[int] = (4, 8, 16),
                  dvfs_options: Sequence[float] = (1.0,),
                  seed: int = 0, restarts: int = 2,
                  climb_iters: int = 64,
                  evaluator: Optional[Evaluator] = None,
                  edge_sites: Sequence[str] = (SITE_EDGE,)) -> SearchResult:
    ev = evaluator or Evaluator(cosim)
    hits0, misses0 = ev.hits, ev.misses
    names = list(cosim.topology)
    options = service_options(chips_options, dvfs_options, edge_sites)
    rng = random.Random(seed)

    anchors = [PlacementPlan.all_edge(names, site=s) for s in edge_sites]
    for c in chips_options:
        anchors.append(PlacementPlan.all_dc(names, chips=c,
                                            dvfs_f=dvfs_options[0]))
    for _ in range(restarts):
        anchors.append(PlacementPlan(
            {n: rng.choice(options) for n in names}))

    best_plan: Optional[PlacementPlan] = None
    for anchor in anchors:
        local = _greedy(ev, anchor, options)
        local = _hill_climb(ev, local, options, rng, climb_iters)
        if best_plan is None or _score(ev(local)) > _score(ev(best_plan)):
            best_plan = local
    assert best_plan is not None
    best = ev(best_plan)
    return SearchResult(best_plan, best, "greedy+hillclimb",
                        ev.misses - misses0, ev.history,
                        cache_hits=ev.hits - hits0,
                        cache_misses=ev.misses - misses0)


def _anchor_plans(names: Sequence[str], chips_options: Sequence[int],
                  dvfs_options: Sequence[float],
                  edge_sites: Sequence[str]) -> List[PlacementPlan]:
    """The baseline plans every screened search re-scores exactly (so
    ``searched >= baselines`` holds even under a screening mis-rank)."""
    plans = [PlacementPlan.all_edge(names, site=s) for s in edge_sites]
    plans.append(PlacementPlan.all_dc(names, chips=chips_options[0],
                                      dvfs_f=dvfs_options[0]))
    return plans


def _plan_of_row(row, names: Sequence[str],
                 options: Sequence[ServicePlacement]) -> PlacementPlan:
    return PlacementPlan({n: options[int(o)] for n, o in zip(names, row)})


def screened_search(cosim: CoSimulator,
                    chips_options: Sequence[int] = (4, 8, 16),
                    dvfs_options: Sequence[float] = (1.0,),
                    seed: int = 0,
                    top_k: Optional[int] = None,
                    evaluator: Optional[Evaluator] = None,
                    edge_sites: Sequence[str] = (SITE_EDGE,),
                    enumerate_limit: int = 65536,
                    sample_budget: int = 2048,
                    climbers: int = 8,
                    climb_rounds: int = 32,
                    corrections=None) -> SearchResult:
    """Two-tier search: tier 1 scores candidates in vectorized batches
    on the screening model (the whole plan space when it enumerates
    under ``enumerate_limit``, else anchors + a seeded random sample
    refined by batched single-flip hill climbing on the screening
    surface); tier 2 runs the exact DES co-simulation only on the
    top-K screened survivors plus the anchor plans, which bounds the
    damage of a screening mis-rank. Deterministic for a fixed seed.

    ``corrections`` (per-service forecast-calibration terms, see
    :mod:`repro.scenario.feedback`) are installed on the screener for
    the duration of this search — tier 1 then *ranks* with calibrated
    latency/value terms — and the screener's previous state is restored
    before returning. Tier 2 is the exact DES either way."""
    ev = evaluator or Evaluator(cosim)
    screener = ev.screener
    if screener is None:
        raise ValueError(f"{type(cosim).__name__} exposes no "
                         "screening_model; use exhaustive/greedy search")
    prev_corr = (screener.set_corrections(corrections)
                 if corrections is not None else None)
    try:
        return _screened_search(cosim, ev, screener, chips_options,
                                dvfs_options, seed, top_k, edge_sites,
                                enumerate_limit, sample_budget, climbers,
                                climb_rounds,
                                calibrated=corrections is not None)
    finally:
        if corrections is not None:
            screener.set_corrections(prev_corr)


def _screen_shortlist(ev: Evaluator, screener,
                      options: Sequence[ServicePlacement],
                      anchors: Sequence[PlacementPlan], seed: int,
                      top_k: int, enumerate_limit: int, sample_budget: int,
                      climbers: int, climb_rounds: int):
    """Tier-1 candidate generation shared by ``screened_search`` and
    ``robust_search``: score the whole space (small) or anchors + a
    seeded sample refined by batched single-flip hill climbing (large),
    then return the deduped top-K survivors best-first, the method
    label, and screening stats. Deterministic for a fixed seed."""
    names = list(screener.order)
    S, n_opts = len(names), len(options)
    space = n_opts ** S

    t0 = time.perf_counter()
    if space <= enumerate_limit:
        grids = np.meshgrid(*([np.arange(n_opts)] * S), indexing="ij")
        P = np.stack(grids, axis=-1).reshape(-1, S)
        scores = ev.screen_matrix(P, options)
        method = "screened-exhaustive"
    else:
        rng = np.random.default_rng(seed)
        A = screener.matrix_of(anchors, options)
        P = np.vstack([A, rng.integers(0, n_opts, size=(sample_budget, S))])
        scores = ev.screen_matrix(P, options)
        # batched first-improvement hill climb from the best seeds: each
        # round scores every single-flip neighbor of every live climber
        # in ONE vectorized pass
        order = np.argsort(-scores, kind="stable")
        cur = P[order[:climbers]].copy()
        cur_sc = scores[order[:climbers]].copy()
        for _ in range(climb_rounds):
            neigh, owner = [], []
            for ci, row in enumerate(cur):
                for si in range(S):
                    for o in range(n_opts):
                        if o != row[si]:
                            r = row.copy()
                            r[si] = o
                            neigh.append(r)
                            owner.append(ci)
            Nb = np.asarray(neigh)
            sc = ev.screen_matrix(Nb, options)
            owner = np.asarray(owner)
            improved = False
            for ci in range(len(cur)):
                mine = np.where(owner == ci)[0]
                bi = mine[np.argmax(sc[mine])]
                if sc[bi] > cur_sc[ci]:
                    cur[ci], cur_sc[ci] = Nb[bi], sc[bi]
                    improved = True
            P = np.vstack([P, Nb])
            scores = np.concatenate([scores, sc])
            if not improved:
                break
        method = "screened-sampled"
    screen_wall = time.perf_counter() - t0

    # deterministic top-K: stable sort on score, dedup on canonical key
    order = np.argsort(-scores, kind="stable")
    survivors: List[PlacementPlan] = []
    seen = set()
    for i in order:
        plan = _plan_of_row(P[i], names, options)
        key = plan.key()
        if key in seen:
            continue
        seen.add(key)
        survivors.append(plan)
        if len(survivors) >= top_k:
            break
    stats = {"screened": int(len(P)), "space": int(space),
             "screen_wall_s": round(screen_wall, 4)}
    return survivors, method, stats


def _default_top_k(space: int, enumerate_limit: int) -> int:
    return (max(2, min(16, space // 10)) if space <= enumerate_limit
            else 16)


def _screened_search(cosim, ev: Evaluator, screener,
                     chips_options: Sequence[int],
                     dvfs_options: Sequence[float], seed: int,
                     top_k: Optional[int], edge_sites: Sequence[str],
                     enumerate_limit: int, sample_budget: int,
                     climbers: int, climb_rounds: int,
                     calibrated: bool = False) -> SearchResult:
    hits0, misses0 = ev.hits, ev.misses
    names = list(screener.order)
    options = service_options(chips_options, dvfs_options, edge_sites)
    space = len(options) ** len(names)
    anchors = _anchor_plans(names, chips_options, dvfs_options, edge_sites)
    if top_k is None:
        top_k = _default_top_k(space, enumerate_limit)
    survivors, method, shortlist_stats = _screen_shortlist(
        ev, screener, options, anchors, seed, top_k, enumerate_limit,
        sample_budget, climbers, climb_rounds)
    screen_best_key = survivors[0].key() if survivors else None

    # tier 2: exact DES on survivors + anchors (memoized; a parallel
    # evaluator fans the uncached ones out, merge order is fixed)
    best_plan: Optional[PlacementPlan] = None
    best: Optional[CoSimResult] = None
    for plan, res in zip(survivors + anchors,
                         ev.evaluate_batch(survivors + anchors)):
        if best is None or _score(res) > _score(best):
            best_plan, best = plan, res
    assert best_plan is not None and best is not None
    screen_stats = dict(shortlist_stats)
    screen_stats.update({
        "top_k": int(top_k),
        "survivors": len(survivors), "anchors": len(anchors),
        "agreement": bool(screen_best_key == best_plan.key()),
        "calibrated": bool(calibrated),
    })
    return SearchResult(best_plan, best, method, ev.misses - misses0,
                        ev.history, screen=screen_stats,
                        cache_hits=ev.hits - hits0,
                        cache_misses=ev.misses - misses0)


def robust_search(cosim: CoSimulator, ensemble, risk="cvar",
                  chips_options: Sequence[int] = (4, 8, 16),
                  dvfs_options: Sequence[float] = (1.0,),
                  seed: int = 0,
                  shortlist: int = 24,
                  final_k: int = 6,
                  evaluator: Optional[Evaluator] = None,
                  edge_sites: Sequence[str] = (SITE_EDGE,),
                  enumerate_limit: int = 65536,
                  sample_budget: int = 2048,
                  climbers: int = 8,
                  climb_rounds: int = 32,
                  corrections=None,
                  prev_plan: Optional[PlacementPlan] = None) -> SearchResult:
    """Three-tier distributionally robust search.

    Tier 1 is the shared vectorized screen (``_screen_shortlist``) over
    the single nominal trace, kept only to cut the space down to
    ``shortlist`` candidates. Tier 2 evaluates every candidate against
    *all* drift realizations of ``ensemble`` (a
    :class:`repro.fluid.ensemble.ScenarioEnsemble`) in one jitted fluid
    call and ranks plans by ``risk`` (a
    :class:`repro.fluid.robust.RiskSpec`, a metric name, or ``None`` for
    risk-neutral mean). Tier 3 re-scores the top ``final_k`` finalists
    plus the anchor plans with the exact DES; the winner is the
    best-risk finalist the DES confirms feasible (falling back to the
    best exact score if none is).

    ``prev_plan`` charges per-candidate migration stalls inside the
    fluid tier, so risk ranking sees switching costs. Deterministic for
    a fixed seed."""
    from repro.fluid.robust import RiskSpec, risk_score

    risk = RiskSpec.of(risk if risk is not None else "mean")
    ev = evaluator or Evaluator(cosim)
    screener = ev.screener
    if screener is None:
        raise ValueError(f"{type(cosim).__name__} exposes no "
                         "screening_model; robust_search needs tier 1")
    hits0, misses0 = ev.hits, ev.misses
    names = list(screener.order)
    options = service_options(chips_options, dvfs_options, edge_sites)
    anchors = _anchor_plans(names, chips_options, dvfs_options, edge_sites)

    prev_corr = (screener.set_corrections(corrections)
                 if corrections is not None else None)
    try:
        survivors, method, shortlist_stats = _screen_shortlist(
            ev, screener, options, anchors, seed, shortlist,
            enumerate_limit, sample_budget, climbers, climb_rounds)
    finally:
        if corrections is not None:
            screener.set_corrections(prev_corr)

    # candidate set for the fluid tier: screened survivors first, then
    # any anchor the screen did not already surface
    candidates: List[PlacementPlan] = []
    seen = set()
    for plan in list(survivors) + list(anchors):
        key = plan.key()
        if key not in seen:
            seen.add(key)
            candidates.append(plan)

    # tier 2: N realizations x M candidates in one jitted fluid call
    t0 = time.perf_counter()
    stalls = (ensemble.fluid.migration_stalls(prev_plan, candidates)
              if prev_plan is not None else None)
    fr = ensemble.evaluate(candidates, corrections=corrections,
                           stalls=stalls)
    fluid_wall = time.perf_counter() - t0
    scores = risk_score(fr.vos, risk)
    mean_scores = fr.vos.mean(axis=0)
    risk_order = np.argsort(-scores, kind="stable")
    finalists = [candidates[i] for i in risk_order[:max(1, final_k)]]
    fluid_best_key = finalists[0].key()

    # tier 3: exact DES on finalists + anchors; winner = best-risk
    # finalist the DES confirms feasible
    pool_plans = finalists + list(anchors)
    exact: Dict[Tuple, CoSimResult] = {
        plan.key(): res
        for plan, res in zip(pool_plans, ev.evaluate_batch(pool_plans))}
    best_plan: Optional[PlacementPlan] = None
    for plan in finalists:
        if exact[plan.key()].feasible:
            best_plan = plan
            break
    if best_plan is None:    # every finalist infeasible under the DES
        pool = finalists + list(anchors)
        best_plan = max(pool, key=lambda p: _score(exact[p.key()]))
    best = exact[best_plan.key()]

    idx_of = {p.key(): i for i, p in enumerate(candidates)}
    screen_stats = dict(shortlist_stats)
    screen_stats.update({
        "top_k": int(shortlist), "survivors": len(survivors),
        "anchors": len(anchors), "calibrated": corrections is not None,
        "agreement": bool(fluid_best_key == best_plan.key()),
        "robust": {
            "risk": risk.label,
            "ensemble": int(ensemble.n_realizations),
            "candidates": len(candidates),
            "fluid_wall_s": round(fluid_wall, 4),
            "finalists": [
                {"plan": p.label,
                 "risk_score": float(scores[idx_of[p.key()]]),
                 "mean_score": float(mean_scores[idx_of[p.key()]]),
                 "des_vos": float(exact[p.key()].vos),
                 "des_feasible": bool(exact[p.key()].feasible)}
                for p in finalists],
        },
    })
    return SearchResult(best_plan, best, f"robust[{risk.label}]+{method}",
                        ev.misses - misses0, ev.history,
                        screen=screen_stats,
                        cache_hits=ev.hits - hits0,
                        cache_misses=ev.misses - misses0)


def search_placement(cosim: CoSimulator,
                     chips_options: Sequence[int] = (4, 8, 16),
                     dvfs_options: Sequence[float] = (1.0,),
                     exhaustive_limit: int = 1024,
                     seed: int = 0,
                     evaluator: Optional[Evaluator] = None,
                     edge_sites: Sequence[str] = (SITE_EDGE,),
                     screen: Optional[bool] = None,
                     top_k: Optional[int] = None,
                     corrections=None,
                     partition: Optional[bool] = None,
                     warm_start: Optional[PlacementPlan] = None
                     ) -> SearchResult:
    """Front door. When the scorer can build a tier-1 screening model
    (the unified ``ScenarioEngine`` can; analytic scorers like the
    online ``ForecastModel`` cannot) the two-tier screened search is
    the default fast path — pass ``screen=False`` to force the legacy
    exact-only search. Without a screener: exhaustive when the plan
    space fits under ``exhaustive_limit`` evaluations, greedy +
    hill-climb otherwise. ``edge_sites`` widens the per-service choice
    set to a multi-gateway fleet; the evaluator must understand those
    site names. ``corrections`` threads forecast-calibration state into
    the tier-1 screen (ignored on the exact-only path, whose scorer —
    e.g. a calibrated ``ForecastModel`` — carries its own).

    ``partition`` routes hierarchical fleets to the decomposed
    per-region search (:func:`repro.region.search.region_search` /
    ``region_search_exact``): ``None`` auto-detects declared regions on
    the scorer's fleet, ``True`` forces it, ``False`` keeps the joint
    search. ``warm_start`` seeds the decomposed path with an incumbent
    plan (the online controller's epoch loop)."""
    ev = evaluator or Evaluator(cosim)
    if screen is None:
        screen = ev.screener is not None
    if partition is None:
        fleet = getattr(getattr(cosim, "cfg", None), "fleet", None) \
            or getattr(getattr(cosim, "info", None), "fleet", None) \
            or getattr(cosim, "fleet", None)
        partition = bool(getattr(fleet, "regions", ()))
    if partition:
        from repro.region.search import region_search, region_search_exact
        if screen:
            return region_search(cosim, chips_options, dvfs_options,
                                 seed=seed, evaluator=ev,
                                 warm_start=warm_start,
                                 corrections=corrections)
        return region_search_exact(cosim, chips_options, dvfs_options,
                                   seed=seed, evaluator=ev,
                                   warm_start=warm_start)
    if screen:
        return screened_search(cosim, chips_options, dvfs_options,
                               seed=seed, top_k=top_k, evaluator=ev,
                               edge_sites=edge_sites,
                               corrections=corrections)
    n_opts = len(edge_sites) + len(chips_options) * len(dvfs_options)
    space = n_opts ** len(cosim.topology)
    if space <= exhaustive_limit:
        return exhaustive_search(cosim, chips_options, dvfs_options,
                                 evaluator=ev, edge_sites=edge_sites)
    return greedy_search(cosim, chips_options, dvfs_options, seed=seed,
                         evaluator=ev, edge_sites=edge_sites)
