"""Edge↔DC placement engine (JITA4DS bridge, arXiv:2108.02558 direction).

Models edge devices and the edge↔DC network, expresses per-service
placement plans over a pipeline DAG, and searches for SLO-optimal
placements. Co-simulation itself lives in the unified Scenario API
(``repro.scenario``); the ``cosim`` module here is a deprecation shim
over it:

  edge.py     EdgeNode — gateway-class device, serial fire execution
  network.py  NetworkModel — uplink/downlink transfer time + energy
  plan.py     PlacementPlan — per-service edge|dc + VDC chips/DVFS hints
  cosim.py    DEPRECATED CoSimulator shim → repro.scenario.engine
  search.py   exhaustive / greedy+hill-climb VoS-optimal placement search

The co-sim names (``CoSimulator``, ``CoSimResult``, ``ServiceProfile``,
...) resolve lazily so the shim's import of ``repro.scenario`` cannot
cycle back through this package's eager imports.
"""
from repro.placement.edge import EdgeNode, EdgeSpec, FireExec
from repro.placement.network import LinkSpec, NetworkModel
from repro.placement.plan import (PlacementPlan, ServicePlacement,
                                  SITE_DC, SITE_EDGE, enumerate_plans,
                                  service_options)

_COSIM_NAMES = ("CoSimConfig", "CoSimResult", "CoSimulator",
                "RecordLedger", "ServiceLedger", "ServiceProfile",
                "ServiceSLO", "analytics_cost_model")
_SEARCH_NAMES = ("Evaluator", "SearchResult", "exhaustive_search",
                 "greedy_search", "robust_search", "screened_search",
                 "search_placement")
_PARALLEL_NAMES = ("ParallelEvaluator", "default_workers")

__all__ = ["EdgeNode", "EdgeSpec", "FireExec", "LinkSpec", "NetworkModel",
           "PlacementPlan", "ServicePlacement", "SITE_DC", "SITE_EDGE",
           "enumerate_plans", "service_options",
           *_COSIM_NAMES, *_SEARCH_NAMES, *_PARALLEL_NAMES]


def __getattr__(name):
    if name in _COSIM_NAMES:
        from repro.placement import cosim
        return getattr(cosim, name)
    if name in _SEARCH_NAMES:
        from repro.placement import search
        return getattr(search, name)
    if name in _PARALLEL_NAMES:
        from repro.placement import parallel
        return getattr(parallel, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
