"""Edge↔DC placement engine (JITA4DS bridge, arXiv:2108.02558 direction).

Models edge devices and the edge↔DC network, expresses per-service
placement plans over a pipeline DAG, co-simulates stream pipelines whose
DC-placed services are offloaded onto just-in-time composed VDCs, and
searches for SLO-optimal placements:

  edge.py     EdgeNode — gateway-class device, serial fire execution
  network.py  NetworkModel — uplink/downlink transfer time + energy
  plan.py     PlacementPlan — per-service edge|dc + VDC chips/DVFS hints
  cosim.py    CoSimulator — pipeline × JITA-4DS Simulator co-simulation
  search.py   exhaustive / greedy+hill-climb VoS-optimal placement search
"""
from repro.placement.edge import EdgeNode, EdgeSpec, FireExec
from repro.placement.network import LinkSpec, NetworkModel
from repro.placement.plan import (PlacementPlan, ServicePlacement,
                                  SITE_DC, SITE_EDGE, enumerate_plans,
                                  service_options)
from repro.placement.cosim import (CoSimConfig, CoSimResult, CoSimulator,
                                   RecordLedger, ServiceLedger,
                                   ServiceProfile, ServiceSLO,
                                   analytics_cost_model)
from repro.placement.search import (Evaluator, SearchResult,
                                    exhaustive_search, greedy_search,
                                    search_placement)
