"""Process-parallel exact-DES plan evaluation.

The exact tier of every search (``screened_search`` / ``robust_search``
/ ``region_search``) scores a shortlist of finalist plans with the full
DES replay — each an independent, CPU-bound ``engine.run_plan`` call on
one shared, already-driven fire trace. :class:`ParallelEvaluator` fans
those calls across a persistent worker pool:

* **fork start method** (Linux default): workers inherit the parent's
  *driven* engine by address-space copy — no pickling, no re-drive; the
  pool amortizes across every batch of the evaluator's lifetime.
* **no fork** (spawn-only platforms): workers rebuild the engine from
  the scenario's JSON ``ScenarioSpec`` (``spec=``) and pay one
  functional drive each, once per pool lifetime.
* **workers <= 1, no usable start method, or no spec to rebuild from**:
  clean in-process fallback — the batch runs the base class's serial
  loop in the caller's process.

Determinism: ``run_plan`` is a pure function of (driven engine, plan),
so per-plan results do not depend on which worker computes them. The
merge replays the submission order exactly as the serial evaluator
would — cache inserts, history entries and hit/miss counters are
bit-identical for any worker count, including the in-process fallback.

The memo cache is the inherited :class:`~repro.placement.search.
Evaluator` cache, shared across calls (and across searches when the
caller passes ``cache=``), so an online controller's epoch loop reuses
exact results instead of re-fanning them out.
"""
from __future__ import annotations

import multiprocessing as mp
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.placement.cosim import CoSimResult, CoSimulator
from repro.placement.plan import PlacementPlan
from repro.placement.search import Evaluator

# Worker-process state: the engine every task of this pool evaluates
# against. Set once by the pool initializer.
_WORKER_ENGINE = None


def _init_worker(engine, spec_dict) -> None:
    global _WORKER_ENGINE
    if engine is None:
        from repro.scenario.spec import ScenarioSpec
        engine = ScenarioSpec.from_dict(spec_dict).compile()
        engine._ensure_driven()
    _WORKER_ENGINE = engine


def _eval_plan(plan_dict: Dict) -> CoSimResult:
    return _WORKER_ENGINE.run_plan(PlacementPlan.from_dict(plan_dict))


def default_workers() -> int:
    """Pool width when the caller does not pin one: the machine's cores
    (a 1-core box degrades to the in-process serial path)."""
    return os.cpu_count() or 1


class ParallelEvaluator(Evaluator):
    """Drop-in :class:`Evaluator` whose :meth:`evaluate_batch` fans the
    *uncached* plans of a batch across a persistent process pool.

    Single-plan ``__call__`` stays in-process (one DES run gains
    nothing from a pool round-trip); searches batch their exact tiers,
    so the pool sees the finalist fan-outs. Close with :meth:`close`
    or use as a context manager; an unclosed pool is reaped with the
    evaluator.

    Parameters
    ----------
    cosim:
        The driven scorer (a ``ScenarioEngine``) — also the engine
        forked into workers.
    workers:
        Pool width; ``None`` means :func:`default_workers`. ``<= 1``
        disables the pool entirely (serial in-process evaluation).
    spec:
        Optional ``ScenarioSpec`` (or its ``to_dict()`` form) for
        spawn-only platforms where workers cannot inherit the engine;
        without it, no-fork platforms fall back to in-process serial.
    """

    def __init__(self, cosim: CoSimulator, workers: Optional[int] = None,
                 spec=None, screener=None,
                 cache: Optional[Dict[Tuple, CoSimResult]] = None,
                 key_prefix: Optional[Tuple] = None):
        super().__init__(cosim, screener=screener, cache=cache,
                         key_prefix=key_prefix)
        self.workers = default_workers() if workers is None else int(workers)
        self._spec_dict = (spec.to_dict() if hasattr(spec, "to_dict")
                          else spec)
        self._pool = None
        self._pool_broken = False
        self.parallel_batches = 0   # batches that actually used the pool
        self.parallel_jobs = 0      # plans evaluated by pool workers
        self.serial_jobs = 0        # uncached plans evaluated in-process

    # ------------------------------------------------------------- pool
    def _start_method(self) -> Optional[str]:
        methods = mp.get_all_start_methods()
        if "fork" in methods:
            return "fork"
        if self._spec_dict is not None and methods:
            return methods[0]
        return None

    def _ensure_pool(self):
        if self.workers <= 1 or self._pool_broken:
            return None
        if self._pool is not None:
            return self._pool
        method = self._start_method()
        if method is None:
            self._pool_broken = True
            return None
        try:
            ctx = mp.get_context(method)
            if method == "fork":
                # fork inherits the driven engine through the address
                # space — make sure the trace exists before forking so
                # workers never each re-drive it
                ensure = getattr(self.cosim, "_ensure_driven", None)
                if ensure is not None:
                    ensure()
                initargs = (self.cosim, None)
            else:
                initargs = (None, self._spec_dict)
            self._pool = ctx.Pool(processes=self.workers,
                                  initializer=_init_worker,
                                  initargs=initargs)
        except Exception:
            self._pool_broken = True
            self._pool = None
        return self._pool

    def close(self) -> None:
        """Shut the worker pool down (idempotent)."""
        if self._pool is not None:
            self._pool.terminate()
            self._pool.join()
            self._pool = None

    def __enter__(self) -> "ParallelEvaluator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):
        try:
            self.close()
        except Exception:
            pass

    # ------------------------------------------------------------ batch
    def evaluate_batch(self, plans: Sequence[PlacementPlan]
                       ) -> List[CoSimResult]:
        """Fan the batch's uncached unique plans across the pool, then
        replay the submission order against the cache — the resulting
        cache contents, history order, and hit/miss counters are
        bit-identical to the serial base class for any worker count."""
        todo: List[PlacementPlan] = []
        seen = set()
        for plan in plans:
            key = self._key(plan)
            if key not in self.cache and key not in seen:
                seen.add(key)
                todo.append(plan)
        pool = self._ensure_pool() if len(todo) > 1 else None
        fresh: Dict[Tuple, CoSimResult] = {}
        if pool is not None:
            try:
                results = pool.map(_eval_plan,
                                   [p.to_dict() for p in todo])
            except Exception:
                # a dead pool must not kill the search — evaluate the
                # batch in-process and stop using the pool
                self._pool_broken = True
                self.close()
                results = None
            if results is not None:
                self.parallel_batches += 1
                self.parallel_jobs += len(todo)
                fresh = {self._key(p): r for p, r in zip(todo, results)}
        out: List[CoSimResult] = []
        for plan in plans:
            key = self._key(plan)
            if key in self.cache:
                self.hits += 1
            else:
                self.misses += 1
                res = fresh.get(key)
                if res is None:
                    res = self._run(plan)
                    self.serial_jobs += 1
                self.cache[key] = res
                self.history.append((plan.label, res.vos))
            out.append(self.cache[key])
        return out

    def stats(self) -> Dict:
        out = super().stats()
        out.update({"workers": self.workers,
                    "parallel_batches": self.parallel_batches,
                    "parallel_jobs": self.parallel_jobs,
                    "serial_jobs": self.serial_jobs})
        return out
