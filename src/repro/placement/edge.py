"""Edge device model for the placement engine.

The paper's edge tier is a small gateway-class box next to the IoT farm:
it can absorb the stream and run light aggregation windows, but a heavy
analytics operator (CNN scoring, large post-mortem scans) quickly
outgrows it — that is precisely the offloading decision the placement
engine searches over.

An :class:`EdgeNode` is a single serial executor (one device per site):
service fires queue behind each other, so co-locating too many services
on the edge shows up as queueing latency, not just energy. Per-fire cost
has an ingest term (records/s the box can pump through its buffers), a
compute term (operator FLOPs against the box's sustained FLOP/s) and a
fixed per-fire overhead (scheduler wakeup + fetch).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """A gateway-class edge device (defaults ≈ a quad-core ARM box)."""
    name: str = "edge-0"
    throughput_rps: float = 50_000.0      # records/s ingest+window pump
    flops_per_s: float = 20e9             # sustained analytics FLOP/s
    ram_bytes: float = 256 * 2**20        # buffer budget for all services
    record_bytes: float = 64.0            # in-RAM footprint of one record
    energy_per_record_j: float = 20e-6    # ingest/window energy
    active_power_w: float = 6.0           # draw while a fire executes
    fire_overhead_s: float = 2e-3         # wakeup + fetch per fire

    def ram_required(self, buffer_records: int) -> float:
        """RAM footprint of hosting `buffer_records` of service buffer
        budget on this device (single source of the record-footprint
        model — the co-sim's feasibility check goes through here)."""
        return buffer_records * self.record_bytes


@dataclasses.dataclass(frozen=True)
class FireExec:
    """Accounting for one service fire executed on the edge."""
    start: float
    finish: float
    energy_j: float


class EdgeNode:
    """Serial executor with busy-queue semantics and energy accounting."""

    def __init__(self, spec: EdgeSpec):
        self.spec = spec
        self.busy_until = 0.0
        self.energy_j = 0.0

    def fire_time(self, n_records: int, flops_per_record: float) -> float:
        """Service time of one window fire over `n_records` values."""
        s = self.spec
        ingest = n_records / s.throughput_rps
        compute = n_records * flops_per_record / s.flops_per_s
        return max(ingest, compute) + s.fire_overhead_s

    def execute_fire(self, ready_ts: float, n_records: int,
                     flops_per_record: float = 0.0) -> FireExec:
        """Run one fire as soon as its inputs are ready and the device is
        free; returns start/finish/energy. Mutates the busy horizon."""
        dur = self.fire_time(n_records, flops_per_record)
        start = max(ready_ts, self.busy_until)
        finish = start + dur
        energy = (n_records * self.spec.energy_per_record_j
                  + dur * self.spec.active_power_w)
        self.busy_until = finish
        self.energy_j += energy
        return FireExec(start, finish, energy)
