"""Placement plans: per-service site assignment over a pipeline DAG.

A plan maps every service of a pipeline topology to a site: the DC
(``SITE_DC``) or an edge gateway. Single-gateway deployments use the
default ``SITE_EDGE`` name; multi-site fleets (``repro.online``) use
one name per gateway — any site other than ``SITE_DC`` is edge-resident.
DC-resident services additionally carry a VDC sizing hint (chip count,
power of two ≥ 4, matching ``PodGrid.compose``) and a DVFS frequency
hint that the co-simulator forwards to the JITA-4DS scheduler.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

from repro.core.vdc import MIN_VDC_CHIPS, is_valid_vdc_size

SITE_EDGE = "edge"
SITE_DC = "dc"
SITES = (SITE_EDGE, SITE_DC)

Topology = Mapping[str, Sequence[str]]  # service -> upstream service names


@dataclasses.dataclass(frozen=True)
class ServicePlacement:
    site: str
    chips: int = 8          # VDC sizing hint (dc only)
    dvfs_f: float = 1.0     # DVFS hint (dc only)

    @property
    def is_edge(self) -> bool:
        return self.site != SITE_DC

    @property
    def label(self) -> str:
        if self.is_edge:
            return self.site
        return f"dc[{self.chips}]@{self.dvfs_f:g}"


class _Assignments(dict):
    """Plan assignment map that can be sealed: once the owning plan's
    canonical ``key()`` is computed (and possibly memoized on), any
    further mutation raises — a stale memo entry would silently score
    the wrong plan."""
    __slots__ = ("_sealed",)

    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self._sealed = False

    def _reject(self):
        raise TypeError("PlacementPlan is frozen once key() has been "
                        "computed; build a new plan with with_placement()")

    def __setitem__(self, k, v):
        if self._sealed:
            self._reject()
        super().__setitem__(k, v)

    def __delitem__(self, k):
        if self._sealed:
            self._reject()
        super().__delitem__(k)

    def _guarded(name):  # noqa: N805 — tiny local factory
        orig = getattr(dict, name)

        def meth(self, *a, **kw):
            if self._sealed:
                self._reject()
            return orig(self, *a, **kw)
        meth.__name__ = name
        return meth

    update = _guarded("update")
    pop = _guarded("pop")
    popitem = _guarded("popitem")
    clear = _guarded("clear")
    setdefault = _guarded("setdefault")
    del _guarded

    def __reduce__(self):
        return (_rebuild_assignments, (dict(self), self._sealed))


def _rebuild_assignments(d, sealed):
    out = _Assignments(d)
    out._sealed = sealed
    return out


@dataclasses.dataclass
class PlacementPlan:
    assignments: Dict[str, ServicePlacement]

    def __post_init__(self):
        self.assignments = _Assignments(self.assignments)
        self._key: Optional[Tuple] = None

    # ------------------------------------------------------------ builders
    @classmethod
    def all_edge(cls, names: Sequence[str],
                 site: str = SITE_EDGE) -> "PlacementPlan":
        return cls({n: ServicePlacement(site) for n in names})

    @classmethod
    def all_dc(cls, names: Sequence[str], chips: int = 8,
               dvfs_f: float = 1.0) -> "PlacementPlan":
        return cls({n: ServicePlacement(SITE_DC, chips, dvfs_f)
                    for n in names})

    # ------------------------------------------------------------- queries
    def placement(self, name: str) -> ServicePlacement:
        return self.assignments[name]

    def site(self, name: str) -> str:
        return self.assignments[name].site

    def is_edge(self, name: str) -> bool:
        return self.assignments[name].is_edge

    def edge_services(self) -> List[str]:
        return [n for n, p in self.assignments.items() if p.is_edge]

    def dc_services(self) -> List[str]:
        return [n for n, p in self.assignments.items() if not p.is_edge]

    def cuts(self, topology: Topology) -> List[Tuple[str, str]]:
        """DAG edges (upstream, downstream) whose endpoints sit on
        different sites — each pays a network hop in the co-sim."""
        out = []
        for svc, ups in topology.items():
            for u in ups:
                if self.site(u) != self.site(svc):
                    out.append((u, svc))
        return out

    def key(self) -> Tuple:
        """Canonical hashable identity (for memoized search). Cached on
        first computation — search layers call this per memo/dedup
        lookup, and re-sorting the full assignment tuple every time
        dominated large-fleet dedup passes. Computing the key seals the
        plan against further assignment mutation."""
        k = self._key
        if k is None:
            k = tuple(sorted((n, p.site, p.chips if not p.is_edge else 0,
                              p.dvfs_f if not p.is_edge else 0.0)
                             for n, p in self.assignments.items()))
            self._key = k
            self.assignments._sealed = True
        return k

    @property
    def label(self) -> str:
        return ",".join(f"{n}={p.label}"
                        for n, p in sorted(self.assignments.items()))

    # ---------------------------------------------------------- validation
    def validate(self, topology: Topology, grid_chips: int = 256,
                 sites: Optional[Sequence[str]] = None) -> None:
        """Raise ValueError unless the plan covers exactly the topology's
        services with well-formed placements. ``sites`` is the allowed
        site universe (default: the classic single-gateway pair)."""
        allowed = set(sites) if sites is not None else set(SITES)
        names = set(topology)
        got = set(self.assignments)
        if got != names:
            missing, extra = names - got, got - names
            raise ValueError(f"plan/topology mismatch: missing={sorted(missing)}"
                             f" extra={sorted(extra)}")
        for svc, ups in topology.items():
            for u in ups:
                if u not in names:
                    raise ValueError(f"{svc!r} upstream {u!r} not in topology")
        for n, p in self.assignments.items():
            if p.site not in allowed:
                raise ValueError(f"{n}: unknown site {p.site!r} "
                                 f"(allowed: {sorted(allowed)})")
            if p.is_edge:
                continue
            if not is_valid_vdc_size(p.chips):
                raise ValueError(f"{n}: VDC chips hint must be a power of "
                                 f"two >= {MIN_VDC_CHIPS}, got {p.chips}")
            if p.chips > grid_chips:
                raise ValueError(f"{n}: chips hint {p.chips} exceeds the "
                                 f"pod grid ({grid_chips})")
            if not 0.0 < p.dvfs_f <= 1.0:
                raise ValueError(f"{n}: dvfs_f must be in (0, 1], "
                                 f"got {p.dvfs_f}")

    # ------------------------------------------------------------- JSON
    def to_dict(self) -> Dict[str, Dict]:
        """Structured JSON form (benchmarks record plans this way so
        regressions can replay them without parsing labels)."""
        return {n: {"site": p.site, "chips": p.chips, "dvfs_f": p.dvfs_f}
                for n, p in sorted(self.assignments.items())}

    @classmethod
    def from_dict(cls, d: Mapping[str, Mapping]) -> "PlacementPlan":
        return cls({n: ServicePlacement(v["site"], int(v.get("chips", 8)),
                                        float(v.get("dvfs_f", 1.0)))
                    for n, v in d.items()})

    # -------------------------------------------------------- enumeration
    def with_placement(self, name: str, placement: ServicePlacement
                       ) -> "PlacementPlan":
        d = dict(self.assignments)
        d[name] = placement
        return PlacementPlan(d)


def service_options(chips_options: Sequence[int] = (4, 8, 16),
                    dvfs_options: Sequence[float] = (1.0,),
                    edge_sites: Sequence[str] = (SITE_EDGE,)
                    ) -> List[ServicePlacement]:
    """The per-service choice set a search explores: one edge option per
    gateway site plus the DC chips×DVFS grid."""
    opts = [ServicePlacement(s) for s in edge_sites]
    for c in chips_options:
        for f in dvfs_options:
            opts.append(ServicePlacement(SITE_DC, c, f))
    return opts


def enumerate_plans(names: Sequence[str],
                    chips_options: Sequence[int] = (4, 8, 16),
                    dvfs_options: Sequence[float] = (1.0,),
                    edge_sites: Sequence[str] = (SITE_EDGE,)
                    ) -> Iterator[PlacementPlan]:
    """Exhaustive plan space: (|sites| + |chips|·|dvfs|)^n plans."""
    opts = service_options(chips_options, dvfs_options, edge_sites)
    for combo in itertools.product(opts, repeat=len(names)):
        yield PlacementPlan(dict(zip(names, combo)))
