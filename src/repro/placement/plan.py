"""Placement plans: per-service edge|dc assignment over a pipeline DAG.

A plan maps every service of a pipeline topology to a site. DC-resident
services additionally carry a VDC sizing hint (chip count, power of two
≥ 4, matching ``PodGrid.compose``) and a DVFS frequency hint that the
co-simulator forwards to the JITA-4DS scheduler.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, Iterator, List, Mapping, Sequence, Tuple

from repro.core.vdc import MIN_VDC_CHIPS, is_valid_vdc_size

SITE_EDGE = "edge"
SITE_DC = "dc"
SITES = (SITE_EDGE, SITE_DC)

Topology = Mapping[str, Sequence[str]]  # service -> upstream service names


@dataclasses.dataclass(frozen=True)
class ServicePlacement:
    site: str
    chips: int = 8          # VDC sizing hint (dc only)
    dvfs_f: float = 1.0     # DVFS hint (dc only)

    @property
    def is_edge(self) -> bool:
        return self.site == SITE_EDGE

    @property
    def label(self) -> str:
        if self.is_edge:
            return SITE_EDGE
        return f"dc[{self.chips}]@{self.dvfs_f:g}"


@dataclasses.dataclass
class PlacementPlan:
    assignments: Dict[str, ServicePlacement]

    # ------------------------------------------------------------ builders
    @classmethod
    def all_edge(cls, names: Sequence[str]) -> "PlacementPlan":
        return cls({n: ServicePlacement(SITE_EDGE) for n in names})

    @classmethod
    def all_dc(cls, names: Sequence[str], chips: int = 8,
               dvfs_f: float = 1.0) -> "PlacementPlan":
        return cls({n: ServicePlacement(SITE_DC, chips, dvfs_f)
                    for n in names})

    # ------------------------------------------------------------- queries
    def placement(self, name: str) -> ServicePlacement:
        return self.assignments[name]

    def site(self, name: str) -> str:
        return self.assignments[name].site

    def is_edge(self, name: str) -> bool:
        return self.assignments[name].is_edge

    def edge_services(self) -> List[str]:
        return [n for n, p in self.assignments.items() if p.is_edge]

    def dc_services(self) -> List[str]:
        return [n for n, p in self.assignments.items() if not p.is_edge]

    def cuts(self, topology: Topology) -> List[Tuple[str, str]]:
        """DAG edges (upstream, downstream) whose endpoints sit on
        different sites — each pays a network hop in the co-sim."""
        out = []
        for svc, ups in topology.items():
            for u in ups:
                if self.site(u) != self.site(svc):
                    out.append((u, svc))
        return out

    def key(self) -> Tuple:
        """Canonical hashable identity (for memoized search)."""
        return tuple(sorted((n, p.site, p.chips if not p.is_edge else 0,
                             p.dvfs_f if not p.is_edge else 0.0)
                            for n, p in self.assignments.items()))

    @property
    def label(self) -> str:
        return ",".join(f"{n}={p.label}"
                        for n, p in sorted(self.assignments.items()))

    # ---------------------------------------------------------- validation
    def validate(self, topology: Topology, grid_chips: int = 256) -> None:
        """Raise ValueError unless the plan covers exactly the topology's
        services with well-formed placements."""
        names = set(topology)
        got = set(self.assignments)
        if got != names:
            missing, extra = names - got, got - names
            raise ValueError(f"plan/topology mismatch: missing={sorted(missing)}"
                             f" extra={sorted(extra)}")
        for svc, ups in topology.items():
            for u in ups:
                if u not in names:
                    raise ValueError(f"{svc!r} upstream {u!r} not in topology")
        for n, p in self.assignments.items():
            if p.site not in SITES:
                raise ValueError(f"{n}: unknown site {p.site!r}")
            if p.is_edge:
                continue
            if not is_valid_vdc_size(p.chips):
                raise ValueError(f"{n}: VDC chips hint must be a power of "
                                 f"two >= {MIN_VDC_CHIPS}, got {p.chips}")
            if p.chips > grid_chips:
                raise ValueError(f"{n}: chips hint {p.chips} exceeds the "
                                 f"pod grid ({grid_chips})")
            if not 0.0 < p.dvfs_f <= 1.0:
                raise ValueError(f"{n}: dvfs_f must be in (0, 1], "
                                 f"got {p.dvfs_f}")

    # -------------------------------------------------------- enumeration
    def with_placement(self, name: str, placement: ServicePlacement
                       ) -> "PlacementPlan":
        d = dict(self.assignments)
        d[name] = placement
        return PlacementPlan(d)


def service_options(chips_options: Sequence[int] = (4, 8, 16),
                    dvfs_options: Sequence[float] = (1.0,)
                    ) -> List[ServicePlacement]:
    """The per-service choice set a search explores."""
    opts = [ServicePlacement(SITE_EDGE)]
    for c in chips_options:
        for f in dvfs_options:
            opts.append(ServicePlacement(SITE_DC, c, f))
    return opts


def enumerate_plans(names: Sequence[str],
                    chips_options: Sequence[int] = (4, 8, 16),
                    dvfs_options: Sequence[float] = (1.0,)
                    ) -> Iterator[PlacementPlan]:
    """Exhaustive plan space: (1 + |chips|·|dvfs|)^n plans."""
    opts = service_options(chips_options, dvfs_options)
    for combo in itertools.product(opts, repeat=len(names)):
        yield PlacementPlan(dict(zip(names, combo)))
