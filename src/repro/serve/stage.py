"""Serving actors: farm drivers and service stages on the virtual loop.

A :class:`FarmDriver` advances one producer farm in drive-step
increments at producer priority (before any stage at the same instant,
matching the engine's farms-then-services drive order). Farms never
backpressure — sensors do not pause — so a slow consumer shows up as
broker-queue overflow (oldest-drop, ledger-accounted), not as lost
wall-clock.

A :class:`ServiceStage` is one real operator instance executing its
service's fire grid serially: park until the fire's timestamp, fetch
and snapshot the window (dispatch half), route the execution to the
placed site — hauling remote inputs through the uplink shaper, running
on the gateway's serial device or in the DC chip pool — park until the
virtual completion, wait for downstream queue space (backpressure), and
only then run the operator and let its sinks publish (completion half).
Late upstream results are simply *absent from the window* — the runtime
never waits on dependencies the way the DES does; that divergence is
part of the measured sim-to-real gap.
"""
from __future__ import annotations

import asyncio
from typing import Callable, List, Optional

from repro.core.value import task_value
from repro.online.fleet import Fleet
from repro.pipeline.adapters import StageAdapter
from repro.placement.plan import SITE_DC
from repro.scenario.observe import epoch_of
from repro.scenario.profiles import ServiceProfile
from repro.serve.clock import VirtualClock
from repro.serve.metrics import ServeTelemetry
from repro.serve.router import PlacementRouter
from repro.serve.shaper import UplinkShaper

_EPS = 1e-6


class FarmDriver:
    """Advances one farm in drive-step increments at producer priority."""

    def __init__(self, farm, clock: VirtualClock, horizon_s: float,
                 step_s: float):
        self.farm = farm
        self.clock = clock
        self.horizon_s = horizon_s
        self.step_s = step_s

    async def run(self) -> None:
        t = 0.0
        while t < self.horizon_s - _EPS:
            t = min(t + self.step_s, self.horizon_s)
            await self.clock.sleep_until(t, prio=0)
            self.farm.advance_to(t)


class ServiceStage:
    """One serial operator instance serving one service's fire grid."""

    def __init__(self, adapter: StageAdapter, rank: int,
                 prof: ServiceProfile, clock: VirtualClock,
                 router: PlacementRouter, shaper: UplinkShaper,
                 telemetry: ServeTelemetry, fleet: Fleet,
                 bounds, horizon_s: float,
                 origin_site: Callable[[Optional[str], str, int], str],
                 result_site: str, dl_user: float,
                 stage_capacity: Optional[int] = None,
                 shed_after_s: Optional[float] = None):
        self.adapter = adapter
        self.name = adapter.name
        self.prio = rank + 1            # producers run first at an instant
        self.prof = prof
        self.vspec = prof.slo.value_spec()
        self.clock = clock
        self.router = router
        self.shaper = shaper
        self.telemetry = telemetry
        self.fleet = fleet
        self.bounds = bounds
        self.horizon_s = horizon_s
        self.origin_site = origin_site
        self.result_site = result_site
        self.dl_user = dl_user
        self.stage_capacity = stage_capacity
        self.shed_after_s = shed_after_s
        self.consumers: List["ServiceStage"] = []   # downstream stages
        self.finished = False       # fire grid exhausted; never fetches again
        self._bp_waiters: List[asyncio.Future] = []
        self.fires_dispatched = 0

    # ------------------------------------------------------------- plumbing
    def notify_fetch(self) -> None:
        """Wake publishers parked on this stage's input backlog."""
        waiters, self._bp_waiters = self._bp_waiters, []
        for fut in waiters:
            self.clock.fire(fut)

    async def _backpressure(self) -> None:
        """Publish-side bound: park until every downstream stage's input
        backlog is under the per-stage queue capacity. A consumer whose
        fire grid is exhausted never fetches again, so it stops counting
        (holding the publisher for it would deadlock the drain); its
        leftover records land as broker backlog the ledger accounts."""
        if self.stage_capacity is None:
            return
        while True:
            blocked = next((c for c in self.consumers
                            if not c.finished
                            and c.adapter.backlog() >= self.stage_capacity),
                           None)
            if blocked is None:
                return
            fut = self.clock.event()
            blocked._bp_waiters.append(fut)
            await self.clock.wait(fut)

    # ------------------------------------------------------------ fire path
    async def run(self) -> None:
        try:
            for idx, ts in enumerate(
                    self.adapter.fire_times(self.horizon_s)):
                await self.clock.sleep_until(ts, self.prio)
                await self._one_fire(idx, ts)
        finally:
            self.finished = True
            self.notify_fetch()     # release publishers parked on us

    async def _one_fire(self, idx: int, ts: float) -> None:
        # ---- dispatch half: snapshot the window as delivered ------------
        backlog = self.adapter.backlog()
        self.adapter.fetch()
        self.notify_fetch()
        n_window = self.adapter.peek_window(ts)
        n_new, origins = self.adapter.preview_cover(ts)
        epoch = epoch_of(self.bounds, ts)
        p = self.router.placement(self.name, epoch)
        self.telemetry.on_dispatch(self.name, idx, p.site, n_window, n_new,
                                   backlog)
        self.fires_dispatched += 1

        base = max(ts, self.router.stall_ready(self.name, ts),
                   self.clock.now)
        if (self.shed_after_s is not None
                and base - ts > self.shed_after_s):
            # load shedding: the wait already burned the latency budget;
            # skip the fire, let the records roll into the next window
            self.telemetry.on_shed(self.name, idx)
            return
        arrival = self.shaper.ship_inputs(
            origins, lambda o: self.origin_site(o, self.name, epoch),
            p.site, base)

        # ---- placed execution -------------------------------------------
        if p.site == SITE_DC:
            dur, energy = self.router.dc_cost(self.name, n_window, p)
            await self.clock.sleep_until(arrival, self.prio)
            start = self.router.dc.acquire(max(arrival, self.clock.now),
                                           p.chips, dur)
            ready_out = start + dur
            await self.clock.sleep_until(ready_out, self.prio)
            self.shaper.result_downlink(self.result_site)
            lat = ready_out + self.dl_user - ts
        else:
            await self.clock.sleep_until(arrival, self.prio)
            ex = self.fleet.site(p.site).execute_fire(
                max(arrival, self.clock.now), n_window,
                self.prof.flops_per_record)
            ready_out, energy = ex.finish, ex.energy_j
            await self.clock.sleep_until(ready_out, self.prio)
            lat = ready_out - ts
        value = task_value(self.vspec, lat, energy)

        # ---- completion half: publish when results reach consumers ------
        pub_at = ready_out
        arr_cache = {}
        for cons in self.consumers:
            ep_now = min(epoch_of(self.bounds, ready_out),
                         len(self.router.plans) - 1)
            dst = self.router.site(cons.name, ep_now)
            if dst not in arr_cache:
                arr_cache[dst] = self.shaper.result_arrival(p.site, dst,
                                                            ready_out)
            pub_at = max(pub_at, arr_cache[dst])
        await self.clock.sleep_until(pub_at, self.prio)
        await self._backpressure()
        self.adapter.fire(ts)       # the real operator + sink publishes
        self.telemetry.on_done(self.name, idx, value, lat, energy)
