"""Live serving runtime: one spec, DES for planning, this for serving.

``repro.serve`` executes a compiled
:class:`~repro.scenario.spec.ScenarioSpec` on *actual records* — real
:class:`~repro.pipeline.composition.Pipeline` operators driven by real
producers on a deterministic virtual-time asyncio loop — while honoring
the same placement physics the DES simulates. Engine and runtime are
interchangeable observation sources
(:mod:`repro.scenario.observe`): the same controllers re-place live,
the same calibration loop trains, except on *measured* residuals.

  clock.py    VirtualClock — deterministic virtual-time event loop
              driver (seeded runs replay identical interleavings)
  stage.py    FarmDriver / ServiceStage — the serving actors: serial
              operator instances with bounded-queue backpressure
  router.py   PlacementRouter / DCPool — plan schedule, migration
              stalls, analytic DC execution under a finite chip pool
  shaper.py   UplinkShaper — cross-site bytes through the same Fleet /
              ContendedUplink models the DES prices
  metrics.py  ServeTelemetry — measured EpochObservation-compatible
              rates and realized residuals, frozen per epoch
  runtime.py  ServeRuntime / serve_scenario — the engine's live twin

See README §Live serving and ``benchmarks/bench_serve.py`` for the
engine-vs-runtime sim-to-real gap this subsystem makes measurable.
"""
from repro.serve.clock import VirtualClock
from repro.serve.metrics import ServeTelemetry, StageFire
from repro.serve.router import DCPool, PlacementRouter
from repro.serve.runtime import ServeConfig, ServeRuntime, serve_scenario
from repro.serve.shaper import UplinkShaper
from repro.serve.stage import FarmDriver, ServiceStage
