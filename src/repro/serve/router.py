"""Placement routing for the serving runtime.

The router owns the live plan schedule: which site executes each
service in each epoch, the migration stalls a plan switch imposes, and
the DC-side execution model for DC-routed fires. Edge-routed fires run
on the fleet's serial gateway devices (the stage calls
``EdgeSite.execute_fire`` directly, in virtual-time order); DC-routed
fires run here, against an analytic roofline cost
(:func:`repro.scenario.analytics_cost_model` cells — the same cells the
DES prices) under a finite chip pool. The runtime deliberately does
*not* embed the JITA-4DS DES: the gap between this analytic DC model
and the co-simulated scheduler is part of the sim-vs-real gap
``bench_serve`` measures.
"""
from __future__ import annotations

import heapq
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.costmodel import CostModel
from repro.core.elastic import ServiceMigration, plan_replacement
from repro.placement.plan import PlacementPlan, ServicePlacement


class DCPool:
    """Finite virtual-time chip reservation: a DC fire holds its
    placement's chip count for its analytic duration; when the pool is
    exhausted the fire waits for the earliest releases (FIFO in the
    virtual-time order stages reach the pool)."""

    def __init__(self, total_chips: int):
        self.total = total_chips
        self._busy: List[Tuple[float, int]] = []   # (release_t, chips)
        self._used = 0
        self.wait_s = 0.0          # total admission wait across fires
        self.admissions = 0

    def acquire(self, t: float, chips: int, duration: float) -> float:
        """Reserve ``chips`` for ``duration`` starting no earlier than
        ``t``; returns the actual start time."""
        chips = min(chips, self.total)
        while self._busy and self._busy[0][0] <= t:
            self._used -= heapq.heappop(self._busy)[1]
        start = t
        while self.total - self._used < chips:
            rel, c = heapq.heappop(self._busy)
            self._used -= c
            start = max(start, rel)
        self._used += chips
        heapq.heappush(self._busy, (start + duration, chips))
        self.wait_s += start - t
        self.admissions += 1
        return start


class PlacementRouter:
    """Live plan schedule + migration stalls + the DC execution model."""

    def __init__(self, cost: CostModel, grid_chips: int,
                 records_per_step: int,
                 state_bytes: Callable[[str], float],
                 ship_state: Callable[[str, str, float, float], float],
                 warmup_s: float):
        self.cost = cost
        self.records_per_step = records_per_step
        self.dc = DCPool(grid_chips)
        self._state_bytes = state_bytes
        self._ship_state = ship_state
        self.warmup_s = warmup_s
        self._plans: List[PlacementPlan] = []
        self._epoch_plan: List[int] = []    # epoch -> index into _plans
        self._stalls: Dict[str, List[Tuple[float, float]]] = {}

    # ------------------------------------------------------------- schedule
    def push_plan(self, plan: PlacementPlan, t0: float,
                  charge: bool = True, epoch: Optional[int] = None,
                  migrations: Optional[List] = None
                  ) -> List[ServiceMigration]:
        """Adopt ``plan`` for the epoch starting at ``t0``. Site moves
        ship operator state over the contended uplink and stall the
        service for transfer + warm-up (cost math from
        ``repro.core.elastic``, identical to the engine).

        Mid-epoch chaos re-plans pass ``epoch`` (the epoch being
        overridden: fires dispatched after the push route under the new
        plan) and ``migrations`` (pre-computed checkpoint-aware
        :class:`~repro.chaos.migrate.ChaosMigration` costs, which
        replace the raw-state epoch-boundary model)."""
        migs: List[ServiceMigration] = migrations
        if migrations is None:
            migs = []
            if self._plans:
                def _xfer(src: str, dst: str, nbytes: float) -> float:
                    if not charge:
                        return 0.0
                    return self._ship_state(src, dst, nbytes, t0) - t0
                migs = plan_replacement(self._plans[-1].assignments,
                                        plan.assignments,
                                        self._state_bytes, _xfer,
                                        warmup_s=self.warmup_s)
        if charge:
            for m in migs:
                self._stalls.setdefault(m.service, []).append(
                    (t0, t0 + m.stall_s))
        self._plans.append(plan)
        if epoch is None:
            self._epoch_plan.append(len(self._plans) - 1)
        else:
            self._epoch_plan[epoch] = len(self._plans) - 1
        return migs

    @property
    def plans(self) -> List[PlacementPlan]:
        return self._plans

    def placement(self, svc: str, epoch: int) -> ServicePlacement:
        i = self._epoch_plan[min(epoch, len(self._epoch_plan) - 1)]
        return self._plans[i].placement(svc)

    def site(self, svc: str, epoch: int) -> str:
        return self.placement(svc, epoch).site

    def stall_ready(self, svc: str, ts: float) -> float:
        """Earliest time a fire dispatched at ``ts`` may start, given
        migration stalls already imposed on the service."""
        t = 0.0
        for t_mig, ready in self._stalls.get(svc, ()):
            if t_mig <= ts:
                t = max(t, ready)
        return t

    # ------------------------------------------------------------- DC model
    def dc_cost(self, svc: str, n_window: int,
                p: ServicePlacement) -> Tuple[float, float]:
        """(duration_s, energy_j) of one DC fire under its placement's
        VDC sizing/DVFS hints — the analytic roofline price per step
        times the fire's step count (same cells the DES prices)."""
        steps = max(1, math.ceil(n_window / self.records_per_step))
        dur = steps * self.cost.time_per_step(f"svc:{svc}", "window",
                                              p.chips, p.dvfs_f)
        energy = steps * self.cost.energy_per_step(f"svc:{svc}", "window",
                                                   p.chips, p.dvfs_f)
        return dur, energy
