"""Deterministic virtual-time driver for the serving event loop.

A live runtime on wall-clock asyncio is not reproducible — task wake
order depends on host scheduling jitter. The serving runtime therefore
runs on *virtual* time: every actor (farm driver, service stage) parks
on this clock instead of ``asyncio.sleep``, and the epoch driver
advances time by resolving parked wakes in ``(t, prio, seq)`` order —
producers (prio 0) before stages (prio 1 + topo-rank), matching the
engine's ``(ts, rank)`` dispatch tie-break — then letting the event
loop settle until every actor is parked again. Two runs of the same
scenario replay the identical interleaving, which is what makes the
seeded-determinism guarantee (identical ledgers and telemetry) hold on
a real event loop.

Actors may also park on *event* futures (queue backpressure) that other
actors resolve mid-settle; the clock counts parked actors and
resolved-but-unconsumed futures so it knows when an instant has fully
played out.
"""
from __future__ import annotations

import asyncio
import heapq
from typing import List, Tuple

_EPS = 1e-9


class VirtualClock:
    def __init__(self, settle_rounds: int = 200_000):
        self.now = 0.0
        self._heap: List[Tuple[float, int, int, asyncio.Future]] = []
        self._seq = 0
        self._actors = 0        # live actor coroutines
        self._parked = 0        # of those, currently awaiting a future
        self._pending = 0       # futures resolved, awaiter not yet resumed
        self._settle_rounds = settle_rounds

    # ---------------------------------------------------------- actor side
    def spawn(self, coro) -> asyncio.Task:
        """Run ``coro`` as a clock-tracked actor task."""
        async def _wrap():
            self._actors += 1
            try:
                await coro
            finally:
                self._actors -= 1
        return asyncio.get_running_loop().create_task(_wrap())

    async def sleep_until(self, t: float, prio: int = 1) -> None:
        """Park until virtual time ``t``; returns immediately if the
        clock is already there. ``prio`` breaks same-instant ties."""
        if t <= self.now + _EPS:
            return
        fut = asyncio.get_running_loop().create_future()
        self._seq += 1
        heapq.heappush(self._heap, (t, prio, self._seq, fut))
        await self._park(fut)

    def event(self) -> asyncio.Future:
        """A park-able future another actor resolves via :meth:`fire`
        (timeless wake: queue backpressure release)."""
        return asyncio.get_running_loop().create_future()

    async def wait(self, fut: asyncio.Future) -> None:
        await self._park(fut)

    def fire(self, fut: asyncio.Future) -> None:
        if not fut.done():
            self._pending += 1
            fut.set_result(None)

    async def _park(self, fut: asyncio.Future) -> None:
        self._parked += 1
        try:
            await fut
        finally:
            self._parked -= 1
            if fut.done() and not fut.cancelled():
                self._pending -= 1

    # --------------------------------------------------------- driver side
    def quiescent(self) -> bool:
        """Every live actor is parked and every resolved wake has been
        consumed — the current instant has fully played out."""
        return self._pending == 0 and self._parked == self._actors

    async def _settle(self) -> None:
        for _ in range(self._settle_rounds):
            await asyncio.sleep(0)
            if self.quiescent():
                return
        raise RuntimeError(
            "serve runtime failed to settle: an actor is spinning without "
            "parking on the virtual clock")

    async def advance_past(self, t_limit: float) -> None:
        """Play the world up to (but excluding) ``t_limit``: resolve
        every scheduled wake with ``t < t_limit`` in ``(t, prio, seq)``
        order, settling the loop between instants, then pin ``now`` at
        the boundary. Wakes at exactly ``t_limit`` belong to the next
        epoch — the driver decides the next plan first, matching the
        engine's strict ``ts < t1`` epoch attribution."""
        await self._settle()
        while self._heap and self._heap[0][0] < t_limit - _EPS:
            t = self._heap[0][0]
            self.now = t
            while self._heap and self._heap[0][0] <= t + _EPS:
                _, _, _, fut = heapq.heappop(self._heap)
                self.fire(fut)
            await self._settle()
        if t_limit != float("inf"):
            self.now = max(self.now, t_limit)
