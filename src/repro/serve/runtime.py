"""ServeRuntime: execute a compiled ScenarioSpec on real record streams.

The runtime is the engine's live twin: it takes the *same* ``build``
callable, profiles and :class:`~repro.scenario.engine.EngineConfig` a
``ScenarioSpec.compile()`` produces, but instead of replaying a cached
functional drive under a DES it runs the actual
:class:`~repro.pipeline.composition.Pipeline` operators on an asyncio
event loop in deterministic virtual time: farms publish real records,
stages fetch/fire through :class:`~repro.pipeline.adapters.StageAdapter`
with bounded-queue backpressure, placement is executed as routing
(serial gateway devices, uplink shaper, DC chip pool), and telemetry is
*measured* rather than simulated.

Interchangeability is the contract
(:class:`~repro.scenario.observe.ObservationSource`): ``info()`` hands
controllers the same :class:`~repro.scenario.observe.BridgeInfo`,
``run(controller)`` asks ``decide`` at every epoch boundary with a
measured :class:`~repro.scenario.observe.EpochObservation` — so an
:class:`~repro.online.controller.OnlineController` makes live
re-placement decisions mid-run and its
:class:`~repro.scenario.feedback.CalibrationLoop` trains on measured
residuals through the unchanged ``feedback`` API — and the result is
the same :class:`~repro.scenario.engine.EngineResult` (with ``dc=None``:
there is no DES to report).

What deliberately diverges from the engine (the measured sim-to-real
gap ``benchmarks/bench_serve.py`` quantifies):

* **Late data.** A fire's window is whatever has physically arrived at
  dispatch; the DES instead waits for upstream settlement.
* **Serial operators.** A stage is one operator instance; a fire that
  outlives the slide delays the next dispatch. The DES overlaps a
  service's DC fires freely.
* **Analytic DC.** DC fires are priced by the same roofline cells but
  run under a plain chip pool, not the JITA-4DS scheduler.
* **No clairvoyance.** ``rates_oracle`` falls back to the trailing
  measurement (first epoch: the controller's own prior of 1 rec/s);
  ``down_oracle`` still reads the *declared* outage schedule.
"""
from __future__ import annotations

import asyncio
import dataclasses
import math
from typing import (AsyncIterator, Callable, Dict, List, Mapping, Optional,
                    Sequence, Tuple)

import numpy as np

from repro.chaos.inject import ChaosTimeline, FaultObservation
from repro.chaos.migrate import plan_chaos_migrations
from repro.core.elastic import ServiceMigration
from repro.online.fleet import Fleet
from repro.pipeline.adapters import StageAdapter
from repro.pipeline.composition import Pipeline
from repro.placement.plan import SITE_DC, PlacementPlan
from repro.scenario.engine import (_SHARED_FIELDS, _FixedPlan, _infeasible,
                                   CoSimResult, EngineConfig, EngineResult,
                                   analytics_cost_model)
from repro.scenario.ledger import (RecordLedger, ServiceLedger, _topo_order,
                                   tap_pipeline)
from repro.scenario.observe import (BridgeInfo, EpochObservation, ServiceInfo,
                                    attach_forecast, epoch_bounds,
                                    merge_realized_vos)
from repro.scenario.profiles import ServiceProfile
from repro.serve.clock import VirtualClock
from repro.serve.metrics import ServeTelemetry
from repro.serve.router import PlacementRouter
from repro.serve.shaper import UplinkShaper
from repro.serve.stage import FarmDriver, ServiceStage

_EPS = 1e-9


@dataclasses.dataclass
class ServeConfig:
    """Serving-only knobs (everything physical comes from the shared
    ``EngineConfig``). ``stage_capacity`` bounds every stage-to-stage
    queue: a publishing stage parks until the downstream backlog drops
    below it (``None`` = unbounded, broker capacity is the only bound).
    ``shed_after_s`` drops a fire whose pre-start wait already exceeds
    the budget (records roll into the next window; ``None`` = never
    shed, the engine's behavior). ``settle_rounds`` caps event-loop
    passes per virtual instant before declaring a livelock."""
    stage_capacity: Optional[int] = None
    shed_after_s: Optional[float] = None
    settle_rounds: int = 200_000


class ServeRuntime:
    """Live serving twin of :class:`~repro.scenario.engine.ScenarioEngine`
    — same constructor shape, same controller contract, measured
    telemetry. Usually constructed via :func:`serve_scenario`."""

    def __init__(self, build: Callable[[], Pipeline],
                 profiles: Dict[str, ServiceProfile],
                 cfg: EngineConfig,
                 outages: Optional[Mapping[str, Sequence[Tuple[float, float]]]]
                 = None,
                 serve: Optional[ServeConfig] = None):
        self.build = build
        self.profiles = dict(profiles)
        self.cfg = cfg
        self.outages = {k: tuple(v) for k, v in (outages or {}).items()}
        self.serve = serve or ServeConfig()
        pipe = build()
        self.topology = pipe.topology()
        names = [s.cfg.name for s in pipe.services]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate service names: {names}")
        missing = set(self.topology) - set(self.profiles)
        if missing:
            raise ValueError(f"no ServiceProfile for {sorted(missing)}")
        self.order = _topo_order(self.topology, names)
        self.rank = {s: i for i, s in enumerate(self.order)}
        self.cost = analytics_cost_model(self.profiles, cfg)
        self.services_info = {
            s.cfg.name: ServiceInfo(queue=s.cfg.queue,
                                    slide_s=s.cfg.window.slide_s,
                                    width_s=s.cfg.window.width_s,
                                    buffer_budget=s.cfg.buffer_budget)
            for s in pipe.services}
        self.epoch_s = cfg.epoch_s or cfg.horizon_s
        self.epochs = epoch_bounds(cfg.horizon_s, cfg.epoch_s)
        self._fresh_pipe: Optional[Pipeline] = pipe
        self._result: Optional[EngineResult] = None
        self.last_telemetry: Optional[ServeTelemetry] = None

    # ----------------------------------------------------------- bridging
    @property
    def all_sites(self) -> Tuple[str, ...]:
        return tuple(self.cfg.fleet.site_names) + (SITE_DC,)

    def info(self) -> BridgeInfo:
        return BridgeInfo(topology=self.topology, profiles=self.profiles,
                          fleet=self.cfg.fleet, services=self.services_info,
                          cost=self.cost,
                          grid_chips=(self.cfg.grid_shape[0]
                                      * self.cfg.grid_shape[1]),
                          epoch_s=self.epoch_s,
                          records_per_step=self.cfg.records_per_step,
                          outages=self.outages)

    def _site_ram_ok(self, plan: PlacementPlan) -> Optional[str]:
        for name in self.cfg.fleet.site_names:
            spec = self.cfg.fleet.site(name).edge
            budget = sum(self.services_info[s].buffer_budget
                         for s in self.order if plan.site(s) == name)
            if spec.ram_required(budget) > spec.ram_bytes:
                return (f"site {name} RAM: buffer budgets need "
                        f"{spec.ram_required(budget)/2**20:.0f} MiB, device "
                        f"has {spec.ram_bytes/2**20:.0f} MiB")
        return None

    def _state_bytes(self, svc: str) -> float:
        return (self.services_info[svc].buffer_budget
                * self.cfg.state_bytes_per_record)

    # ---------------------------------------------------------------- run
    def run(self, controller) -> EngineResult:
        """Serve one plan schedule end-to-end; returns the same result
        type the engine returns (``dc=None``)."""
        async def _drive():
            async for _ in self.iter_epochs(controller):
                pass
            return self._result
        return asyncio.run(_drive())

    def run_plan(self, plan: PlacementPlan,
                 label: Optional[str] = None) -> CoSimResult:
        """One fixed plan for the whole horizon (the engine's
        single-plan surface, served live)."""
        plan.validate(self.topology,
                      grid_chips=self.cfg.grid_shape[0]
                      * self.cfg.grid_shape[1],
                      sites=self.all_sites)
        bad = self._site_ram_ok(plan)
        if bad is not None:
            return _infeasible(plan, bad)
        res = self.run(_FixedPlan(plan, label=label or plan.label))
        return CoSimResult(plan_label=label or plan.label, feasible=True,
                           **{k: getattr(res, k) for k in _SHARED_FIELDS})

    async def iter_epochs(self, controller) -> AsyncIterator[Dict]:
        """Iterator-first serving: set up the live world, yield one
        epoch record per boundary (after the controller's re-placement
        decision has been applied and the epoch has been served), then
        drain in-flight fires and score. After exhaustion the full
        :class:`EngineResult` is available via ``run``'s return or
        ``self._result``."""
        cfg = self.cfg
        pipe, self._fresh_pipe = self._fresh_pipe or self.build(), None
        staps, qtaps = tap_pipeline(pipe)
        clock = VirtualClock(settle_rounds=self.serve.settle_rounds)
        timeline = (ChaosTimeline.compile(
            cfg.chaos, cfg.fleet.site_names, cfg.horizon_s, self.epochs)
            if cfg.chaos is not None else None)
        fleet = Fleet(cfg.fleet, self.outages, chaos=timeline)
        self._duplicates: Dict[str, int] = {}
        link_snap = {s: (0.0, 0) for s in cfg.fleet.site_names}
        link_secs: List[Dict[str, float]] = []
        shaper = UplinkShaper(fleet)
        router = PlacementRouter(
            cost=self.cost,
            grid_chips=cfg.grid_shape[0] * cfg.grid_shape[1],
            records_per_step=cfg.records_per_step,
            state_bytes=self._state_bytes,
            ship_state=shaper.ship_state,
            warmup_s=cfg.migration_warmup_s)
        telemetry = ServeTelemetry(
            self.order,
            {s: self.services_info[s].slide_s for s in self.order},
            self.epochs, cfg.horizon_s)
        self.last_telemetry = telemetry     # inspectable after the run
        dl_user = fleet.downlink_time(cfg.fleet.result_site)

        def origin_site(origin: Optional[str], consumer: str,
                        epoch: int) -> str:
            if origin is None:
                return cfg.fleet.farm_site(self.services_info[consumer].queue)
            return router.site(origin, epoch)

        stages: Dict[str, ServiceStage] = {}
        for svc_obj in pipe.services:
            name = svc_obj.cfg.name
            adapter = StageAdapter(svc_obj, qtaps[name], staps[name])
            stages[name] = ServiceStage(
                adapter, self.rank[name], self.profiles[name], clock,
                router, shaper, telemetry, fleet, self.epochs,
                cfg.horizon_s, origin_site, cfg.fleet.result_site, dl_user,
                stage_capacity=self.serve.stage_capacity,
                shed_after_s=self.serve.shed_after_s)
        # wire downstream consumers: services fed by a queue some
        # upstream stage's sink republishes into
        for up, q in pipe.edges:
            for svc_obj in pipe.services:
                if svc_obj.cfg.queue == q:
                    stages[up].consumers.append(stages[svc_obj.cfg.name])

        step = cfg.drive_step_s or min(self.services_info[s].slide_s
                                       for s in self.order)
        tasks = [clock.spawn(FarmDriver(farm, clock, cfg.horizon_s,
                                        step).run())
                 for farm in pipe.farms]
        tasks += [clock.spawn(stages[s].run()) for s in self.order]

        charge = getattr(controller, "charge_migrations", True)
        bind = getattr(controller, "bind", None)
        if bind is not None:
            bind(self.info())

        epoch_meta: List[Dict] = []
        n_migs = 0
        rates_window: List[Dict[str, float]] = []
        try:
            for k, (t0, t1) in enumerate(self.epochs):
                obs = EpochObservation(
                    epoch=k, t0=t0, t1=t1,
                    rates_window=list(rates_window),
                    realized_window=telemetry.realized_upto(k),
                    down_now={s: fleet.site(s).failed_at(t0)
                              for s in cfg.fleet.site_names},
                    rates_oracle=(dict(rates_window[-1]) if rates_window
                                  else {s: 1.0 for s in self.order}),
                    down_oracle={s: any(d < t1 and u > t0
                                        for d, u in fleet.site(s).outages)
                                 for s in cfg.fleet.site_names},
                    partitioned_now={s: fleet.site(s).partitioned_at(t0)
                                     for s in cfg.fleet.site_names},
                    link_secs_window=[dict(d) for d in link_secs])
                plan = controller.decide(obs)
                plan.validate(self.topology,
                              grid_chips=cfg.grid_shape[0]
                              * cfg.grid_shape[1],
                              sites=self.all_sites)
                bad = self._site_ram_ok(plan)
                if bad is not None:
                    raise ValueError(f"epoch {k}: infeasible plan from "
                                     f"{type(controller).__name__}: {bad}")
                migs: List[ServiceMigration] = router.push_plan(
                    plan, t0, charge=charge)
                n_migs += len(migs)

                # mid-epoch chaos reaction: cut the epoch at realized
                # fault boundaries so a chaos-aware controller can push
                # an emergency plan (fires dispatched after the push
                # route under it); the controller sees only the realized
                # world, never the fault schedule
                chaos_log: List[Dict] = []
                react = (timeline is not None
                         and getattr(controller, "decide_fault", None)
                         is not None)
                for T in (timeline.boundaries(t0, t1) if react else []):
                    await clock.advance_past(T)
                    fobs = FaultObservation(
                        t=T, epoch=k,
                        down_now={s: fleet.site(s).failed_at(T)
                                  for s in cfg.fleet.site_names},
                        partitioned_now={s: fleet.site(s).partitioned_at(T)
                                         for s in cfg.fleet.site_names},
                        straggle_now={s: fleet.site(s).straggle_factor(T)
                                      for s in cfg.fleet.site_names},
                        events=timeline.events_at(T))
                    plan2 = controller.decide_fault(fobs)
                    if plan2 is None:
                        continue
                    entry = self._adopt_replan(
                        plan2, T, k, fobs, charge, router, fleet, shaper,
                        telemetry,
                        rates_window[-1] if rates_window else {})
                    chaos_log.append(entry)
                    n_migs += len(entry["migrations"])

                await clock.advance_past(t1)
                # close the epoch's uplink telemetry window: mean
                # serialization seconds per transfer at each site
                window: Dict[str, float] = {}
                for s in cfg.fleet.site_names:
                    site = fleet.site(s)
                    b0, n0 = link_snap[s]
                    db = site.link_busy_s - b0
                    dn = site.link_transfers - n0
                    link_snap[s] = (site.link_busy_s, site.link_transfers)
                    window[s] = db / dn if dn > 0 else 0.0
                link_secs.append(window)
                rates_window.append(telemetry.measured_rates(k))
                meta = {
                    "epoch": k, "t0": t0, "t1": t1, "plan": plan.label,
                    "migrations": [
                        {"service": m.service, "src": m.src, "dst": m.dst,
                         "stall_s": round(m.stall_s, 3)} for m in migs],
                    "rates_measured": {s: round(r, 6) for s, r
                                       in rates_window[-1].items()},
                }
                if chaos_log:
                    meta["chaos"] = chaos_log
                attach_forecast(controller, k, meta)
                epoch_meta.append(meta)
                yield meta

            # ---- drain: finish in-flight fires past the horizon ---------
            for _ in range(len(self.order) + 2):
                await clock.advance_past(float("inf"))
                if all(t.done() for t in tasks):
                    break
                for st in stages.values():   # chained backpressure parks
                    st.notify_fetch()
            await asyncio.gather(*tasks)
        finally:
            for t in tasks:
                if not t.done():
                    t.cancel()
            await asyncio.gather(*tasks, return_exceptions=True)

        self._result = self._score(pipe, staps, qtaps, fleet, router,
                                   telemetry, epoch_meta, n_migs, controller)

    # ---------------------------------------------------------- chaos path
    def _adopt_replan(self, plan: PlacementPlan, T: float, k: int,
                      fobs, charge: bool, router: PlacementRouter,
                      fleet: Fleet, shaper, telemetry: ServeTelemetry,
                      rates_k: Dict[str, float]) -> Dict:
        """Adopt an emergency mid-epoch plan at time ``T`` with the
        checkpoint-aware live/cold migration semantics (the serve twin
        of ``ScenarioEngine._adopt_replan``: measured fire counts stand
        in for the DES fire trace)."""
        plan.validate(self.topology,
                      grid_chips=self.cfg.grid_shape[0]
                      * self.cfg.grid_shape[1],
                      sites=self.all_sites)
        bad = self._site_ram_ok(plan)
        if bad is not None:
            raise ValueError(f"epoch {k}: infeasible fault re-plan: {bad}")
        chaos = self.cfg.chaos
        ck = max(1, chaos.checkpoint_every)
        old = router.plans[-1]

        def _replay_records(svc: str) -> int:
            fires = telemetry.fires[svc]
            i_t = len(fires)
            return sum(f.n_new for f in fires[(i_t // ck) * ck:i_t])

        def _replay_time(svc: str, n: int, dst: str) -> float:
            if dst == SITE_DC:
                return router.dc_cost(svc, n, plan.placement(svc))[0]
            return fleet.site(dst).node.fire_time(
                n, self.profiles[svc].flops_per_record)

        def _drain(svc: str) -> float:
            src = old.site(svc)
            if src == SITE_DC:
                return 0.0
            return max(0.0, fleet.site(src).node.busy_until - T)

        def _src_dead(s: str) -> bool:
            if s == SITE_DC:
                return False
            site = fleet.site(s)
            return site.crashed_at(T) or site.partitioned_at(T)

        def _local_origin(svc: str, dst: str) -> bool:
            return (not self.topology[svc]
                    and self.cfg.fleet.farm_site(
                        self.services_info[svc].queue) == dst)

        def _ckpt_bytes(svc: str) -> float:
            return (self.services_info[svc].buffer_budget
                    * chaos.checkpoint_bytes_per_record)

        migs = plan_chaos_migrations(
            chaos, old.assignments, plan.assignments, T,
            src_dead=_src_dead, ship=shaper.ship_state,
            state_bytes=self._state_bytes, ckpt_bytes=_ckpt_bytes,
            replay_records=_replay_records, replay_time=_replay_time,
            rate_rps=lambda svc: rates_k.get(svc, 0.0),
            drain_s=_drain, dc_site=SITE_DC, local_origin=_local_origin,
            warmup_s=self.cfg.migration_warmup_s, charge=charge)
        for m in migs:
            if m.duplicates:
                self._duplicates[m.service] = (
                    self._duplicates.get(m.service, 0) + m.duplicates)
        router.push_plan(plan, T, charge=charge, epoch=k, migrations=migs)
        return {"t": round(T, 6), "plan": plan.label,
                "trigger": list(fobs.events),
                "migrations": [m.digest() for m in migs]}

    # -------------------------------------------------------------- score
    def _score(self, pipe, staps, qtaps, fleet: Fleet,
               router: PlacementRouter, telemetry: ServeTelemetry,
               epoch_meta: List[Dict], n_migs: int,
               controller) -> EngineResult:
        vos = max_vos = 0.0
        latencies: List[float] = []
        completed = dropped = inflight = 0
        dc_energy = 0.0
        ep_vos = [0.0] * len(self.epochs)
        per_service: Dict[str, Dict] = {}
        for svc in self.order:
            prof = self.profiles[svc]
            s_lat: List[float] = []
            s_done = s_drop = s_wait = 0
            for f in telemetry.fires[svc]:
                max_vos += prof.slo.max_value
                if f.done:
                    s_done += 1
                    s_lat.append(f.lat_s)
                    if f.site == SITE_DC:
                        dc_energy += f.energy_j
                elif f.shed:
                    s_drop += 1
                else:
                    s_wait += 1
                ep_vos[f.epoch] += f.value
                vos += f.value
            completed += s_done
            dropped += s_drop
            inflight += s_wait
            latencies.extend(s_lat)
            per_service[svc] = {
                "site": router.plans[-1].placement(svc).label
                if router.plans else "",
                "fires": len(telemetry.fires[svc]), "completed": s_done,
                "dropped": s_drop, "inflight": s_wait,
                "vos": round(sum(f.value for f in telemetry.fires[svc]), 4),
                "latency_p95": round(float(np.percentile(s_lat, 95)), 4)
                if s_lat else float("nan"),
            }
        merge_realized_vos(epoch_meta, ep_vos)

        ledger, per_site = self._ledger(pipe, staps, qtaps, fleet, telemetry)
        lat = (np.asarray(latencies) if latencies
               else np.asarray([float("nan")]))
        p50, p95, p99 = np.percentile(lat, (50, 95, 99))
        return EngineResult(
            label=getattr(controller, "label", type(controller).__name__),
            vos=vos, vos_normalized=vos / max(max_vos, 1e-6),
            fires_total=sum(len(fl) for fl in telemetry.fires.values()),
            fires_completed=completed, fires_dropped=dropped,
            fires_inflight=inflight,
            latency_p50=float(p50), latency_p95=float(p95),
            latency_p99=float(p99),
            edge_energy_j=fleet.edge_energy_j,
            network_energy_j=fleet.network_energy_j,
            dc_energy_j=dc_energy,
            bytes_up=fleet.bytes_up, bytes_down=fleet.bytes_down,
            uplink_wait_s=fleet.uplink_wait_s,
            uplink_transfers=fleet.uplink_transfers,
            migrations=n_migs, ledger=ledger, per_site=per_site,
            per_service=per_service, epochs=epoch_meta, dc=None)

    def _ledger(self, pipe, staps, qtaps, fleet: Fleet,
                telemetry: ServeTelemetry
                ) -> Tuple[RecordLedger, Dict[str, Dict]]:
        """Same conservation schema as the engine, from the live taps:
        identity partitions over what the runtime actually published,
        dropped, fetched and covered. Fires that never ran (shed, or
        truncated by a crash) claim nothing — their records stay in the
        ``buffered``/``unread`` buckets, so the ledger still conserves."""
        ledger = RecordLedger()
        site_processed: Dict[str, int] = {s: 0
                                          for s in self.cfg.fleet.site_names}
        site_processed[SITE_DC] = 0
        for svc_obj in pipe.services:
            name = svc_obj.cfg.name
            tap, qtap = staps[name], qtaps[name]
            fetched_ids = set(qtap.fetched.get(name, {}))
            covered_ids = set(tap.covered)
            buf_ids = set(map(id, svc_obj.buffer))
            drop_ids = set(map(id, qtap.drop_refs))
            evicted_unc = fetched_ids - buf_ids - covered_ids
            sl = ServiceLedger(
                service=name, queue=svc_obj.cfg.queue,
                produced=len(qtap.pub_refs),
                overflow=len(drop_ids - fetched_ids),
                unread=len(set(map(id, svc_obj.q.buf)) - fetched_ids),
                fetched=len(fetched_ids),
                buffered=len(buf_ids - covered_ids),
                **{("evicted_stored" if svc_obj.cfg.store is not None
                    else "evicted_lost"): len(evicted_unc)})
            sl.duplicates = getattr(self, "_duplicates", {}).get(name, 0)
            for f in telemetry.fires[name]:
                if not f.done:
                    continue        # shed/unfired: records roll or buffer
                if f.site != SITE_DC:
                    sl.processed_edge += f.n_new
                    site_processed[f.site] += f.n_new
                else:
                    sl.processed_dc += f.n_new
                    site_processed[SITE_DC] += f.n_new
            ledger.services[name] = sl
        per_site = fleet.per_site_energy()
        for s, n in site_processed.items():
            per_site.setdefault(s, {})["records_processed"] = n
        return ledger, per_site


def serve_scenario(spec, calibrator=None,
                   serve: Optional[ServeConfig] = None) -> ServeRuntime:
    """``ScenarioSpec`` → live runtime — the serving counterpart of
    ``spec.compile()``: same validation, same profiles (optionally
    kernel-calibrated), same engine config; only the execution substrate
    differs."""
    spec.validate()
    if calibrator is not None:
        from repro.scenario.calibrate import calibrate_profiles
        profiles, _ = calibrate_profiles(spec, calibrator)
    else:
        profiles = spec.profiles()
    return ServeRuntime(spec.build_pipeline, profiles, spec.engine_config(),
                        outages=spec.outage_map(), serve=serve)
