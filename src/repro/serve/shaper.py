"""Uplink shaping: the serving runtime's cross-site byte movement.

Placement-as-routing means a DC-placed stage's inputs go through an
uplink shaper and an edge-placed stage's remote inputs are hauled
between gateways. The shaper delegates every transfer to the *same*
:class:`~repro.online.fleet.Fleet` physical models the DES uses — the
shared :class:`~repro.online.fleet.ContendedUplink` FIFO, per-site
:class:`~repro.placement.network.NetworkModel` byte/energy accounting —
so a measured byte costs exactly what a simulated byte costs. The only
difference is *when* admissions happen: the runtime's stages reach the
shaper at their virtual-time instants (the serving analogue of the
engine's causal cursor), so FIFO admission order is the order stages
actually offload.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

from repro.online.fleet import Fleet
from repro.placement.plan import SITE_DC


class UplinkShaper:
    def __init__(self, fleet: Fleet):
        self.fleet = fleet

    def ship_inputs(self, origins: Dict[Optional[str], int],
                    origin_site: Callable[[Optional[str]], str],
                    dst: str, base: float) -> float:
        """Arrival time at ``dst`` of a fire's newly covered records
        that live on other sites (mirrors the engine's input haul:
        per-source-site grouped transfers, DC-origin results ride the
        result hop instead of re-shipping)."""
        groups: Dict[str, int] = {}
        for o, c in origins.items():
            so = origin_site(o)
            if so == dst or so == SITE_DC or c == 0:
                continue
            groups[so] = groups.get(so, 0) + c
        t = base
        for so in sorted(groups):
            t = max(t, self.fleet.ship_records(so, dst, groups[so], base))
        return t

    def result_arrival(self, src: str, dst: str, ready_out: float) -> float:
        """When one completed aggregate becomes visible on ``dst``
        (mirrors the engine's result hop: free to the same site, rides
        the consumer's record uplink to the DC, downlink from the DC,
        FIFO-contended uplink between gateways)."""
        if src == dst or dst == SITE_DC:
            return ready_out
        if src == SITE_DC:
            return ready_out + self.fleet.downlink_time(dst)
        return self.fleet.ship_result(src, dst, ready_out)

    def ship_state(self, src: str, dst: str, nbytes: float,
                   t0: float) -> float:
        """Migration state transfer (arrival time); contends the shared
        uplink like any transfer."""
        return self.fleet.ship_state(src, dst, nbytes, t0)

    def result_downlink(self, result_site: str) -> None:
        """Account one completed DC aggregate surfacing at the user's
        site (one downlink record, as the engine books per DC fire)."""
        self.fleet.site(result_site).net.downlink(1)
