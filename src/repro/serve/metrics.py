"""Measured telemetry for the serving runtime.

The runtime must feed the *same* consumers the engine feeds — the
controller's :class:`~repro.scenario.observe.EpochObservation` and the
calibration loop's realized-residual schema — but from measurement, not
simulation:

  rates_window      newly covered records/s per completed epoch, summed
                    at fire *dispatch* (so a boundary snapshot includes
                    fires whose execution is still in flight)
  realized_window   per-service {vos, completed, dropped, inflight,
                    lat_mean_s} per completed epoch, frozen at the first
                    boundary after the epoch (identical freezing rule to
                    the engine's, so the calibration loop sees one
                    schema from either source)

The fire grid is precomputed from each service's slide — the runtime
knows every fire it will ever dispatch — so an epoch snapshot can count
not-yet-dispatched fires (a stage lagging behind its schedule) as
``inflight`` instead of silently missing them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.scenario.observe import epoch_of

_EPS = 1e-9


@dataclasses.dataclass
class StageFire:
    """One scheduled fire of one service, updated as it moves through
    the serving lifecycle: scheduled -> dispatched -> done | shed."""
    svc: str
    idx: int
    ts: float
    epoch: int
    state: str = "scheduled"
    site: str = ""                   # routing site at dispatch (e.g. "dc")
    n_window: int = 0
    n_new: int = 0
    backlog: int = 0                 # input backlog observed at dispatch
    value: float = 0.0
    lat_s: float = float("nan")
    energy_j: float = 0.0

    @property
    def done(self) -> bool:
        return self.state == "done"

    @property
    def shed(self) -> bool:
        return self.state == "shed"


class ServeTelemetry:
    def __init__(self, order: Sequence[str],
                 slides: Dict[str, float],
                 bounds: Sequence[Tuple[float, float]],
                 horizon_s: float):
        self.order = list(order)
        self.bounds = list(bounds)
        self.fires: Dict[str, List[StageFire]] = {}
        for svc in self.order:
            grid: List[StageFire] = []
            t = slides[svc]
            while t <= horizon_s:       # same accumulation as run_until
                grid.append(StageFire(svc=svc, idx=len(grid), ts=t,
                                      epoch=epoch_of(bounds, t)))
                t += slides[svc]
            self.fires[svc] = grid
        self._realized: List[Dict[str, Dict]] = []

    # ------------------------------------------------------------ lifecycle
    def on_dispatch(self, svc: str, idx: int, site: str,
                    n_window: int, n_new: int, backlog: int = 0) -> None:
        f = self.fires[svc][idx]
        f.state, f.site = "dispatched", site
        f.n_window, f.n_new, f.backlog = n_window, n_new, backlog

    def on_done(self, svc: str, idx: int, value: float, lat_s: float,
                energy_j: float) -> None:
        f = self.fires[svc][idx]
        f.state, f.value, f.lat_s, f.energy_j = "done", value, lat_s, energy_j

    def on_shed(self, svc: str, idx: int) -> None:
        self.fires[svc][idx].state = "shed"

    # ----------------------------------------------------------- per epoch
    def measured_rates(self, epoch: int) -> Dict[str, float]:
        """Covered-records/s per service over one completed epoch, from
        dispatch-time measurements. The live analogue of the engine's
        drive-derived ``true_epoch_rates`` — minus clairvoyance: fires a
        lagging stage has not dispatched yet contribute nothing."""
        t0, t1 = self.bounds[epoch]
        dur = max(t1 - t0, _EPS)
        return {svc: sum(f.n_new for f in grid
                         if f.epoch == epoch and f.state != "scheduled")
                / dur
                for svc, grid in self.fires.items()}

    def residuals(self, epoch: int) -> Dict[str, Dict]:
        """Per-service realized residuals of one epoch as measured now —
        same keys and rounding as the engine's epoch residuals."""
        out = {s: {"vos": 0.0, "completed": 0, "dropped": 0,
                   "inflight": 0, "lat_mean_s": float("nan"),
                   "_lat_sum": 0.0}
               for s in self.order}
        for svc, grid in self.fires.items():
            d = out[svc]
            for f in grid:
                if f.epoch != epoch:
                    continue
                if f.done:
                    d["completed"] += 1
                    d["vos"] += f.value
                    d["_lat_sum"] += f.lat_s
                elif f.shed:
                    d["dropped"] += 1
                else:
                    d["inflight"] += 1
        for d in out.values():
            if d["completed"]:
                d["lat_mean_s"] = d["_lat_sum"] / d["completed"]
            del d["_lat_sum"]
            d["vos"] = round(d["vos"], 6)
        return out

    def realized_upto(self, upto_epoch: int) -> List[Dict[str, Dict]]:
        """Frozen residual snapshots for every epoch < ``upto`` —
        materialized exactly once at the first boundary after each epoch
        completes (the engine's freezing rule), so the calibration loop
        reads a one-pass deterministic feed."""
        while len(self._realized) < upto_epoch:
            self._realized.append(self.residuals(len(self._realized)))
        return [{s: dict(d) for s, d in per.items()}
                for per in self._realized[:upto_epoch]]
