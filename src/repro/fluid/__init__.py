"""Batched fluid-approximation scenario engine (pure JAX).

The exact DES scores one (plan, drift-trace) pair per Python event
loop; this package lowers a compiled scenario to padded dense arrays
and evaluates *ensembles* — N drift realizations × M plan candidates —
in one jitted ``lax.scan``, returning per-(realization, plan)
VoS / latency / drop trajectories. On top of it sit distributionally
robust risk metrics (mean / CVaR / worst-quantile VoS) used by
``repro.placement.search.robust_search`` and
``OnlineController(risk=...)``.

The DES remains ground truth: the fluid tier ranks, the DES re-scores
survivors (the same two-tier contract the numpy screen established).
"""
from repro.fluid.engine import FluidEngine, FluidResult
from repro.fluid.ensemble import ScenarioEnsemble, sample_specs
from repro.fluid.robust import (RiskSpec, calibration_prior, ensemble_spread,
                                rank_plans, risk_score)

__all__ = [
    "FluidEngine", "FluidResult", "ScenarioEnsemble", "sample_specs",
    "RiskSpec", "risk_score", "rank_plans", "ensemble_spread",
    "calibration_prior",
]
