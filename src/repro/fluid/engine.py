"""Fixed-step fluid approximation of the scenario DES, batched in JAX.

``FluidEngine.compile(spec_or_engine)`` lowers one compiled scenario —
the placement-independent fire trace (timestamps, window sizes, origin
record counts), the per-site device/link specs, the per-service SLO
value curves, and the DC roofline cells — into padded dense arrays.
``evaluate`` then runs a ``lax.scan`` time-stepper vmapped over BOTH
batch axes (drift realizations × plan candidates) in a single jitted
call.

The fluid model mirrors ``ScreeningModel``'s per-fire cost terms
(duration, energy, uplink serialization, rank blocking, DC composition
pressure, migration stalls from ``core/elastic.py``'s charge model) but
replaces the screen's *stateless* queueing knee on edge devices with an
explicit per-site backlog recursion over time bins of width ``dt``
(default: the minimum service slide, so at most one fire per service
per bin):

    lat(fire of s in bin k) = B[site, k] + rank_wait + dur + hop + haul
    B[site, k+1] = max(0, B[site, k] + Σ dur·fires − dt·(1 − down_frac))

which reproduces the DES's transient saturation behaviour (growing,
draining and oscillating backlogs) that a horizon-averaged utilization
knee cannot. The shared-uplink FIFO gets the same treatment (a scalar
backlog plus the classic knee below saturation). Site outages reduce
bin service capacity and defer fires to recovery.

Everything here is deterministic array math — randomness lives in the
*inputs* (the sampled realization modulations built by
``repro.fluid.ensemble``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.placement.plan import PlacementPlan
from repro.region.hier import regions_view
from repro.scenario.queueing import q_factor_jnp

# Uplink utilization is clamped here before the queueing knee: overload
# beyond the clamp surfaces as *backlog* (unbounded wait growth over
# bins), not as an instantaneous NEVER_S cliff, matching the DES's FIFO
# pipe where early fires during an overload still complete.
_UPLINK_Q_CLAMP = 0.92


@dataclasses.dataclass
class FluidResult:
    """Per-(realization, plan) trajectories from one ensemble call.

    ``vos[n, m]`` is the fluid VoS estimate of plan ``m`` under drift
    realization ``n`` (``-inf`` for site-RAM-infeasible plans);
    ``vos_service``/``vos_t`` split it per service / per time bin,
    ``lat_mean`` is the fire-weighted mean latency per service, and
    ``drop_frac``/``drop_t`` count zero-value fires (the fluid analogue
    of drops)."""
    vos: np.ndarray            # [N, M]
    vos_service: np.ndarray    # [N, M, S]
    vos_t: np.ndarray          # [N, M, T]
    lat_mean: np.ndarray       # [N, M, S]
    drop_frac: np.ndarray      # [N, M]
    drop_t: np.ndarray         # [N, M, T]
    feasible: np.ndarray       # [M] bool
    order: List[str]
    t_bins: np.ndarray         # [T] bin start times (s)
    max_vos: float             # Σ gamma·fires — normalization denominator

    @property
    def n_realizations(self) -> int:
        return self.vos.shape[0]

    @property
    def n_plans(self) -> int:
        return self.vos.shape[1]


class FluidEngine:
    """Compiled fluid twin of one :class:`ScenarioEngine`.

    Shares the engine's (already driven) fire trace, so compiling is
    cheap; the first ``evaluate`` of a given (N, M) batch shape pays the
    XLA trace, subsequent calls reuse it.
    """

    def __init__(self, engine, dt_s: Optional[float] = None):
        engine._ensure_driven()
        _, staps, _ = engine._driven
        cfg = engine.cfg
        self.engine = engine
        self.order: List[str] = list(engine.order)
        self.rank = {s: i for i, s in enumerate(self.order)}
        self.topology = engine.topology
        S = len(self.order)
        self.horizon_s = float(cfg.horizon_s)
        self.grid_chips = float(cfg.grid_shape[0] * cfg.grid_shape[1])
        self.records_per_step = float(cfg.records_per_step)

        fleet = cfg.fleet
        self.site_names: List[str] = list(fleet.site_names)
        self._site_idx = {n: j for j, n in enumerate(self.site_names)}
        J = len(self.site_names)
        edges = [fleet.site(n).edge for n in self.site_names]
        links = [fleet.site(n).link for n in self.site_names]
        self._thr = np.array([e.throughput_rps for e in edges])
        self._fps = np.array([e.flops_per_s for e in edges])
        self._ovh = np.array([e.fire_overhead_s for e in edges])
        self._epr = np.array([e.energy_per_record_j for e in edges])
        self._apw = np.array([e.active_power_w for e in edges])
        self._ram = np.array([e.ram_bytes for e in edges])
        self._ram_rec = np.array([e.record_bytes for e in edges])
        self._rtt = np.array([ln.rtt_s for ln in links])
        self._up_bps = np.array([ln.uplink_bps for ln in links])
        self._dn_bps = np.array([ln.downlink_bps for ln in links])
        self._wire_rec = np.array([ln.record_bytes * ln.compression
                                   for ln in links])
        self._dn_rec = np.array([ln.record_bytes for ln in links])
        user = self._site_idx[fleet.result_site]
        self.dl_user_s = (links[user].rtt_s / 2
                          + links[user].result_bytes
                          / links[user].downlink_bps)

        # hierarchy: per-region edge tiers + RAP trunks. ``_hier`` is a
        # *trace-time* flag: flat fleets take the original scalar-backlog
        # program (byte-identical XLA — recorded fluid benchmarks stay
        # exact), hierarchical ones a per-region [R]-vector twin.
        regions = regions_view(fleet)
        self.n_regions = len(regions)
        rmap = {s: i for i, r in enumerate(regions) for s in r.sites}
        self._region_of = np.array([rmap[n] for n in self.site_names],
                                   dtype=int)
        self._rap = [None if r.transparent else r.rap for r in regions]
        self._hier = any(r is not None for r in self._rap)
        self._rap_res_up = np.zeros(J)
        self._rap_res_dn = np.zeros(J)
        for j in range(J):
            rap = self._rap[self._region_of[j]]
            if rap is not None:
                self._rap_res_up[j] = (rap.rtt_s / 2
                                       + links[j].result_bytes
                                       / rap.uplink_bps)
                self._rap_res_dn[j] = (rap.rtt_s / 2
                                       + links[j].result_bytes
                                       / rap.downlink_bps)
        rap_u = self._rap[self._region_of[user]]
        if rap_u is not None:
            self.dl_user_s += (rap_u.rtt_s / 2
                               + links[user].result_bytes
                               / rap_u.downlink_bps)

        # Per-service static facts -------------------------------------
        self.slide = np.empty(S)
        self.width = np.empty(S)
        self.budget = np.empty(S)
        self.flops = np.empty(S)
        self.farm_site = np.empty(S, dtype=int)
        self.queue_of: List[str] = []
        self.gamma = np.empty(S)
        self.wp = np.empty(S)
        self.we = np.empty(S)
        self.p_soft = np.empty(S)
        self.p_hard = np.empty(S)
        self.e_soft = np.empty(S)
        self.e_hard = np.empty(S)
        self.is_exp = np.zeros(S)
        self.is_root = np.zeros(S)
        self._ups: List[List[str]] = []
        for si, s in enumerate(self.order):
            prof = engine.profiles[s]
            info = engine.services_info[s]
            spec = prof.slo.value_spec()
            self.slide[si] = float(info.slide_s)
            self.width[si] = float(info.width_s)
            self.budget[si] = float(info.buffer_budget)
            self.flops[si] = float(prof.flops_per_record)
            self.farm_site[si] = self._site_idx[fleet.farm_site(info.queue)]
            self.queue_of.append(info.queue)
            self.gamma[si] = spec.gamma
            self.wp[si] = spec.w_p
            self.we[si] = spec.w_e
            self.p_soft[si] = spec.perf_curve.th_soft
            self.p_hard[si] = spec.perf_curve.th_hard
            self.e_soft[si] = spec.energy_curve.th_soft
            self.e_hard[si] = spec.energy_curve.th_hard
            self.is_exp[si] = 1.0 if prof.slo.shape == "exponential" else 0.0
            ups = list(self.topology[s])
            self._ups.append(ups)
            self.is_root[si] = 1.0 if not ups else 0.0

        self.dt = float(dt_s if dt_s is not None else self.slide.min())
        if self.dt <= 0:
            raise ValueError("fluid bin width must be positive")
        self.T = int(math.floor(self.horizon_s / self.dt + 1e-9)) + 1
        self.t_bins = np.arange(self.T) * self.dt

        # Bin the placement-independent fire trace ---------------------
        self.U = 1 + max((len(u) for u in self._ups), default=0)
        T, U = self.T, self.U
        self.fires = np.zeros((T, S))
        nw_sum = np.zeros((T, S))
        orig_sum = np.zeros((T, S, U))
        for si, s in enumerate(self.order):
            keys = [None] + self._ups[si]
            for f in staps[s].fires:
                k = min(int(f.ts / self.dt + 1e-9), T - 1)
                self.fires[k, si] += 1.0
                nw_sum[k, si] += f.n_window
                for ui, okey in enumerate(keys):
                    orig_sum[k, si, ui] += f.origins.get(okey, 0)
        cnt = np.maximum(self.fires, 1.0)
        self.nw = nw_sum / cnt           # per-fire mean window size
        self.orig = orig_sum / cnt[:, :, None]   # per-fire origin counts
        self.total_orig = orig_sum.sum(axis=0)   # [S, U] trace totals
        self.fires_total = self.fires.sum(axis=0)
        self.max_vos = float((self.gamma * self.fires_total).sum())

        # earlier-rank alignment factors (screen's rank-blocking term)
        self.align_rank = np.zeros((S, S))
        for si in range(S):
            for oi in range(si):
                self.align_rank[si, oi] = min(
                    1.0, self.slide[si] / self.slide[oi])

        self._sim_jit = None
        self._sim_eager = None

    # ------------------------------------------------------------------
    @classmethod
    def compile(cls, source, dt_s: Optional[float] = None) -> "FluidEngine":
        """Lower a ``ScenarioSpec`` (compiled on the spot) or an
        already-compiled ``ScenarioEngine`` into a fluid engine."""
        engine = source.compile() if hasattr(source, "compile") else source
        return cls(engine, dt_s=dt_s)

    # ------------------------------------------------------- realizations
    def base_realization(self) -> Dict[str, np.ndarray]:
        """The nominal (unperturbed) single realization: unit rate
        modulation, the engine's own outage windows."""
        T, S = self.T, len(self.order)
        fdown, recover = self.outage_arrays(self.engine.outages)
        return {
            "modw": np.ones((1, T, S)),
            "mods": np.ones((1, T, S)),
            "fdown": fdown[None],
            "recover": recover[None],
        }

    def outage_arrays(self, outages: Mapping[str, Sequence]):
        """Lower per-site ``(down, up)`` windows to per-bin capacity
        fractions and recovery waits (fire deferral to outage end)."""
        T, J = self.T, len(self.site_names)
        fdown = np.zeros((T, J))
        recover = np.zeros((T, J))
        for site, wins in (outages or {}).items():
            j = self._site_idx.get(site)
            if j is None:
                continue
            for d, u in wins:
                for k in range(T):
                    t0, t1 = self.t_bins[k], self.t_bins[k] + self.dt
                    ov = max(0.0, min(t1, u) - max(t0, d))
                    fdown[k, j] = min(1.0, fdown[k, j] + ov / self.dt)
                    if d <= t0 < u:
                        recover[k, j] = max(recover[k, j], u - t0)
        return fdown, recover

    # ------------------------------------------------------ plan lowering
    def lower_plans(self, plans: Sequence[PlacementPlan],
                    corrections=None,
                    stalls: Optional[Mapping[int, Mapping[str, float]]] = None
                    ) -> Dict[str, np.ndarray]:
        """Dense per-plan arrays for the jitted stepper. ``corrections``
        is the per-service calibration mapping the screen/forecast tiers
        use (duck-typed ``.tier(is_edge)`` → q_mult/lat_bias_s/
        drop_offset); ``stalls`` maps plan index → per-service
        stall-until times (migration charges)."""
        M, S, J, U = len(plans), len(self.order), len(self.site_names), self.U
        Z = dict(
            isdc=np.zeros((M, S)), onehot=np.zeros((M, S, J)),
            thr=np.ones((M, S)), fps=np.ones((M, S)),
            ovh=np.zeros((M, S)), epr=np.zeros((M, S)),
            apw=np.zeros((M, S)), tstep=np.zeros((M, S)),
            estep=np.zeros((M, S)), chips=np.zeros((M, S)),
            hop=np.zeros((M, S)), stall=np.zeros((M, S)),
            alignsite=np.zeros((M, S, S)), act=np.zeros((M, S, U)),
            rtt_leg=np.zeros((M, S, U)), upsec_pr=np.zeros((M, S, U)),
            dn_pr=np.zeros((M, S, U)),
            uses_up=np.zeros((M, S)), qm=np.ones((M, S)),
            qb=np.zeros((M, S)), keep=np.ones((M, S)),
        )
        if self._hier:
            # per-move origin-region one-hot + RAP trunk leg coefficients
            Z.update(
                oreg=np.zeros((M, S, U, self.n_regions)),
                rap_upsec_pr=np.zeros((M, S, U)),
                rap_rtt=np.zeros((M, S, U)),
                rap_dn_pr=np.zeros((M, S, U)),
                rap_uses=np.zeros((M, S, U)),
            )
        feasible = np.ones(M, dtype=bool)
        corr = dict(corrections or {})
        cost = self.engine.cost
        for m, plan in enumerate(plans):
            exec_site = np.empty(S, dtype=int)
            ram_need = np.zeros(J)
            for si, s in enumerate(self.order):
                p = plan.placement(s)
                if p.is_edge:
                    j = self._site_idx[p.site]
                    exec_site[si] = j
                    Z["onehot"][m, si, j] = 1.0
                    Z["thr"][m, si] = self._thr[j]
                    Z["fps"][m, si] = self._fps[j]
                    Z["ovh"][m, si] = self._ovh[j]
                    Z["epr"][m, si] = self._epr[j]
                    Z["apw"][m, si] = self._apw[j]
                    ram_need[j] += self.budget[si] * self._ram_rec[j]
                else:
                    exec_site[si] = -1
                    Z["isdc"][m, si] = 1.0
                    Z["tstep"][m, si] = cost.time_per_step(
                        f"svc:{s}", "window", p.chips, p.dvfs_f)
                    Z["estep"][m, si] = cost.energy_per_step(
                        f"svc:{s}", "window", p.chips, p.dvfs_f)
                    Z["chips"][m, si] = float(p.chips)
                cal = corr.get(s)
                c = cal.tier(p.is_edge) if cal is not None else None
                if c is not None:
                    Z["qm"][m, si] = c.q_mult
                    Z["qb"][m, si] = c.lat_bias_s
                    Z["keep"][m, si] = max(0.0, 1.0 - c.drop_offset)
            feasible[m] = bool((ram_need <= self._ram).all())
            for si, s in enumerate(self.order):
                my = exec_site[si]
                # result-handoff hop (max over upstream cuts; DC pays
                # nothing extra — folded into dl_user, like the screen)
                h = 0.0
                for u in self._ups[si]:
                    us = exec_site[self.rank[u]]
                    if my >= 0 and us != my:
                        hh = (self._rtt[my] / 2
                              + (self._rtt[us] / 2 if us >= 0 else 0.0))
                        if self._hier and (
                                us < 0 or self._region_of[us]
                                != self._region_of[my]):
                            # cross-region handoff: src RAP up + dst down
                            if us >= 0:
                                hh += self._rap_res_up[us]
                            hh += self._rap_res_dn[my]
                        h = max(h, hh)
                Z["hop"][m, si] = h
                if my >= 0:
                    for oi in range(si):
                        if exec_site[oi] == my:
                            Z["alignsite"][m, si, oi] = \
                                self.align_rank[si, oi]
                # cross-site raw-record haul coefficients per origin
                keys = [None] + self._ups[si]
                for ui, okey in enumerate(keys):
                    if self.total_orig[si, ui] <= 0.0:
                        continue
                    osite = (self.farm_site[si] if okey is None
                             else exec_site[self.rank[okey]])
                    if osite < 0 or osite == my:
                        continue
                    Z["act"][m, si, ui] = 1.0
                    Z["rtt_leg"][m, si, ui] = self._rtt[osite] / 2
                    Z["upsec_pr"][m, si, ui] = (self._wire_rec[osite]
                                                / self._up_bps[osite])
                    if my >= 0:   # relay onto another edge: its downlink
                        Z["rtt_leg"][m, si, ui] += self._rtt[my] / 2
                        Z["dn_pr"][m, si, ui] = (self._dn_rec[my]
                                                 / self._dn_bps[my])
                    if self._hier:
                        rj = int(self._region_of[osite])
                        Z["oreg"][m, si, ui, rj] = 1.0
                        if my < 0 or self._region_of[my] != rj:
                            rap = self._rap[rj]
                            if rap is not None:
                                Z["rap_uses"][m, si, ui] = 1.0
                                Z["rap_upsec_pr"][m, si, ui] = (
                                    self._wire_rec[osite] / rap.uplink_bps)
                                Z["rap_rtt"][m, si, ui] = rap.rtt_s / 2
                            if my >= 0:
                                rapd = self._rap[self._region_of[my]]
                                if rapd is not None:
                                    Z["rap_rtt"][m, si, ui] += \
                                        rapd.rtt_s / 2
                                    Z["rap_dn_pr"][m, si, ui] = (
                                        self._dn_rec[my]
                                        / rapd.downlink_bps)
                Z["uses_up"][m, si] = float(Z["act"][m, si].any())
            if stalls and m in stalls:
                for s, until in stalls[m].items():
                    Z["stall"][m, self.rank[s]] = float(until)
        Z["feasible"] = feasible
        return Z

    def migration_stalls(self, prev_plan: Optional[PlacementPlan],
                         plans: Sequence[PlacementPlan],
                         at_s: float = 0.0) -> Dict[int, Dict[str, float]]:
        """Per-plan stall-until times for migrating off ``prev_plan`` at
        ``at_s`` — the analytic form of ``core.elastic.plan_replacement``
        charges (state bytes over the origin uplink + warm-up)."""
        if prev_plan is None:
            return {}
        from repro.core.elastic import plan_replacement
        cfg = self.engine.cfg
        out: Dict[int, Dict[str, float]] = {}
        for m, plan in enumerate(plans):
            migs = plan_replacement(
                prev_plan.assignments, plan.assignments,
                state_bytes_fn=lambda s: (
                    self.budget[self.rank[s]] * cfg.state_bytes_per_record),
                transfer_time_fn=self._transfer_time,
                warmup_s=cfg.migration_warmup_s)
            if migs:
                out[m] = {mig.service: at_s + mig.stall_s for mig in migs}
        return out

    def _transfer_time(self, src: str, dst: str, nbytes: float) -> float:
        j = self._site_idx.get(src)
        if j is None:    # DC-origin state rides the destination downlink
            j = self._site_idx.get(dst)
            if j is None:
                return 0.0
            return self._rtt[j] / 2 + nbytes / self._dn_bps[j]
        return self._rtt[j] / 2 + nbytes / self._up_bps[j]

    # ----------------------------------------------------------- the core
    def _build_sim(self):
        import jax
        import jax.lax as lax
        import jax.numpy as jnp

        S, J, U = len(self.order), len(self.site_names), self.U
        R, hier = self.n_regions, self._hier
        dt = self.dt
        f32 = lambda a: jnp.asarray(a, dtype=jnp.float32)
        fires, nw, orig = f32(self.fires), f32(self.nw), f32(self.orig)
        t_bins = f32(self.t_bins)
        budget, flops = f32(self.budget), f32(self.flops)
        gamma, wp, we = f32(self.gamma), f32(self.wp), f32(self.we)
        p_soft, p_hard = f32(self.p_soft), f32(self.p_hard)
        e_soft, e_hard = f32(self.e_soft), f32(self.e_hard)
        is_exp, is_root = f32(self.is_exp), f32(self.is_root)
        u0 = f32(np.eye(1, U, 0)[0])     # [U] one-hot on the farm slot
        rps, grid, dl_user = self.records_per_step, self.grid_chips, \
            self.dl_user_s

        def curve(x, soft, hard):
            # ValueCurve with (v_max, v_min) = (1, 0.1): full value at or
            # under soft, 0 past hard, linear or 3-e-fold decay between.
            frac = jnp.clip((x - soft) / jnp.maximum(hard - soft, 1e-9),
                            0.0, 1.0)
            mid = jnp.where(is_exp > 0,
                            0.1 + 0.9 * jnp.exp(-3.0 * frac),
                            1.0 - 0.9 * frac)
            return jnp.where(x <= soft, 1.0,
                             jnp.where(x > hard, 0.0, mid))

        def one(plan, real):
            def step(carry, x):
                if hier:
                    B, Bup, Brap = carry
                else:
                    B, Bup = carry
                (fires_t, nw_t, orig_t, modw_t, mods_t,
                 fdown_t, recov_t, tb) = x
                nwm = jnp.clip(nw_t * jnp.where(is_root > 0, modw_t, 1.0),
                               0.0, budget)
                dur_e = (jnp.maximum(nwm / plan["thr"],
                                     nwm * flops / plan["fps"])
                         + plan["ovh"])
                steps = jnp.maximum(1.0, jnp.ceil(nwm / rps))
                dur_d = steps * plan["tstep"]
                isdc = plan["isdc"]
                edge_work = (1.0 - isdc) * dur_e * fires_t
                work_j = plan["onehot"].T @ edge_work             # [J]
                # origin record counts per fire: the farm slot scales
                # with the realization's slide-window modulation,
                # upstream slots fire once per upstream fire regardless
                farm_mod = jnp.where(is_root > 0, mods_t, 1.0)
                modc = jnp.where(u0[None, :] > 0, farm_mod[:, None], 1.0)
                c = orig_t * modc                                 # [S, U]
                if hier:
                    # per-region twins of the scalar edge-tier terms,
                    # plus the RAP-trunk second tier: every per-move
                    # quantity is routed through the move's *origin
                    # region* one-hot (oreg), so each region's pipe and
                    # trunk carries exactly its own traffic
                    oreg = plan["oreg"]                       # [S, U, R]
                    upsec_su = plan["act"] * c * plan["upsec_pr"]
                    up_work_r = jnp.einsum(
                        "su,sur->r", upsec_su * fires_t[:, None], oreg)
                    q_up_su = (oreg @ q_factor_jnp(jnp.minimum(
                        up_work_r / dt, _UPLINK_Q_CLAMP)))    # [S, U]
                    rapsec_su = plan["act"] * c * plan["rap_upsec_pr"]
                    rap_work_r = jnp.einsum(
                        "su,sur->r", rapsec_su * fires_t[:, None], oreg)
                    q_rap_su = (oreg @ q_factor_jnp(jnp.minimum(
                        rap_work_r / dt, _UPLINK_Q_CLAMP)))
                    haul = ((plan["act"]
                             * (plan["rtt_leg"]
                                + c * plan["upsec_pr"] * q_up_su
                                + c * plan["dn_pr"]
                                + plan["rap_rtt"]
                                + c * plan["rap_upsec_pr"] * q_rap_su
                                + c * plan["rap_dn_pr"])).sum(-1)
                            + (plan["act"] * (oreg @ Bup)).max(-1)
                            + (plan["rap_uses"] * (oreg @ Brap)).max(-1))
                else:
                    upsec = (plan["act"] * c * plan["upsec_pr"]).sum(-1)
                    up_work = (upsec * fires_t).sum()
                    q_up = q_factor_jnp(jnp.minimum(up_work / dt,
                                                    _UPLINK_Q_CLAMP))
                    haul = ((plan["act"]
                             * (plan["rtt_leg"]
                                + c * plan["upsec_pr"] * q_up
                                + c * plan["dn_pr"])).sum(-1)
                            + plan["uses_up"] * Bup)
                demand = (isdc * plan["chips"] * dur_d * fires_t).sum() / dt
                dc_over = jnp.maximum(1.0, demand / grid)
                rw = plan["alignsite"] @ edge_work
                B_here = plan["onehot"] @ B
                recov_s = plan["onehot"] @ recov_t
                stall_x = jnp.maximum(0.0, plan["stall"] - tb)
                lat_e = (B_here + rw + dur_e + plan["hop"] + haul
                         + recov_s + stall_x)
                lat_d = haul + dur_d * dc_over + dl_user + stall_x
                lat = jnp.where(isdc > 0, lat_d, lat_e)
                lat = jnp.maximum(plan["qm"] * lat + plan["qb"], 0.0)
                en = jnp.where(isdc > 0, steps * plan["estep"],
                               nwm * plan["epr"] + dur_e * plan["apw"])
                vp = curve(lat, p_soft, p_hard)
                ve = curve(en, e_soft, e_hard)
                v = jnp.where((vp > 0) & (ve > 0),
                              gamma * (wp * vp + we * ve), 0.0)
                v = v * plan["keep"]
                B2 = jnp.maximum(B + work_j - dt * (1.0 - fdown_t), 0.0)
                ys = (v * fires_t, lat * fires_t,
                      jnp.where(v <= 0.0, fires_t, 0.0))
                if hier:
                    Bup2 = jnp.maximum(Bup + up_work_r - dt, 0.0)
                    Brap2 = jnp.maximum(Brap + rap_work_r - dt, 0.0)
                    return (B2, Bup2, Brap2), ys
                Bup2 = jnp.maximum(Bup + up_work - dt, 0.0)
                return (B2, Bup2), ys

            xs = (fires, nw, orig, real["modw"], real["mods"],
                  real["fdown"], real["recover"], t_bins)
            carry0 = ((jnp.zeros(J), jnp.zeros(R), jnp.zeros(R)) if hier
                      else (jnp.zeros(J), jnp.zeros(())))
            _, ys = lax.scan(step, carry0, xs)
            return ys

        def batch(plans, reals):
            per_real = lambda real: jax.vmap(
                lambda plan: one(plan, real))(plans)
            return jax.vmap(per_real)(reals)

        self._sim_eager = batch
        self._sim_jit = jax.jit(batch)

    # ------------------------------------------------------------- fronts
    def evaluate(self, plans: Sequence[PlacementPlan],
                 realizations: Optional[Mapping[str, np.ndarray]] = None,
                 corrections=None,
                 stalls: Optional[Mapping[int, Mapping[str, float]]] = None,
                 jit: bool = True) -> FluidResult:
        """Score every plan under every realization in one batched call.

        ``realizations`` is the array bundle built by
        :class:`repro.fluid.ensemble.ScenarioEnsemble` (default: the
        single nominal realization). ``jit=False`` runs the identical
        program eagerly (the bit-identity property test uses it)."""
        import jax.numpy as jnp
        if self._sim_jit is None:
            self._build_sim()
        real = dict(realizations if realizations is not None
                    else self.base_realization())
        Z = self.lower_plans(plans, corrections=corrections, stalls=stalls)
        feasible = Z.pop("feasible")
        f32 = lambda a: jnp.asarray(np.asarray(a), dtype=jnp.float32)
        plan_arrs = {k: f32(v) for k, v in Z.items()}
        real_arrs = {k: f32(v) for k, v in real.items()}
        sim = self._sim_jit if jit else self._sim_eager
        vv, latw, dead = (np.asarray(a, dtype=np.float64)
                          for a in sim(plan_arrs, real_arrs))
        # vv/latw/dead: [N, M, T, S]
        vos_service = vv.sum(axis=2)
        vos = vos_service.sum(axis=-1)
        vos_t = vv.sum(axis=-1)
        ftot = np.maximum(self.fires_total, 1.0)
        lat_mean = latw.sum(axis=2) / ftot[None, None, :]
        fires_t = np.maximum(self.fires.sum(axis=-1), 1.0)
        drop_t = dead.sum(axis=-1) / fires_t[None, None, :]
        drop_frac = dead.sum(axis=(2, 3)) / max(self.fires_total.sum(), 1.0)
        vos[:, ~feasible] = float("-inf")
        return FluidResult(vos=vos, vos_service=vos_service, vos_t=vos_t,
                           lat_mean=lat_mean, drop_frac=drop_frac,
                           drop_t=drop_t, feasible=feasible,
                           order=list(self.order),
                           t_bins=self.t_bins.copy(),
                           max_vos=self.max_vos)
