"""Drift ensembles for the fluid engine: sampled scenario realizations.

A realization is itself a valid ``ScenarioSpec`` — the base spec with
every farm's declarative ``RateSpec`` structurally perturbed (the same
jitter family as ``repro.online.drift.perturb_curve``: lognormal
base/burst rates, diurnal phase/amplitude jitter, re-seeded poisson
arrival processes) and outage onsets jittered. That keeps the exact DES
available as ground truth for *any* ensemble member: compile the
realization spec and ``run_plan`` it.

The fluid engine consumes realizations as rate-*modulation* arrays
``mod[n, t, s] = windowed_rate_realization / windowed_rate_base``
evaluated over each service's window (for window sizes) and slide (for
newly-covered record counts), so the placement-independent fire trace
is scaled, not re-driven — which is what makes N×M evaluation one
array program.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from repro.fluid.engine import FluidEngine, FluidResult
from repro.online.drift import perturb_outages
from repro.scenario.spec import RateSpec, ScenarioSpec


def _lognorm(rng: random.Random, sigma: float) -> float:
    return math.exp(rng.gauss(0.0, sigma))


def perturb_rate_spec(rate: RateSpec, rng: random.Random,
                      rate_scale: float = 0.15) -> RateSpec:
    """One perturbed realization of a declarative rate curve (the
    RateSpec twin of ``drift.perturb_curve``)."""
    k = rate.kind
    if k == "constant":
        return dataclasses.replace(
            rate, base_hz=rate.base_hz * _lognorm(rng, rate_scale))
    if k == "diurnal":
        return dataclasses.replace(
            rate,
            base_hz=rate.base_hz * _lognorm(rng, rate_scale),
            amplitude=min(0.95, rate.amplitude * _lognorm(rng, rate_scale)),
            phase_s=rate.phase_s + rng.gauss(0.0, rate.period_s / 12.0))
    if k == "step_bursts":
        wins = []
        for t0, t1 in rate.windows:
            length = max(1e-9, (t1 - t0) * _lognorm(rng, rate_scale))
            start = max(0.0, t0 + rng.gauss(0.0, 0.1 * (t1 - t0)))
            wins.append((start, start + length))
        return dataclasses.replace(
            rate,
            base_hz=rate.base_hz * _lognorm(rng, rate_scale),
            burst_hz=rate.burst_hz * _lognorm(rng, rate_scale),
            windows=tuple(wins))
    if k == "piecewise_linear":
        return dataclasses.replace(
            rate, knots=tuple((t, r * _lognorm(rng, rate_scale))
                              for t, r in rate.knots))
    if k == "poisson_bursts":
        return dataclasses.replace(
            rate,
            base_hz=rate.base_hz * _lognorm(rng, rate_scale),
            burst_hz=rate.burst_hz * _lognorm(rng, rate_scale),
            seed=rng.randrange(2 ** 31))
    raise ValueError(f"unknown rate kind {k!r}")


def sample_specs(spec: ScenarioSpec, n: int, seed: int = 0,
                 rate_scale: float = 0.15,
                 onset_scale: float = 0.1) -> List[ScenarioSpec]:
    """``n`` perturbed realizations of ``spec`` (deterministic per
    seed). Farm rates are perturbed structurally, outage onsets
    jittered with durations preserved."""
    rng = random.Random(seed * 9176 + 5)
    out: List[ScenarioSpec] = []
    for k in range(n):
        farms = tuple(dataclasses.replace(
            f, rate=perturb_rate_spec(f.rate, rng, rate_scale))
            for f in spec.farms)
        outages = perturb_outages(spec.outage_map(), rng, onset_scale)
        out.append(dataclasses.replace(
            spec, name=f"{spec.name}#{k}", farms=farms,
            outages=tuple(sorted((s, tuple(w))
                                 for s, w in outages.items()))))
    return out


class _CurveTable:
    """One rate curve sampled once on a fine grid, exposing windowed
    averages at arbitrary times via the cumulative integral (so N
    realizations cost one Python sweep each, not one per bin)."""

    def __init__(self, curve, t_lo: float, t_hi: float, h: float):
        self.g = np.arange(t_lo, t_hi + h, h)
        vals = np.array([max(0.0, curve(float(t))) for t in self.g])
        self.cum = np.concatenate(
            [[0.0], np.cumsum((vals[1:] + vals[:-1]) / 2.0 * h)])

    def window_avg(self, ts: np.ndarray, w: float) -> np.ndarray:
        hi = np.interp(ts, self.g, self.cum)
        lo = np.interp(ts - w, self.g, self.cum)
        return (hi - lo) / max(w, 1e-12)


class ScenarioEnsemble:
    """A fluid engine plus N sampled drift realizations, evaluated
    against M plans in one batched call.

    ``specs[i]`` is the full ScenarioSpec of realization ``i`` — hand it
    to ``spot_check`` for exact-DES ground truth on that member. With
    ``include_nominal=True`` (default) realization 0 is the unperturbed
    base scenario."""

    def __init__(self, fluid: FluidEngine, specs: Sequence[ScenarioSpec],
                 realizations: Mapping[str, np.ndarray]):
        self.fluid = fluid
        self.specs = list(specs)
        self.realizations = dict(realizations)

    # ------------------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: ScenarioSpec, n: int = 64, seed: int = 0,
                  rate_scale: float = 0.15, onset_scale: float = 0.1,
                  engine=None, dt_s: Optional[float] = None,
                  include_nominal: bool = True) -> "ScenarioEnsemble":
        """Build the ensemble: compile (or reuse) the base engine, lower
        it to a fluid engine, sample ``n`` perturbed realizations and
        precompute their modulation / outage arrays. When ``engine`` is
        a :class:`~repro.scenario.engine.ScenarioEngine` the lowering
        goes through its cached :meth:`fluid_engine` accessor, so
        repeated ensembles on one engine (an epoch loop) share arrays
        and jit cache."""
        make = getattr(engine, "fluid_engine", None)
        if make is not None:
            fluid = make(dt_s=dt_s)
        else:
            fluid = FluidEngine.compile(
                engine if engine is not None else spec, dt_s=dt_s)
        perturbed = sample_specs(spec, n, seed=seed, rate_scale=rate_scale,
                                 onset_scale=onset_scale)
        specs = ([spec] + perturbed) if include_nominal else perturbed
        return cls(fluid, specs, cls._lower(fluid, spec, specs))

    @staticmethod
    def _lower(fluid: FluidEngine, base: ScenarioSpec,
               specs: Sequence[ScenarioSpec]) -> Dict[str, np.ndarray]:
        S = len(fluid.order)
        T, dt = fluid.T, fluid.dt
        N = len(specs)
        ts = fluid.t_bins
        w_max = float(fluid.width.max()) if S else dt
        h = min(dt, float(fluid.slide.min()) if S else dt) / 8.0
        t_lo, t_hi = -w_max - dt, fluid.horizon_s + dt

        def tables(sp: ScenarioSpec) -> Dict[str, _CurveTable]:
            return {f.queue: _CurveTable(
                f.rate.curve(sp.horizon_s), t_lo, t_hi, h)
                for f in sp.farms}

        base_tab = tables(base)
        modw = np.ones((N, T, S))
        mods = np.ones((N, T, S))
        J = len(fluid.site_names)
        fdown = np.zeros((N, T, J))
        recover = np.zeros((N, T, J))
        for ni, sp in enumerate(specs):
            tab = tables(sp)
            for si in range(S):
                if not fluid.is_root[si]:
                    continue
                q = fluid.queue_of[si]
                if q not in tab or q not in base_tab:
                    continue
                b_w = base_tab[q].window_avg(ts, fluid.width[si])
                r_w = tab[q].window_avg(ts, fluid.width[si])
                b_s = base_tab[q].window_avg(ts, fluid.slide[si])
                r_s = tab[q].window_avg(ts, fluid.slide[si])
                modw[ni, :, si] = r_w / np.maximum(b_w, 1e-9)
                mods[ni, :, si] = r_s / np.maximum(b_s, 1e-9)
            fdown[ni], recover[ni] = fluid.outage_arrays(sp.outage_map())
        return {"modw": modw, "mods": mods, "fdown": fdown,
                "recover": recover}

    # ------------------------------------------------------------------
    @property
    def n_realizations(self) -> int:
        return self.realizations["modw"].shape[0]

    def evaluate(self, plans, corrections=None, stalls=None,
                 jit: bool = True) -> FluidResult:
        """Fluid VoS of every plan under every realization — one jitted
        N×M call."""
        return self.fluid.evaluate(plans, realizations=self.realizations,
                                   corrections=corrections, stalls=stalls,
                                   jit=jit)

    def spot_check(self, idx: int, plan):
        """Exact-DES ground truth for realization ``idx``: compile its
        spec and run the plan through the event-driven engine."""
        return self.specs[idx].compile().run_plan(plan)
