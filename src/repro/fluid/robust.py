"""Distributionally robust plan ranking over a fluid ensemble.

Given ``vos[n, m]`` (N drift realizations × M plans) from
:class:`repro.fluid.engine.FluidEngine`, a :class:`RiskSpec` collapses
the realization axis into one score per plan:

=============  =====================================================
``mean``       risk-neutral expectation (what single-trace search
               implicitly optimizes when the trace is the mean drift)
``cvar``       mean of the worst ``alpha`` fraction of realizations
               (Conditional Value-at-Risk; the default robust metric)
``quantile``   the ``alpha``-quantile (Value-at-Risk)
``worst``      min over realizations (most conservative)
=============  =====================================================

CVaR ranking disagrees with mean ranking exactly when a plan's *tail*
collapses (burst saturation, outage exposure) while its typical case
looks fine — that disagreement is the point of evaluating ensembles.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class RiskSpec:
    """A risk metric over the realization axis. ``alpha`` is the tail
    fraction (cvar) or quantile level (quantile); ignored by mean and
    worst."""
    metric: str = "cvar"    # mean | cvar | quantile | worst
    alpha: float = 0.2

    @classmethod
    def mean(cls) -> "RiskSpec":
        return cls(metric="mean")

    @classmethod
    def cvar(cls, alpha: float = 0.2) -> "RiskSpec":
        return cls(metric="cvar", alpha=alpha)

    @classmethod
    def quantile(cls, alpha: float = 0.1) -> "RiskSpec":
        return cls(metric="quantile", alpha=alpha)

    @classmethod
    def worst(cls) -> "RiskSpec":
        return cls(metric="worst")

    @classmethod
    def of(cls, spec) -> "RiskSpec":
        """Coerce ``None`` / a metric name / a RiskSpec into a RiskSpec
        (``None`` → mean, matching single-trace behaviour)."""
        if spec is None:
            return cls.mean()
        if isinstance(spec, cls):
            return spec
        return cls(metric=str(spec))

    @property
    def label(self) -> str:
        if self.metric in ("mean", "worst"):
            return self.metric
        return f"{self.metric}[{self.alpha:g}]"

    def score(self, vos: np.ndarray, axis: int = 0) -> np.ndarray:
        """Collapse the realization axis of ``vos`` into risk scores.
        ``-inf`` (infeasible) propagates through every metric."""
        v = np.asarray(vos, dtype=float)
        if self.metric == "mean":
            return v.mean(axis=axis)
        if self.metric == "worst":
            return v.min(axis=axis)
        if self.metric == "quantile":
            return np.quantile(v, self.alpha, axis=axis)
        if self.metric == "cvar":
            n = v.shape[axis]
            k = max(1, int(math.ceil(self.alpha * n)))
            worst_k = np.sort(v, axis=axis)
            worst_k = np.take(worst_k, range(k), axis=axis)
            return worst_k.mean(axis=axis)
        raise ValueError(f"unknown risk metric {self.metric!r}")


def risk_score(vos: np.ndarray, risk=None) -> np.ndarray:
    """Per-plan risk scores for an ``[N, M]`` ensemble VoS matrix."""
    return RiskSpec.of(risk).score(vos, axis=0)


def rank_plans(vos: np.ndarray, risk=None) -> np.ndarray:
    """Plan indices sorted best-first by the risk metric (stable, so
    ties keep candidate order — deterministic)."""
    scores = risk_score(vos, risk)
    return np.argsort(-scores, kind="stable")


def ensemble_spread(result, plan_index: int) -> Dict[str, float]:
    """Per-service *relative* VoS spread (std / max attainable) across
    realizations for one plan — the predictive-uncertainty signal."""
    v = result.vos_service[:, plan_index, :]    # [N, S]
    out: Dict[str, float] = {}
    for si, s in enumerate(result.order):
        scale = max(1e-9, float(np.abs(v[:, si]).max()))
        out[s] = float(v[:, si].std() / scale)
    return out


def calibration_prior(result, plan_index: int,
                      plan=None) -> Dict[str, Dict[str, float]]:
    """Ensemble spread shaped as a per-service per-tier uncertainty
    prior for ``CalibrationLoop.set_variance_prior``: services whose
    predicted VoS varies a lot across drift realizations should be
    corrected *faster* (larger RLS prior covariance). When ``plan`` is
    given only the tier the plan actually uses carries the measured
    spread; the unused tier keeps a neutral 0."""
    spread = ensemble_spread(result, plan_index)
    out: Dict[str, Dict[str, float]] = {}
    for s, rel in spread.items():
        if plan is None:
            out[s] = {"edge": rel, "dc": rel}
        else:
            is_edge = plan.placement(s).is_edge
            out[s] = {"edge": rel if is_edge else 0.0,
                      "dc": 0.0 if is_edge else rel}
    return out
