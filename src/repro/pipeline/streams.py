"""Stream transport: an in-process broker with the RabbitMQ semantics the
paper deploys (named queues, bounded capacity, consumer offsets) and IoT
producers that generate Neubot-shaped network-test records (DESIGN §8:
the original dataset is not shipped; records are synthetic but share the
schema: timestamp, download_speed, upload_speed, latency, connection_type).
"""
from __future__ import annotations

import collections
import dataclasses
import math
import random
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Record:
    ts: float
    values: Dict[str, float]


DEFAULT_QUEUE_CAPACITY = 65536


class Queue:
    """Bounded FIFO with per-consumer offsets (retained until all consume).

    Capacity is enforced with an oldest-drop policy: a publish into a
    full queue evicts the head record and counts it in ``dropped`` (the
    conservation ledger's ``overflow`` bucket). ``len(buf) <= capacity``
    is an invariant at every point, including across ``set_capacity``
    shrinks."""

    def __init__(self, name: str, capacity: int = DEFAULT_QUEUE_CAPACITY):
        if capacity < 1:
            raise ValueError(f"queue {name!r}: capacity must be >= 1, "
                             f"got {capacity}")
        self.name = name
        self.capacity = capacity
        self.buf: Deque[Record] = collections.deque()
        self.base_seq = 0              # seq of buf[0]
        self.offsets: Dict[str, int] = {}
        self.dropped = 0

    def publish(self, rec: Record) -> None:
        if len(self.buf) >= self.capacity:
            self.buf.popleft()
            self.base_seq += 1
            self.dropped += 1
        self.buf.append(rec)

    def set_capacity(self, capacity: int) -> None:
        """Rebound the queue; shrinking below the current backlog evicts
        the oldest records with the same drop accounting as a full
        publish."""
        if capacity < 1:
            raise ValueError(f"queue {self.name!r}: capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        while len(self.buf) > self.capacity:
            self.buf.popleft()
            self.base_seq += 1
            self.dropped += 1

    def register(self, consumer: str) -> None:
        self.offsets.setdefault(consumer, self.base_seq + len(self.buf))

    def backlog(self, consumer: str) -> int:
        """Records published but not yet fetched by ``consumer`` (what a
        backpressured publisher is waiting on)."""
        off = max(self.offsets.get(consumer, self.base_seq), self.base_seq)
        return self.base_seq + len(self.buf) - off

    def fetch(self, consumer: str, max_n: int = 1 << 30) -> List[Record]:
        off = self.offsets.get(consumer, self.base_seq)
        off = max(off, self.base_seq)
        start = off - self.base_seq
        out = list(self.buf)[start:start + max_n]
        self.offsets[consumer] = off + len(out)
        return out


class Broker:
    def __init__(self):
        self.queues: Dict[str, Queue] = {}

    def queue(self, name: str, capacity: Optional[int] = None) -> Queue:
        """Get-or-create a queue. ``capacity=None`` (the default) leaves
        an existing queue's bound untouched; an explicit capacity is
        applied even when the queue already exists — previously it was
        silently ignored, so two declarations with different bounds
        diverged from what actually ran."""
        if name not in self.queues:
            self.queues[name] = Queue(name, capacity if capacity is not None
                                      else DEFAULT_QUEUE_CAPACITY)
        elif capacity is not None and capacity != self.queues[name].capacity:
            self.queues[name].set_capacity(capacity)
        return self.queues[name]


_TWOPI = 2.0 * math.pi
_sqrt, _log, _cos, _sin = math.sqrt, math.log, math.cos, math.sin


class StreamProducer:
    """One 'thing' producing measurements at a fixed rate.

    ``_record`` inlines ``random.gauss`` / ``random.choice([0,1,2])``
    against the producer's own ``Random`` instance — same underlying
    Mersenne-Twister draw sequence (gauss pair-caching and the
    ``getrandbits`` rejection loop included), so the generated values
    are bit-identical to the stdlib calls while skipping their
    per-record attribute-lookup and call overhead. The functional drive
    creates millions of records per scenario; this is its hottest path.
    """

    def __init__(self, broker: Broker, queue: str, thing_id: int,
                 rate_hz: float = 1.0, seed: int = 0):
        self.q = broker.queue(queue)
        self.thing_id = thing_id
        self.period = 1.0 / rate_hz
        self.rng = random.Random(seed * 7919 + thing_id)
        self._random = self.rng.random
        self._getrandbits = self.rng.getrandbits
        self._gauss_next: Optional[float] = None
        self._next_t = 0.0

    def _record(self, ts: float) -> Record:
        rnd = self._random
        g = self._gauss_next
        # gauss(base, 4e6)
        if g is None:
            x2pi = rnd() * _TWOPI
            g2rad = _sqrt(-2.0 * _log(1.0 - rnd()))
            z = _cos(x2pi) * g2rad
            g = _sin(x2pi) * g2rad
        else:
            z, g = g, None
        base = 20e6 + 5e6 * _sin(ts / 3600.0 + self.thing_id)
        dl = base + z * 4e6
        # gauss(base / 4, 1e6)
        if g is None:
            x2pi = rnd() * _TWOPI
            g2rad = _sqrt(-2.0 * _log(1.0 - rnd()))
            z = _cos(x2pi) * g2rad
            g = _sin(x2pi) * g2rad
        else:
            z, g = g, None
        ul = base / 4 + z * 1e6
        # gauss(30, 12)
        if g is None:
            x2pi = rnd() * _TWOPI
            g2rad = _sqrt(-2.0 * _log(1.0 - rnd()))
            z = _cos(x2pi) * g2rad
            g = _sin(x2pi) * g2rad
        else:
            z, g = g, None
        lat = 30 + z * 12
        self._gauss_next = g
        # choice([0, 1, 2]) == seq[_randbelow(3)] with k = 2 bits
        grb = self._getrandbits
        r = grb(2)
        while r >= 3:
            r = grb(2)
        return Record(ts=ts, values={
            "thing": float(self.thing_id),
            "download_speed": max(0.1e6, dl),
            "upload_speed": max(0.05e6, ul),
            "latency_ms": max(1.0, lat),
            "connection_type": float(r),
        })

    def advance_to(self, ts: float) -> int:
        n = 0
        while self._next_t <= ts:
            self.q.publish(self._record(self._next_t))
            self._next_t += self.period
            n += 1
        return n


class NeubotFarm:
    """An IoT farm of producers on one queue (the paper's clustered
    RabbitMQ deployment, scaled by n_things)."""

    def __init__(self, broker: Broker, queue: str = "neubotspeed",
                 n_things: int = 8, rate_hz: float = 1.0, seed: int = 0):
        self.producers = [StreamProducer(broker, queue, i, rate_hz, seed)
                          for i in range(n_things)]

    def advance_to(self, ts: float) -> int:
        return sum(p.advance_to(ts) for p in self.producers)
