"""The paper's use-case queries (§3) and the just-in-time edge→VDC offload.

  Q1: EVERY 60 s compute the MAX of download_speed over the last 3 min
      FROM cassandra series speedtests AND streaming queue neubotspeed
  Q2: EVERY 5 min compute the MEAN of download_speed over the last 120 d
      FROM the same sources

Both mash a post-mortem store range with the live stream. The
HybridExecutor is the paper's "services interact with the VDC underlying
services only when the process needs more resources": windows whose record
count fits the edge budget aggregate in the service loop (NumPy on host);
larger windows offload to the VDC path — the Pallas window_agg kernel
(+ its roofline-costed submesh, scheduled like any other task).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.window_agg import window_aggregate
from repro.pipeline.operators import WindowSpec, aggregate
from repro.pipeline.service import ServiceConfig, StreamService
from repro.pipeline.store import TimeSeriesStore
from repro.pipeline.streams import Broker

EDGE_WINDOW_BUDGET = 100_000  # records an edge service may aggregate inline


def neubot_query_1(broker: Broker, store: TimeSeriesStore) -> StreamService:
    return StreamService(ServiceConfig(
        name="q1_max_speed", queue="neubotspeed", column="download_speed",
        agg="max", window=WindowSpec("sliding", width_s=180.0, slide_s=60.0),
        store=store), broker)


def neubot_query_2(broker: Broker, store: TimeSeriesStore) -> StreamService:
    return StreamService(ServiceConfig(
        name="q2_mean_speed", queue="neubotspeed", column="download_speed",
        agg="mean",
        window=WindowSpec("sliding", width_s=120 * 86400.0, slide_s=300.0),
        store=store), broker)


@dataclasses.dataclass
class OffloadDecision:
    offload: bool
    n_records: int
    reason: str


class HybridExecutor:
    """Runs a service's window either on the edge or on the VDC path."""

    def __init__(self, edge_budget: int = EDGE_WINDOW_BUDGET):
        self.edge_budget = edge_budget
        self.offloads = 0
        self.edge_runs = 0

    def decide(self, n_records: int) -> OffloadDecision:
        if n_records <= self.edge_budget:
            return OffloadDecision(False, n_records,
                                   f"fits edge budget ({self.edge_budget})")
        return OffloadDecision(True, n_records,
                               "window exceeds edge compute/RAM — VDC JIT")

    def run_window(self, values: np.ndarray, agg: str, *,
                   stride: Optional[int] = None) -> float:
        d = self.decide(len(values))
        if not d.offload:
            self.edge_runs += 1
            return aggregate(values, agg)
        self.offloads += 1
        # VDC path: fold the 1-D range into the TPU's 128 lanes so the
        # Pallas segment kernel reduces rows in parallel, then combine the
        # 128 per-lane partials.
        from repro.kernels.window_agg.kernel import INIT
        base = "sum" if agg == "mean" else agg
        n = len(values)
        cols = 128
        rows = -(-n // cols)
        fill = 0.0 if agg == "mean" else INIT[base]
        x = np.full((rows * cols,), fill, np.float32)
        x[:n] = values
        x2 = jnp.asarray(x).reshape(rows, cols)
        seg = window_aggregate(x2, agg=base, window=rows, stride=rows,
                               interpret=True)[0]          # [128]
        if agg == "max":
            return float(jnp.max(seg))
        if agg == "min":
            return float(jnp.min(seg))
        total = float(jnp.sum(seg))
        return total / n if agg == "mean" else total
