"""Time-indexed columnar store (the paper's Cassandra series stand-in).

Post-mortem observations live in time-chunked column arrays; services
combine range scans over the store with live broker streams (the 120-day
mean query). Chunks can be 'spilled' (dropped to a spill list) to model
the paper's buffer-space collaboration between edge RAM and VDC storage.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.pipeline.streams import Record


@dataclasses.dataclass
class Chunk:
    t0: float
    ts: np.ndarray                  # [n]
    cols: Dict[str, np.ndarray]    # each [n]
    spilled: bool = False


class TimeSeriesStore:
    def __init__(self, series: str, chunk_seconds: float = 3600.0,
                 edge_budget_chunks: int = 48):
        self.series = series
        self.chunk_seconds = chunk_seconds
        self.edge_budget_chunks = edge_budget_chunks
        self.chunks: List[Chunk] = []
        self._open: Optional[Tuple[float, List[Record]]] = None
        self.spill_events = 0

    # ---------------------------------------------------------------- write
    def append(self, rec: Record) -> None:
        c0 = (rec.ts // self.chunk_seconds) * self.chunk_seconds
        if self._open is None or self._open[0] != c0:
            self._flush_open()
            self._open = (c0, [])
        self._open[1].append(rec)

    def _flush_open(self) -> None:
        if self._open is None or not self._open[1]:
            return
        t0, recs = self._open
        keys = recs[0].values.keys()
        self.chunks.append(Chunk(
            t0=t0,
            ts=np.array([r.ts for r in recs]),
            cols={k: np.array([r.values[k] for r in recs]) for k in keys}))
        self._open = None
        # edge RAM budget: oldest chunks spill to "VDC storage"
        resident = [c for c in self.chunks if not c.spilled]
        for c in resident[:-self.edge_budget_chunks]:
            if not c.spilled:
                c.spilled = True
                self.spill_events += 1

    def flush(self) -> None:
        self._flush_open()

    # ----------------------------------------------------------------- read
    def scan(self, t_lo: float, t_hi: float, col: str,
             include_spilled: bool = True) -> np.ndarray:
        """Values of `col` with t_lo <= ts < t_hi (time-ordered)."""
        self.flush()
        out = []
        for c in self.chunks:
            if c.t0 + self.chunk_seconds <= t_lo or c.t0 >= t_hi:
                continue
            if c.spilled and not include_spilled:
                continue
            m = (c.ts >= t_lo) & (c.ts < t_hi)
            out.append(c.cols[col][m])
        return np.concatenate(out) if out else np.array([])

    def count(self, t_lo: float, t_hi: float) -> int:
        return len(self.scan(t_lo, t_hi, next(iter(
            self.chunks[0].cols)) if self.chunks else "x"))

    @property
    def resident_chunks(self) -> int:
        return sum(1 for c in self.chunks if not c.spilled)
