"""Edge-based Data Science pipeline services (paper §3, Fig. 1-2).

Services implement big data/stream operators (aggregation, analytics) and
compose into pipelines by data-flow mash-up. Each service follows the
paper's architecture: Fetch → buffer (with a data-management strategy) →
OperatorLogic → Sink, driven by a recurrence scheduler. Services run on
the EDGE (host NumPy/JAX-CPU) and spill to the VDC just in time when the
task outgrows the edge (queries.py).
"""
from repro.pipeline.streams import Broker, StreamProducer, NeubotFarm
from repro.pipeline.store import TimeSeriesStore
from repro.pipeline.service import StreamService, ServiceConfig
from repro.pipeline.operators import (WindowSpec, aggregate, kmeans,
                                      linear_regression)
from repro.pipeline.composition import Pipeline
from repro.pipeline.queries import (neubot_query_1, neubot_query_2,
                                    HybridExecutor)
