"""Pipeline mash-up (paper §3): services compose by connecting Sinks to
Fetches, expressing a data flow. A Pipeline advances all producers, then
all services in topological order.

The data-flow edges are recorded so downstream tooling (e.g. the
edge↔DC placement engine, ``repro.placement``) can recover the service
DAG: an edge (u, q) means service ``u``'s sink republishes into queue
``q``; the consumers of ``q`` are u's downstream services.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.pipeline.service import StreamService
from repro.pipeline.streams import Broker, NeubotFarm


class Pipeline:
    def __init__(self, broker: Broker):
        self.broker = broker
        self.farms: List[NeubotFarm] = []
        self.services: List[StreamService] = []
        self.edges: List[Tuple[str, str]] = []   # (upstream name, queue)

    def add_farm(self, farm: NeubotFarm) -> "Pipeline":
        self.farms.append(farm)
        return self

    def add_service(self, svc: StreamService) -> "Pipeline":
        self.services.append(svc)
        return self

    def connect(self, upstream: StreamService, downstream_queue: str) -> None:
        """Sink of `upstream` republishes into `downstream_queue`."""
        q = self.broker.queue(downstream_queue)

        def sink(res: Dict) -> None:
            from repro.pipeline.streams import Record
            q.publish(Record(ts=res["ts"], values={"value": res["value"]}))

        upstream.connect(sink)
        self.edges.append((upstream.cfg.name, downstream_queue))

    def topology(self) -> Dict[str, List[str]]:
        """Service DAG: name -> upstream service names (empty for services
        fed directly by producer queues)."""
        topo: Dict[str, List[str]] = {}
        for svc in self.services:
            topo[svc.cfg.name] = [u for (u, q) in self.edges
                                  if q == svc.cfg.queue]
        return topo

    def advance_to(self, ts: float) -> Dict[str, List[Dict]]:
        for farm in self.farms:
            farm.advance_to(ts)
        out: Dict[str, List[Dict]] = {}
        for svc in self.services:
            out[svc.cfg.name] = svc.run_until(ts)
        return out
