"""Big data/stream operators (paper §3): windowed aggregations and the
analytics services (k-means, linear regression) implemented in JAX so the
same operator runs on the edge (CPU) or a VDC submesh.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    kind: str          # sliding | landmark
    width_s: float     # window width (ignored for landmark)
    slide_s: float     # recurrence / stride


def aggregate(values: np.ndarray, agg: str) -> float:
    """Edge-path aggregation over one window (numpy, tiny)."""
    if len(values) == 0:
        return float("nan")
    return float({"max": np.max, "min": np.min, "mean": np.mean,
                  "sum": np.sum, "count": len}[agg](values))


@jax.jit
def _kmeans_step(centers, xs):
    d = jnp.sum((xs[:, None, :] - centers[None]) ** 2, -1)
    assign = jnp.argmin(d, axis=1)
    onehot = jax.nn.one_hot(assign, centers.shape[0], dtype=xs.dtype)
    counts = jnp.maximum(onehot.sum(0), 1.0)
    new = (onehot.T @ xs) / counts[:, None]
    return new, assign


def kmeans(xs: jnp.ndarray, k: int, iters: int = 20, seed: int = 0
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Lloyd's k-means (the paper's analytics service example)."""
    key = jax.random.PRNGKey(seed)
    idx = jax.random.choice(key, xs.shape[0], (k,), replace=False)
    centers = xs[idx]
    for _ in range(iters):
        centers, assign = _kmeans_step(centers, xs)
    return centers, assign


def linear_regression(x: jnp.ndarray, y: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """OLS fit via normal equations (analytics service)."""
    X = jnp.stack([jnp.ones_like(x), x], axis=1)
    beta = jnp.linalg.solve(X.T @ X, X.T @ y)
    resid = y - X @ beta
    return beta, resid


# ---------------------------------------------------------------------------
# CNN analytics service (the paper's §3 operator list includes CNN): a tiny
# 1-D conv classifier over fixed-length measurement windows — e.g. labeling
# connectivity traces as {stable, degraded, bursty}. Same JAX code runs on
# the edge or a VDC submesh.
# ---------------------------------------------------------------------------
def init_cnn_classifier(key, window: int = 64, n_classes: int = 3,
                        channels: int = 8):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "conv1": jax.random.normal(k1, (5, 1, channels)) * 0.3,
        "conv2": jax.random.normal(k2, (5, channels, channels)) * 0.2,
        "head": jax.random.normal(k3, (channels, n_classes)) * 0.3,
    }


def cnn_classify(params, windows: jnp.ndarray) -> jnp.ndarray:
    """windows: [B, T] series → logits [B, n_classes]. Standardizes per
    window; max-pools over time (bursts are sparse events)."""
    mu = jnp.mean(windows, axis=1, keepdims=True)
    sd = jnp.std(windows, axis=1, keepdims=True) + 1e-6
    x = ((windows - mu) / sd)[..., None]                      # [B, T, 1]
    for w in (params["conv1"], params["conv2"]):
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(2,), padding="SAME",
            dimension_numbers=("NWC", "WIO", "NWC"))
        x = jax.nn.relu(x)
    pooled = jnp.max(x, axis=1)                               # [B, C]
    return pooled @ params["head"]
