"""The paper's stream-service architecture (Fig. 2): a scheduler drives the
recurrence; Fetch consumes notified streams into a bounded internal buffer
(with a data-management strategy that collaborates with the store when RAM
is short); OperatorLogic applies the analytics operation; Sink forwards
results to connected services.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.pipeline.operators import WindowSpec, aggregate
from repro.pipeline.store import TimeSeriesStore
from repro.pipeline.streams import Broker, Queue, Record


@dataclasses.dataclass
class ServiceConfig:
    name: str
    queue: str                    # input stream queue
    column: str                   # field to aggregate
    agg: str                      # max | min | mean | sum | count
    window: WindowSpec
    buffer_budget: int = 4096     # edge RAM (records) for the internal buffer
    store: Optional[TimeSeriesStore] = None  # post-mortem history source


class StreamService:
    """One big data/stream operator service (edge-resident)."""

    def __init__(self, cfg: ServiceConfig, broker: Broker):
        self.cfg = cfg
        self.q: Queue = broker.queue(cfg.queue)
        self.q.register(cfg.name)
        self.buffer: List[Record] = []
        self.results: List[Dict] = []
        self.sinks: List[Callable[[Dict], None]] = []
        self._next_fire = cfg.window.slide_s
        self.buffer_evictions = 0
        # observers (e.g. the conservation taps) see each eviction batch
        # without re-scanning the buffer; None when nobody listens
        self._spill_hook: Optional[Callable[[List[Record]], None]] = None

    # ---- Fetch: unlimited consumption of notified records ----------------
    def fetch(self) -> int:
        recs = self.q.fetch(self.cfg.name)
        buf = self.buffer
        buf.extend(recs)
        # data-management strategy: records older than the window spill to
        # the store (if attached) instead of being lost (paper §3)
        horizon = buf[-1].ts - self.cfg.window.width_s if buf else 0.0
        keep = [r for r in buf if r.ts >= horizon]
        spill = ([r for r in buf if r.ts < horizon]
                 if len(keep) != len(buf) else [])
        if len(keep) > self.cfg.buffer_budget:
            spill.extend(keep[:-self.cfg.buffer_budget])
            keep = keep[-self.cfg.buffer_budget:]
        if spill:
            self.buffer_evictions += len(spill)
            store = self.cfg.store
            if store is not None:
                for r in spill:
                    store.append(r)
            if self._spill_hook is not None:
                self._spill_hook(spill)
        self.buffer = keep
        return len(recs)

    # ---- OperatorLogic ----------------------------------------------------
    def _window_values(self, now: float) -> np.ndarray:
        w = self.cfg.window
        lo = 0.0 if w.kind == "landmark" else now - w.width_s
        vals = [r.values[self.cfg.column] for r in self.buffer
                if lo <= r.ts < now]
        if self.cfg.store is not None and (not self.buffer
                                           or self.buffer[0].ts > lo):
            # history beyond the buffer comes from the store; clamp to `now`
            # (catch-up fires must not see records from their future)
            hi = min(self.buffer[0].ts, now) if self.buffer else now
            vals = list(self.cfg.store.scan(lo, hi, self.cfg.column)) + vals
        return np.asarray(vals)

    def fire(self, now: float) -> Optional[Dict]:
        vals = self._window_values(now)
        res = {"service": self.cfg.name, "ts": now,
               "agg": self.cfg.agg, "n": len(vals),
               "value": aggregate(vals, self.cfg.agg)}
        self.results.append(res)
        for sink in self.sinks:
            sink(res)
        return res

    # ---- Scheduler: recurrence rate (paper Fig. 2) -------------------------
    def run_until(self, now: float) -> List[Dict]:
        out = []
        self.fetch()
        while self._next_fire <= now:
            out.append(self.fire(self._next_fire))
            self._next_fire += self.cfg.window.slide_s
        return out

    def connect(self, sink: Callable[[Dict], None]) -> None:
        self.sinks.append(sink)
