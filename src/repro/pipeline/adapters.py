"""Async-capable operator adapters.

:meth:`~repro.pipeline.service.StreamService.run_until` fuses the Fig. 2
recurrence — fetch, then fire every due window — into one synchronous
call. An event-loop runtime needs *time between the halves*: the window
is snapshotted when the fire is dispatched, but the operator only runs
(and its sinks only publish) once the placed device finishes executing,
possibly much later and on another site. :class:`StageAdapter` splits
the recurrence accordingly and adds the dispatch-time introspection the
serving layer needs (window size, newly covered records and their
origins — for shipping cost — and input-queue backlog — for
backpressure) without touching the operator classes themselves.

The adapter expects the pipeline to be instrumented with the
conservation taps (:func:`repro.scenario.ledger.tap_pipeline`): the taps
own the covered-record set and the per-record origin attribution the
preview reads, and they record the canonical ``FireRec`` trace when
:meth:`fire` finally runs.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple

from repro.pipeline.service import StreamService


class StageAdapter:
    """One service, split into dispatch-time and completion-time halves.

    The adapter is only safe under *serial* use (one in-flight fire per
    service): :meth:`preview_cover` assumes nothing covers records
    between the dispatch that previewed them and the :meth:`fire` that
    claims them — which is exactly the serving runtime's model of an
    operator instance."""

    def __init__(self, svc: StreamService, qtap, stap):
        self.svc = svc
        self.qtap = qtap            # _QueueTap of the input queue
        self.stap = stap            # _ServiceTap of this service
        self.name = svc.cfg.name
        self.slide_s = svc.cfg.window.slide_s

    def fire_times(self, horizon_s: float) -> Iterator[float]:
        """The service's fire grid over the horizon — same float
        accumulation as ``run_until``'s ``_next_fire`` so the engine's
        drive and the runtime schedule byte-identical fire sets."""
        t = self.slide_s
        while t <= horizon_s:
            yield t
            t += self.slide_s

    # ---- dispatch-time half ----------------------------------------------
    def fetch(self) -> int:
        """Consume the input queue into the operator buffer (Fetch)."""
        return self.svc.fetch()

    def peek_window(self, ts: float) -> int:
        """Window size the fire at ``ts`` will aggregate — what the
        placed device's execution time is charged for."""
        return int(len(self.svc._window_values(ts)))

    def preview_cover(self, ts: float
                      ) -> Tuple[int, Dict[Optional[str], int]]:
        """(n_new, origins) the fire at ``ts`` will newly cover, without
        mutating the tap's covered set: the runtime needs per-origin
        record counts *at dispatch* to ship cross-site inputs, while the
        tap claims coverage only when the operator actually fires."""
        n_new = 0
        origins: Dict[Optional[str], int] = {}
        for r in self.svc.buffer:
            if id(r) not in self.stap.covered and r.ts < ts:
                n_new += 1
                o = self.qtap.origin.get(id(r))
                origins[o] = origins.get(o, 0) + 1
        return n_new, origins

    def backlog(self) -> int:
        """Unfetched records in this stage's input queue (what an
        upstream publisher backpressures on)."""
        return self.svc.q.backlog(self.name)

    # ---- completion-time half --------------------------------------------
    def fire(self, ts: float) -> Optional[Dict]:
        """Run OperatorLogic for the window at logical time ``ts`` and
        let the Sinks publish downstream. Called at the fire's *virtual
        completion* instant — the window is still the dispatch-time
        snapshot because the stage is serial and only ``fetch`` mutates
        the buffer."""
        return self.svc.fire(ts)
