"""AdamW with fp32 master moments, decoupled weight decay.

State is a pytree mirroring params — GSPMD shards it identically to the
params (ZeRO-style: sharded over "data" via the FSDP rules), so optimizer
memory scales down with the mesh like the weights do.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    """moment_dtype=bfloat16 halves optimizer memory (the §Perf Cell B
    queued lever for 70B-class training on 16 GiB chips); updates still
    accumulate through fp32 inside adamw_update."""
    zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    count = state.count + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - b1 ** c
    bc2 = 1.0 - b2 ** c

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        mdt = m.dtype
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p - lr * step).astype(p.dtype), m.astype(mdt), v.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    outs = [upd(g, m, v, p)
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return new_params, AdamWState(mu=new_mu, nu=new_nu, count=count)
