from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import cosine_schedule, linear_warmup
from repro.optim.clipping import global_norm, clip_by_global_norm
