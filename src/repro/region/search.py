"""Decomposed placement search for hierarchical fleets.

A 500-site fleet makes the joint per-service site choice set explode:
``(sites + dc_options)^services`` is astronomically larger than any
screening budget. But a hierarchical fleet is *loosely coupled*: a
service chain rooted in one region almost always wants to execute
inside that region (its raw records live there) or in the DC — placing
it on an arbitrary third region's gateway pays two RAP trunks for
nothing. ``region_search`` exploits that structure:

  1. ``partition_services`` groups the services by the region of their
     root farm queue and caps each region's candidate-site list
     (farm sites first, then the beefiest boxes) so every per-region
     block space is enumerable;
  2. a block-coordinate pass sweeps the regions: each region's block of
     services is screened over its own candidate space — budgets scale
     with *that region's* space via ``_default_top_k`` — while every
     other region stays pinned at the current plan, so the global
     screening model prices cross-region edge-tier and RAP-trunk
     contention on full fleet-wide plans, never on an isolated slice;
  3. finalists (the composed winner plus single-region runner-up swaps,
     optionally re-ranked by a fluid drift ensemble) are re-scored with
     the exact DES alongside the anchor plans, bounding any screening
     mis-rank exactly like the flat ``screened_search``.

``region_search_exact`` is the analytic twin for scorers without a
screening model (the online controller's ``ForecastModel``): a
block-coordinate greedy descent over the same partition, warm-started
from the incumbent plan so successive epochs cost a handful of model
evaluations instead of a cold search.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.placement.plan import (PlacementPlan, ServicePlacement, SITE_DC,
                                  service_options)
from repro.placement.search import (Evaluator, SearchResult, _default_top_k,
                                    _score)
from repro.region.hier import regions_view


@dataclasses.dataclass(frozen=True)
class RegionPartition:
    """One region's slice of the search problem: the services whose
    chains are rooted there and the candidate edge sites the search may
    place them on (every service can additionally go to the DC)."""
    region: str
    services: Tuple[str, ...]
    sites: Tuple[str, ...]


def _root_of(svc: str, topology: Mapping[str, Sequence[str]]) -> str:
    """Walk a service's upstream chain to its root (first upstream at
    every hop — the dominant record source, as in ``ForecastModel``)."""
    seen = set()
    cur = svc
    while True:
        ups = topology.get(cur) or ()
        if not ups or cur in seen:
            return cur
        seen.add(cur)
        cur = ups[0]


def partition_services(fleet, topology: Mapping[str, Sequence[str]],
                       farm_site_of: Mapping[str, str],
                       max_sites_per_region: int = 12
                       ) -> List[RegionPartition]:
    """Group services by the region of their root farm queue.

    ``farm_site_of`` maps each *root* service to the site its input
    queue's farm is pinned to; chained services inherit their root's
    region. Regions with no services are dropped. Each partition's
    candidate-site list is capped at ``max_sites_per_region``: the
    member services' farm sites always make the cut, the rest of the
    region is ranked by device capability (FLOP/s, then name for
    determinism)."""
    regions = regions_view(fleet)
    region_of = {s: i for i, r in enumerate(regions) for s in r.sites}
    by_region: Dict[int, List[str]] = {}
    needed: Dict[int, List[str]] = {}
    for svc in topology:
        root = _root_of(svc, topology)
        site = farm_site_of.get(root) or farm_site_of.get(svc)
        if site is None:
            raise KeyError(f"no farm site known for root {root!r} "
                           f"(service {svc!r})")
        ri = region_of[site]
        by_region.setdefault(ri, []).append(svc)
        needed.setdefault(ri, []).append(site)
    out: List[RegionPartition] = []
    for ri, r in enumerate(regions):
        svcs = by_region.get(ri)
        if not svcs:
            continue
        sites = list(r.sites)
        if len(sites) > max_sites_per_region:
            must = [s for s in dict.fromkeys(needed[ri]) if s in set(sites)]
            rest = sorted((s for s in sites if s not in set(must)),
                          key=lambda n: (-fleet.site(n).edge.flops_per_s, n))
            sites = (must + rest)[:max(max_sites_per_region, len(must))]
        out.append(RegionPartition(region=r.name, services=tuple(svcs),
                                   sites=tuple(sites)))
    return out


def _partition_from_screener(screener, fleet,
                             max_sites_per_region: int
                             ) -> List[RegionPartition]:
    farm_site_of = {s: screener.site_names[sv["farm_site"]]
                    for s, sv in screener._svc.items()}
    return partition_services(fleet, screener.topology, farm_site_of,
                              max_sites_per_region)


def _block_rows(n_opts: int, width: int, enumerate_limit: int,
                sample_budget: int, seed: int) -> np.ndarray:
    """All option-index rows of one region's block when the space
    enumerates under the limit, else a seeded sample."""
    space = n_opts ** width
    if space <= enumerate_limit:
        grids = np.meshgrid(*([np.arange(n_opts)] * width), indexing="ij")
        return np.stack(grids, axis=-1).reshape(-1, width)
    rng = np.random.default_rng(seed)
    return rng.integers(0, n_opts, size=(sample_budget, width))


def _home_edge_plan(partitions: Sequence[RegionPartition],
                    topology: Mapping[str, Sequence[str]],
                    farm_site_of: Mapping[str, str]) -> PlacementPlan:
    """Every chain on its root's farm site — the natural all-edge anchor
    at fleet scale (one all-edge plan per site would be 500 anchors)."""
    out = {}
    for part in partitions:
        for svc in part.services:
            root = _root_of(svc, topology)
            out[svc] = ServicePlacement(farm_site_of[root])
    return PlacementPlan(out)


def region_search(cosim,
                  chips_options: Sequence[int] = (4, 8),
                  dvfs_options: Sequence[float] = (1.0,),
                  seed: int = 0,
                  partitions: Optional[Sequence[RegionPartition]] = None,
                  max_sites_per_region: int = 12,
                  sweeps: int = 2,
                  final_k: int = 6,
                  enumerate_limit: int = 65536,
                  sample_budget: int = 2048,
                  evaluator: Optional[Evaluator] = None,
                  warm_start: Optional[PlacementPlan] = None,
                  ensemble=None, risk="cvar",
                  corrections=None) -> SearchResult:
    """Decomposed screened search over a hierarchical fleet (see the
    module docstring for the three-stage structure). ``warm_start``
    seeds the block-coordinate pass (the online controller passes its
    incumbent); ``ensemble`` + ``risk`` optionally rank the finalists
    by a fluid drift ensemble before the exact tier, exactly like
    ``robust_search``. Deterministic for a fixed seed."""
    ev = evaluator or Evaluator(cosim)
    screener = ev.screener
    if screener is None:
        raise ValueError(f"{type(cosim).__name__} exposes no "
                         "screening_model; use region_search_exact")
    hits0, misses0 = ev.hits, ev.misses
    fleet = cosim.cfg.fleet if hasattr(cosim, "cfg") else cosim.fleet
    if partitions is None:
        partitions = _partition_from_screener(screener, fleet,
                                              max_sites_per_region)
    order = list(screener.order)
    rank = {s: i for i, s in enumerate(order)}
    farm_site_of = {s: screener.site_names[sv["farm_site"]]
                    for s, sv in screener._svc.items()}

    # global option table: every region's candidate sites + the DC grid.
    # Option indices are shared across regions so one full-width matrix
    # can hold any composition of per-region blocks.
    all_sites: List[str] = []
    for part in partitions:
        for s in part.sites:
            if s not in all_sites:
                all_sites.append(s)
    # warm-start / anchor placements may sit on sites outside the capped
    # candidate lists — keep them representable
    for plan in ([warm_start] if warm_start is not None else []):
        for p in plan.assignments.values():
            if p.is_edge and p.site not in all_sites:
                all_sites.append(p.site)
    options = service_options(chips_options, dvfs_options, all_sites)
    if warm_start is not None:
        # warm-start DC placements may use chips/DVFS outside the grid
        known = {(o.site, o.chips if not o.is_edge else 0,
                  o.dvfs_f if not o.is_edge else 0.0) for o in options}
        for p in warm_start.assignments.values():
            k = (p.site, p.chips if not p.is_edge else 0,
                 p.dvfs_f if not p.is_edge else 0.0)
            if k not in known:
                known.add(k)
                options.append(p)
    opt_idx = {(o.site, o.chips if not o.is_edge else 0,
                o.dvfs_f if not o.is_edge else 0.0): i
               for i, o in enumerate(options)}
    dc_opts = [i for i, o in enumerate(options) if not o.is_edge]
    site_opt = {o.site: i for i, o in enumerate(options) if o.is_edge}

    def row_of(plan: PlacementPlan) -> np.ndarray:
        row = np.empty(len(order), dtype=int)
        for si, s in enumerate(order):
            p = plan.placement(s)
            row[si] = opt_idx[(p.site, p.chips if not p.is_edge else 0,
                               p.dvfs_f if not p.is_edge else 0.0)]
        return row

    prev_corr = (screener.set_corrections(corrections)
                 if corrections is not None else None)
    t0 = time.perf_counter()
    region_stats: Dict[str, Dict] = {}
    runner_up: Dict[str, List[np.ndarray]] = {}
    try:
        # start: warm incumbent or the first-DC-option anchor
        cur = row_of(warm_start) if warm_start is not None else row_of(
            PlacementPlan.all_dc(order, chips=chips_options[0],
                                 dvfs_f=dvfs_options[0]))
        screened = 0
        for sweep in range(max(1, sweeps)):
            for ri, part in enumerate(partitions):
                cols = [rank[s] for s in part.services]
                # this region's choice set: its own edge sites + the DC
                sub = [site_opt[s] for s in part.sites] + dc_opts
                space_r = len(sub) ** len(cols)
                top_k_r = _default_top_k(space_r, enumerate_limit)
                B = _block_rows(len(sub), len(cols), enumerate_limit,
                                sample_budget,
                                seed * 7919 + sweep * 131 + ri)
                sub_arr = np.asarray(sub)
                P = np.tile(cur, (len(B), 1))
                P[:, cols] = sub_arr[B]
                # delta-aware: only this region's columns vary, so the
                # pinned complement is scored once (bit-identical to the
                # dense screen_matrix; see ScreeningModel.score_block)
                scores = ev.screen_block(P, cols, options)
                screened += len(P)
                best_rows = np.argsort(-scores, kind="stable")
                cur = P[best_rows[0]].copy()
                # the region's screening shortlist beyond the winner
                # feeds the finalist swaps; its depth scales with the
                # region's own block space
                runner_up[part.region] = [P[i].copy()
                                          for i in best_rows[1:top_k_r]]
                region_stats[part.region] = {
                    "services": len(cols),
                    "candidate_sites": len(part.sites),
                    "space": int(space_r),
                    "top_k": int(top_k_r),
                    "screened": int(len(P)),
                    "best_screen_vos": float(scores[best_rows[0]]),
                }
    finally:
        if corrections is not None:
            screener.set_corrections(prev_corr)
    screen_wall = time.perf_counter() - t0

    def plan_of(row: np.ndarray) -> PlacementPlan:
        return PlacementPlan({s: options[int(row[si])]
                              for si, s in enumerate(order)})

    # finalists: composed winner + single-region runner-up swaps, round-
    # robin over regions so every region's shortlist is represented
    finalists: List[PlacementPlan] = [plan_of(cur)]
    for depth in range(max(len(v) for v in runner_up.values())
                       if runner_up else 0):
        for part in partitions:
            alts = runner_up.get(part.region, [])
            if depth >= len(alts):
                continue
            row = cur.copy()
            cols = [rank[s] for s in part.services]
            row[cols] = alts[depth][cols]
            finalists.append(plan_of(row))
    seen = set()
    finalists = [p for p in finalists
                 if not (p.key() in seen or seen.add(p.key()))]
    finalists = finalists[:max(1, final_k)]

    anchors = [PlacementPlan.all_dc(order, chips=c, dvfs_f=dvfs_options[0])
               for c in chips_options]
    anchors.append(_home_edge_plan(partitions, screener.topology,
                                   farm_site_of))
    if warm_start is not None:
        anchors.append(warm_start)

    robust_stats = None
    if ensemble is not None:
        from repro.fluid.robust import RiskSpec, risk_score
        rs = RiskSpec.of(risk if risk is not None else "mean")
        fin_keys = {p.key() for p in finalists}
        cands = finalists + [a for a in anchors if a.key() not in fin_keys]
        t1 = time.perf_counter()
        fr = ensemble.evaluate(cands, corrections=corrections)
        fluid_wall = time.perf_counter() - t1
        scores = risk_score(fr.vos, rs)
        ordr = np.argsort(-scores, kind="stable")
        finalists = [cands[i] for i in ordr[:max(1, final_k)]]
        robust_stats = {"risk": rs.label,
                        "ensemble": int(ensemble.n_realizations),
                        "candidates": len(cands),
                        "fluid_wall_s": round(fluid_wall, 4)}

    # exact tier: DES on finalists + anchors (memoized; a parallel
    # evaluator fans the uncached ones out, merge order is fixed)
    best_plan: Optional[PlacementPlan] = None
    best = None
    for plan, res in zip(finalists + anchors,
                         ev.evaluate_batch(finalists + anchors)):
        if best is None or _score(res) > _score(best):
            best_plan, best = plan, res
    assert best_plan is not None and best is not None

    screen_stats = {
        "space": int(sum(r["space"] for r in region_stats.values())),
        "screened": int(screened),
        "screen_wall_s": round(screen_wall, 4),
        "regions": region_stats,
        "sweeps": int(max(1, sweeps)),
        "finalists": len(finalists),
        "anchors": len(anchors),
        "warm_started": warm_start is not None,
        "calibrated": corrections is not None,
        "agreement": bool(finalists
                          and finalists[0].key() == best_plan.key()),
    }
    delta = getattr(screener, "delta_stats", None)
    if delta is not None:
        screen_stats["delta"] = delta()
    if robust_stats is not None:
        screen_stats["robust"] = robust_stats
    method = ("region-screened" if ensemble is None
              else "region-screened+fluid")
    return SearchResult(best_plan, best, method, ev.misses - misses0,
                        ev.history, screen=screen_stats,
                        cache_hits=ev.hits - hits0,
                        cache_misses=ev.misses - misses0)


def region_search_exact(model,
                        chips_options: Sequence[int] = (4, 8),
                        dvfs_options: Sequence[float] = (1.0,),
                        seed: int = 0,
                        partitions: Optional[Sequence[RegionPartition]]
                        = None,
                        max_sites_per_region: int = 12,
                        sweeps: int = 2,
                        evaluator: Optional[Evaluator] = None,
                        warm_start: Optional[PlacementPlan] = None
                        ) -> SearchResult:
    """Analytic block-coordinate twin of :func:`region_search` for
    scorers without a screening model (the online ``ForecastModel``):
    per-service greedy descent restricted to each service's own region
    sites + the DC grid, swept region by region, warm-started from the
    incumbent. Every evaluation is an O(services) model call, so an
    epoch's re-plan costs ``sweeps × Σ_r services_r × options_r`` calls
    instead of a cold joint search."""
    ev = evaluator or Evaluator(model)
    hits0, misses0 = ev.hits, ev.misses
    info = model.info
    fleet = info.fleet
    if partitions is None:
        farm_site_of = {s: fleet.farm_site(i.queue)
                        for s, i in info.services.items()}
        partitions = partition_services(fleet, model.topology, farm_site_of,
                                        max_sites_per_region)
    names = [s for part in partitions for s in part.services]

    farm_site_of = {s: fleet.farm_site(i.queue)
                    for s, i in info.services.items()}
    if warm_start is not None:
        current = warm_start
    else:
        current = PlacementPlan.all_dc(names, chips=chips_options[0],
                                       dvfs_f=dvfs_options[0])
    score = _score(ev(current))

    for _ in range(max(1, sweeps)):
        improved = False
        for part in partitions:
            opts = service_options(chips_options, dvfs_options, part.sites)
            for svc in part.services:
                for opt in opts:
                    if opt == current.assignments[svc]:
                        continue
                    cand = current.with_placement(svc, opt)
                    s = _score(ev(cand))
                    if s > score:
                        current, score = cand, s
                        improved = True
        if not improved:
            break

    # anchors keep the exact guarantee: searched >= home-edge / all-DC
    anchors = [PlacementPlan.all_dc(names, chips=c, dvfs_f=dvfs_options[0])
               for c in chips_options]
    anchors.append(_home_edge_plan(partitions, model.topology,
                                   farm_site_of))
    best_plan, best = current, ev(current)
    for plan, res in zip(anchors, ev.evaluate_batch(anchors)):
        if _score(res) > _score(best):
            best_plan, best = plan, res
    region_stats = {part.region: {"services": len(part.services),
                                  "candidate_sites": len(part.sites)}
                    for part in partitions}
    return SearchResult(best_plan, best, "region-exact",
                        ev.misses - misses0, ev.history,
                        screen={"regions": region_stats,
                                "warm_started": warm_start is not None},
                        cache_hits=ev.hits - hits0,
                        cache_misses=ev.misses - misses0)
