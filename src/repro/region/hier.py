"""Hierarchical fleet topology: edge sites → regional aggregation
points (RAPs) → DC core.

A flat :class:`~repro.online.fleet.FleetSpec` models one shared WAN
uplink for the whole fleet — fine for a handful of gateways, wrong at
planet scale where hundreds of sites hang off *regional* aggregation
points and only the RAP trunks converge on the DC core. A
:class:`HierFleetSpec` partitions the sites into :class:`RegionSpec`s:
each region gets its own contended edge-tier pipe (the per-region twin
of the flat uplink) and a RAP trunk link whose RAP→DC direction is a
second FIFO tier. Same-region traffic turns around at the RAP; only
cross-region and edge→DC traffic transits the trunks.

Backward compatibility is *exact*: wrapping a flat fleet as a single
region with the :data:`TRANSPARENT_RAP` (infinite trunk bandwidth, zero
RTT, zero per-byte energy) routes every transfer bit-identically to the
flat fleet — the runtime (:class:`repro.online.fleet.Fleet`) skips
transparent RAP legs entirely, and the one edge-tier pipe *is* the old
shared uplink. ``degenerate()`` builds that wrapper; the regression
suite pins the equivalence.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Tuple

from repro.online.fleet import FleetSpec, SiteSpec, transparent_link
from repro.placement.network import LinkSpec

#: The no-op RAP: a one-region hierarchy with this trunk is
#: bit-identical to the flat fleet (every RAP leg short-circuits).
TRANSPARENT_RAP = LinkSpec(uplink_bps=math.inf, downlink_bps=math.inf,
                           rtt_s=0.0, energy_per_byte_j=0.0)

#: A realistic metro-aggregation trunk: fat pipes (fiber backhaul), one
#: extra metro hop of latency. Generators default to scaled versions.
DEFAULT_RAP = LinkSpec(uplink_bps=2e9, downlink_bps=4e9, rtt_s=0.012,
                       energy_per_byte_j=4e-9)


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """One region: its member edge sites and the RAP trunk link that
    carries the region's traffic to/from the DC core. ``sites`` are
    names into the enclosing fleet's site list."""
    name: str
    sites: Tuple[str, ...]
    rap: LinkSpec = dataclasses.field(default_factory=lambda: DEFAULT_RAP)

    def __post_init__(self):
        if not self.name:
            raise ValueError("a region needs a name")
        if not self.sites:
            raise ValueError(f"region {self.name!r} has no sites")
        if len(set(self.sites)) != len(self.sites):
            raise ValueError(f"region {self.name!r}: duplicate sites")

    @property
    def transparent(self) -> bool:
        return transparent_link(self.rap)


@dataclasses.dataclass(frozen=True)
class HierFleetSpec(FleetSpec):
    """A fleet whose sites are partitioned into regions. With
    ``regions=()`` it degrades to a plain flat fleet; with regions the
    partition must be exact — every site in exactly one region."""
    regions: Tuple[RegionSpec, ...] = ()

    def __post_init__(self):
        super().__post_init__()
        if not self.regions:
            return
        names = [r.name for r in self.regions]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate region names: {names}")
        site_names = set(self.site_names)
        seen: Dict[str, str] = {}
        for r in self.regions:
            for s in r.sites:
                if s not in site_names:
                    raise ValueError(
                        f"region {r.name!r} claims unknown site {s!r}")
                if s in seen:
                    raise ValueError(
                        f"site {s!r} in both regions {seen[s]!r} "
                        f"and {r.name!r}")
                seen[s] = r.name
        missing = site_names - set(seen)
        if missing:
            raise ValueError(
                f"sites in no region: {sorted(missing)} — regions must "
                "partition the fleet exactly")

    # ------------------------------------------------------------- queries
    def region_of(self, site: str) -> str:
        """Region name of ``site`` (fleets built without regions place
        everything in an implicit region named after the fleet)."""
        return self.region_index()[site]

    def region_index(self) -> Mapping[str, str]:
        cached = getattr(self, "_region_index", None)
        if cached is None:
            cached = {s: r.name for r in self.regions for s in r.sites}
            object.__setattr__(self, "_region_index", cached)
        return cached

    def region(self, name: str) -> RegionSpec:
        for r in self.regions:
            if r.name == name:
                return r
        raise KeyError(name)

    @classmethod
    def degenerate(cls, flat: FleetSpec,
                   name: str = "global") -> "HierFleetSpec":
        """Wrap a flat fleet as a one-region hierarchy with a
        transparent RAP — routes bit-identically to ``flat`` (the
        regression suite pins this)."""
        return cls(sites=flat.sites, user_site=flat.user_site,
                   regions=(RegionSpec(name, flat.site_names,
                                       rap=TRANSPARENT_RAP),))


def regions_view(fleet: FleetSpec) -> Tuple[RegionSpec, ...]:
    """The one-transparent-region reading of any fleet: hierarchical
    fleets return their declared regions, flat fleets one region over
    all sites with the transparent RAP. Every per-region consumer
    (screen, forecast, fluid) goes through this so the flat path is the
    degenerate case of the hierarchical one, not a separate branch."""
    declared = tuple(getattr(fleet, "regions", ()) or ())
    if declared:
        return declared
    return (RegionSpec("fleet", fleet.site_names, rap=TRANSPARENT_RAP),)
