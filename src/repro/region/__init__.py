"""Planet-scale hierarchical fleets: regions, RAP trunks, synthetic
fleet generation and decomposed placement search.

  hier.py      RegionSpec / HierFleetSpec — edge sites → regional
               aggregation points (RAPs) → DC core, per-tier FIFO
               contention; a flat FleetSpec is the degenerate
               one-region hierarchy with a transparent RAP
               (bit-identical routing, pinned by regression tests)
  generate.py  FleetGenSpec / generate_fleet — seeded synthetic
               O(100–1000)-site heterogeneous fleets with per-region
               drift phases and pipeline chains
  search.py    partition_services / region_search — decompose the
               placement search by origin region: per-region screened
               candidate generation (budgets scaled to each region's
               own space), global cross-region coordination, exact DES
               on the finalists; region_search_exact is the analytic
               twin the warm-started online controller runs each epoch

Only ``hier`` is imported eagerly (it depends just on the fleet/network
models); the generator and search resolve lazily so importing
``repro.region`` from ``repro.scenario.spec`` cannot cycle back through
the scenario/placement packages.
"""
from repro.region.hier import (DEFAULT_RAP, HierFleetSpec, RegionSpec,
                               TRANSPARENT_RAP, regions_view)

_GENERATE_NAMES = ("FleetGenSpec", "generate_fleet", "hier_fleet_spec")
_SEARCH_NAMES = ("RegionPartition", "partition_services", "region_search",
                 "region_search_exact")

__all__ = ["RegionSpec", "HierFleetSpec", "TRANSPARENT_RAP", "DEFAULT_RAP",
           "regions_view", *_GENERATE_NAMES, *_SEARCH_NAMES]


def __getattr__(name):
    if name in _GENERATE_NAMES:
        from repro.region import generate
        return getattr(generate, name)
    if name in _SEARCH_NAMES:
        from repro.region import search
        return getattr(search, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
