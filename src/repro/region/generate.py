"""Seeded synthetic hierarchical fleets: O(100–1000) heterogeneous
edge sites grouped into regions, each with its own pipeline chain,
drift phase and RAP trunk.

``generate_fleet`` is deterministic per :class:`FleetGenSpec` — the
same spec always yields the same :class:`~repro.scenario.spec
.ScenarioSpec`, field for field (the property suite pins this), so
benchmark scenarios at planet scale stay reproducible data rather than
hand-written builders.

The workload shape keeps the *DES* tractable while the *fleet* scales:
fires scale with services (``n_regions × services_per_region``), not
with sites, so a 500-site scenario co-simulates in seconds — the
placement *search space* is what explodes with sites, which is exactly
what the decomposed ``region_search`` exists to handle.
"""
from __future__ import annotations

import dataclasses
import random
from typing import List, Optional, Tuple

from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.region.hier import HierFleetSpec, RegionSpec
from repro.online.fleet import SiteSpec
from repro.scenario.spec import (FarmSpec, RateSpec, ScenarioSpec,
                                 ServiceSpec)
from repro.scenario.profiles import ServiceSLO


@dataclasses.dataclass(frozen=True)
class FleetGenSpec:
    """Knobs of the synthetic fleet generator. Everything downstream of
    ``seed`` is deterministic."""
    name: str = "hier-fleet"
    n_sites: int = 500
    n_regions: int = 8
    services_per_region: int = 3     # chain length: agg → trend → post…
    seed: int = 0
    horizon_s: float = 3600.0
    epoch_s: Optional[float] = None  # None → one static epoch
    base_rate_hz: float = 5.0
    drift: str = "diurnal"           # constant | diurnal | bursts
    outage_regions: int = 0          # first K regions lose their farm site
    rap_uplink_bps: float = 1.5e9
    rap_rtt_s: float = 0.012
    things_per_farm: int = 8

    def __post_init__(self):
        if self.n_regions < 1:
            raise ValueError("n_regions must be >= 1")
        if self.n_sites < self.n_regions:
            raise ValueError("need at least one site per region")
        if self.services_per_region < 1:
            raise ValueError("services_per_region must be >= 1")
        if self.drift not in ("constant", "diurnal", "bursts"):
            raise ValueError(f"unknown drift kind {self.drift!r}")
        if not 0 <= self.outage_regions <= self.n_regions:
            raise ValueError("outage_regions out of range")


def _site(rng: random.Random, name: str) -> SiteSpec:
    """One heterogeneous gateway: ingest-bound box (slow record pump,
    frugal active power) on a thin last-mile link with compact
    delta-coded records — the regime where the edge/DC optimum actually
    flips with the record rate instead of one side winning outright."""
    box = 2.0 ** rng.uniform(-1.0, 1.0)       # box class: ¼×–4× spread
    edge = EdgeSpec(
        name=name,
        throughput_rps=2000.0 * box,
        flops_per_s=20e9 * box,
        ram_bytes=float(rng.choice((128, 256, 512, 1024)) * 2 ** 20),
        energy_per_record_j=50e-6 * 2.0 ** rng.uniform(-0.5, 0.5),
        active_power_w=1.0 * 2.0 ** rng.uniform(-0.5, 0.5))
    link = LinkSpec(
        uplink_bps=15e3 * 2.0 ** rng.uniform(-1.0, 1.0),
        downlink_bps=2e6 * 2.0 ** rng.uniform(-1.0, 1.0),
        rtt_s=rng.uniform(0.030, 0.080),
        record_bytes=64.0, compression=0.25)
    return SiteSpec(name=name, edge=edge, link=link)


def _rate(gen: FleetGenSpec, rng: random.Random, region: int) -> RateSpec:
    """Region-phase-shifted drift so regions peak at different times —
    what makes per-region re-placement decisions diverge."""
    base = gen.base_rate_hz * 2.0 ** rng.uniform(-0.5, 0.5)
    if gen.drift == "constant":
        return RateSpec.constant(base)
    if gen.drift == "diurnal":
        # ~9× swing: troughs sit below the edge/DC flip point, peaks
        # above it, so the per-region optimum genuinely moves per epoch
        return RateSpec.diurnal(
            base, amplitude=0.8,
            period_s=gen.horizon_s,
            phase_s=region * gen.horizon_s / max(1, gen.n_regions))
    # bursts: staggered per-region surge windows
    t0 = (0.15 + 0.6 * region / max(1, gen.n_regions)) * gen.horizon_s
    return RateSpec.bursts(base, burst_hz=base * 4.0,
                           windows=[(t0, t0 + 0.15 * gen.horizon_s)])


def generate_fleet(gen: FleetGenSpec) -> ScenarioSpec:
    """Spec → scenario: ``n_sites`` heterogeneous gateways partitioned
    into ``n_regions`` regions (each with a RAP trunk), one pipeline
    chain per region rooted at a farm pinned inside the region."""
    rng = random.Random(gen.seed * 9_176_003 + 17)

    # -------------------------------------------------------------- sites
    counts = [gen.n_sites // gen.n_regions
              + (1 if r < gen.n_sites % gen.n_regions else 0)
              for r in range(gen.n_regions)]
    sites: List[SiteSpec] = []
    regions: List[RegionSpec] = []
    region_sites: List[List[str]] = []
    for r in range(gen.n_regions):
        names = [f"r{r:02d}-s{i:03d}" for i in range(counts[r])]
        region_sites.append(names)
        for n in names:
            sites.append(_site(rng, n))
        rap = LinkSpec(
            uplink_bps=gen.rap_uplink_bps * 2.0 ** rng.uniform(-0.5, 0.5),
            downlink_bps=2.0 * gen.rap_uplink_bps
            * 2.0 ** rng.uniform(-0.5, 0.5),
            rtt_s=gen.rap_rtt_s * 2.0 ** rng.uniform(-0.3, 0.3),
            energy_per_byte_j=4e-9)
        regions.append(RegionSpec(name=f"region-{r:02d}",
                                  sites=tuple(names), rap=rap))

    # ----------------------------------------------------- farms, services
    farms: List[FarmSpec] = []
    services: List[ServiceSpec] = []
    outages: List[Tuple[str, Tuple[Tuple[float, float], ...]]] = []
    farm_pin: List[Tuple[str, str]] = []    # (queue, site)
    for r in range(gen.n_regions):
        queue = f"r{r:02d}-q"
        farm_site = region_sites[r][rng.randrange(counts[r])]
        farm_pin.append((queue, farm_site))
        farms.append(FarmSpec(queue=queue, n_things=gen.things_per_farm,
                              seed=gen.seed * 101 + r,
                              rate=_rate(gen, rng, r)))
        # the region's services form a fan: a light windowing root and
        # the heavy analytics stages both read the *raw* farm queue
        # (that is where the record volume — hence the edge/DC placement
        # tension — lives); further light stages chain off the root's
        # republished aggregates. Per-region flops jitter means some
        # regions' heavy stage fits their beefier boxes while others
        # must offload — regional optima genuinely diverge.
        chain_q = queue
        for k in range(gen.services_per_region):
            name = f"r{r:02d}-svc{k}"
            heavy = (k % 2 == 1)
            if heavy:
                services.append(ServiceSpec(
                    name=name, queue=queue, column="latency_ms",
                    agg="mean", width_s=300.0, slide_s=60.0,
                    buffer_budget=16384,
                    slo=ServiceSLO(soft_latency_s=5.0, hard_latency_s=15.0,
                                   soft_energy_j=80.0, hard_energy_j=400.0,
                                   gamma=2.0),
                    flops_per_record=2e8 * 2.0 ** rng.uniform(-1.0, 1.0),
                    bytes_per_record=16.0))
            else:
                root = (chain_q == queue)
                out_q = (f"r{r:02d}-out{k}"
                         if k + 2 < gen.services_per_region else None)
                # the root windows raw records on a per-fire energy
                # budget spanning the VDC floor (~2.3 J for a 4-chip
                # tile): edge fires cost well under a joule at the rate
                # trough and blow the hard threshold at the peak, so
                # drift moves it across the edge/DC flip point each
                # epoch; chained stages fire rarely and stay loose
                services.append(ServiceSpec(
                    name=name, queue=chain_q,
                    column="download_speed" if root else "value",
                    agg="max" if root else "mean",
                    width_s=120.0 if root else 300.0,
                    slide_s=30.0 if root else 60.0,
                    buffer_budget=8192,
                    publishes_to=out_q,
                    slo=(ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                                    soft_energy_j=0.3, hard_energy_j=3.0)
                         if root else
                         ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                                    soft_energy_j=1.0, hard_energy_j=60.0)),
                    flops_per_record=2e3))
                chain_q = out_q if out_q else chain_q
        if r < gen.outage_regions:
            outages.append((farm_site,
                            ((0.45 * gen.horizon_s, 0.65 * gen.horizon_s),)))

    # pin each farm queue to its site
    pin = dict(farm_pin)
    sites = [dataclasses.replace(
        s, farm_queues=tuple(q for q, st in pin.items() if st == s.name))
        for s in sites]

    spec = ScenarioSpec(
        name=f"{gen.name}-{gen.n_sites}x{gen.n_regions}",
        services=tuple(services), farms=tuple(farms),
        sites=tuple(sites), user_site=region_sites[0][0],
        regions=tuple(regions),
        horizon_s=gen.horizon_s, epoch_s=gen.epoch_s,
        dc_step_floor_s=2e-3,
        # windowed aggregators migrate their accumulator state, not raw
        # record buffers — keeps epoch-scale re-placement affordable on
        # thin last-mile links
        state_bytes_per_record=1.0,
        outages=tuple(outages))
    spec.validate()
    return spec


def hier_fleet_spec(spec: ScenarioSpec) -> HierFleetSpec:
    """The fleet topology of a generated scenario (convenience for
    callers that want the :class:`HierFleetSpec` without compiling)."""
    return HierFleetSpec(sites=spec.sites, user_site=spec.user_site,
                         regions=spec.regions)
