"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — device counts are only locked
in when a launcher actually builds a mesh (dryrun.py sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 first).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_submesh(n_chips: int, *, model_parallel: int = 16) -> Mesh:
    """A VDC submesh: n_chips arranged as (data, model). Used by the VoS
    scheduler (core/vdc.py) when composing per-job virtual data centers."""
    model = min(model_parallel, n_chips)
    while n_chips % model:
        model //= 2
    data = n_chips // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def make_dev_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh for CPU tests."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
