"""The full JITA-4DS loop on real (reduced) jobs: the VoS scheduler
composes VDCs (here: job slots on the host), launches actual training jobs
per assignment, earns value on completion — the end-to-end integration of
core/ with the training substrate.

  PYTHONPATH=src python -m repro.launch.schedule_run --jobs 6 --heuristic VPTR
"""
from __future__ import annotations

import argparse
import time

from repro.core.costmodel import CostModel
from repro.core.emulator import measure_step_time
from repro.core.heuristics import HEURISTICS
from repro.core.simulator import Simulator
from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator
from repro.launch.train import train_loop

EDGE_ARCHS = ["smollm-135m", "qwen3-1.7b", "mamba2-1.3b"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--heuristic", default="VPTR", choices=sorted(HEURISTICS))
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cost = CostModel.analytic()
    types = [TaskType(a, "train_4k") for a in EDGE_ARCHS]
    gen = WorkloadGenerator(types, cost, seed=0, **PAPER_REGIME)
    trace = gen.trace(args.jobs)

    sim = Simulator(HEURISTICS[args.heuristic], cost)
    result = sim.run([t for t in trace])
    print(f"[plan] {args.heuristic}: VoS={result.vos:.1f} "
          f"completed={result.completed}/{args.jobs}")

    # execute the planned jobs for real (reduced configs, host execution)
    for task in result.tasks:
        if task.start is None:
            print(f"  job {task.tid} ({task.ttype.name}): not scheduled")
            continue
        t0 = time.perf_counter()
        _, losses = train_loop(task.ttype.arch, steps=args.steps, batch=2,
                               seq=64, log_every=10**9)
        dt = time.perf_counter() - t0
        print(f"  job {task.tid} ({task.ttype.arch:14s}): "
              f"planned {task.chips} chips f={task.dvfs_f:.1f} "
              f"V̂={task.earned:.2f} | ran {args.steps} real steps in "
              f"{dt:.1f}s loss {losses[0]:.3f}->{losses[-1]:.3f}")


if __name__ == "__main__":
    main()
