import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, print memory/cost analysis, and emit roofline reports.

MUST run as its own process (`python -m repro.launch.dryrun`) so XLA_FLAGS
takes effect before jax initializes devices.

Roofline methodology (see EXPERIMENTS.md §Roofline): XLA's cost_analysis
counts while-loop bodies ONCE, so per-cell costs are measured on two
fully-UNROLLED depth variants (r=1 and r=2 layer groups, python loops for
every inner scan) and extrapolated linearly to the true depth R:

    total(R) = C(1) + (R - 1) · [C(2) - C(1)]

which is exact because the layer stack is homogeneous per group. The full
scanned program is still compiled for the memory analysis (its peak is the
real one) and for the multi-pod shardability proof.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out results/dryrun
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import roofline as RL
from repro import sharding as shd
from repro.configs import (SHAPES, ArchConfig, ShapeSpec, get_arch,
                           list_archs, supports_shape)
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.train import TrainHParams, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

TRAIN_ACCUM = 4


def _variant_cfg(cfg: ArchConfig, r: int) -> ArchConfig:
    """Depth-r variant: r repeats of the layer pattern (enc scaled too)."""
    pattern, _ = cfg.scan_groups()
    repl = {"n_layers": len(pattern) * r}
    if cfg.enc_dec is not None:
        repl["enc_dec"] = dataclasses.replace(cfg.enc_dec, n_enc_layers=r)
    return dataclasses.replace(cfg, **repl)


def lower_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, *,
               unroll: bool = False, grad_accum: int = TRAIN_ACCUM,
               verbose: bool = True, hp: Optional[TrainHParams] = None):
    """Lower + compile one (arch × shape) cell on `mesh`."""
    if hp is None:
        accum = cfg.grad_accum if grad_accum == TRAIN_ACCUM else grad_accum
        hp = TrainHParams(grad_accum=accum if shape.kind == "train" else 1,
                          unroll=unroll)
    if shape.kind == "train" and hp.grad_accum > 1:
        # §Perf Cell B, H2 lesson: a microbatch that does not divide the
        # data-axis width silently replicates ALL compute across it.
        width = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                width *= mesh.shape[a]
        micro = shape.global_batch // hp.grad_accum
        if micro % width and width % micro:
            print(f"  WARNING: microbatch {micro} vs batch-shard width "
                  f"{width}: compute will replicate (fix grad_accum)")
    with shd.use_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, hp)
            state_sds = SP.train_state_sds(cfg)
            state_sh = SP.train_state_shardings(mesh, cfg)
            batch_sds = SP.batch_specs(cfg, shape)
            batch_sh = SP.batch_shardings(mesh, cfg, shape)
            jf = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,))
            lowered = jf.lower(state_sds, batch_sds)
        elif shape.kind == "prefill":
            params_sds = SP.param_sds(cfg, jnp.bfloat16)
            params_sh = SP.param_shardings(mesh, cfg, "serve")
            batch_sds = SP.batch_specs(cfg, shape)
            batch_sh = SP.batch_shardings(mesh, cfg, shape)
            cache_sh = SP.cache_shardings(mesh, cfg, shape.global_batch)
            logits_sh = NamedSharding(
                mesh, P(shd.batch_axes_for(mesh, shape.global_batch), "model"))

            def prefill(params, batch):
                return M.prefill(cfg, params, batch, shape.seq_len,
                                 q_chunk=1024, unroll=unroll)

            jf = jax.jit(prefill, in_shardings=(params_sh, batch_sh),
                         out_shardings=(logits_sh, cache_sh))
            lowered = jf.lower(params_sds, batch_sds)
        else:  # decode
            B = shape.global_batch
            long_ctx = B == 1
            params_sds = SP.param_sds(cfg, jnp.bfloat16)
            params_sh = SP.param_shardings(
                mesh, cfg, "serve_long" if long_ctx else "serve")
            cache_sds = SP.cache_sds(cfg, B, shape.seq_len)
            cache_sh = SP.cache_shardings(mesh, cfg, B, long_ctx)
            b_ax = shd.batch_axes_for(mesh, B)
            tok_sh = NamedSharding(mesh, P(b_ax, None))
            logits_sh = NamedSharding(mesh, P(b_ax, "model"))

            def decode(params, cache, token, pos):
                return M.decode_step(cfg, params, cache, token, pos,
                                     unroll=unroll)

            jf = jax.jit(decode,
                         in_shardings=(params_sh, cache_sh, tok_sh, None),
                         out_shardings=(logits_sh, cache_sh),
                         donate_argnums=(1,))
            lowered = jf.lower(params_sds, cache_sds,
                               jax.ShapeDtypeStruct((B, 1), jnp.int32),
                               jax.ShapeDtypeStruct((), jnp.int32))
        t0 = time.time()
        compiled = lowered.compile()
        if verbose:
            print(f"    compiled in {time.time() - t0:.1f}s "
                  f"({'unrolled' if unroll else 'scanned'}, "
                  f"{cfg.n_layers} layers)")
    return compiled, lowered


def extrapolated_costs(cfg: ArchConfig, shape: ShapeSpec, mesh,
                       verbose: bool = True, hp=None):
    """Per-device (flops, bytes, coll_bytes, counts) extrapolated to true R."""
    pattern, R = cfg.scan_groups()
    c = {}
    for r in (1, 2):
        cfg_r = _variant_cfg(cfg, r)
        compiled, _ = lower_cell(cfg_r, shape, mesh, unroll=True,
                                 verbose=verbose, hp=hp)
        c[r] = RL.raw_costs(compiled)
    flops = c[1][0] + (R - 1) * (c[2][0] - c[1][0])
    nbytes = c[1][1] + (R - 1) * (c[2][1] - c[1][1])
    coll = c[1][2] + (R - 1) * (c[2][2] - c[1][2])
    counts = {k: c[1][3].get(k, 0) + (R - 1) * (c[2][3].get(k, 0)
                                                - c[1][3].get(k, 0))
              for k in set(c[1][3]) | set(c[2][3])}
    if verbose:
        dfl = c[2][0] - c[1][0]
        print(f"    variants: r1_flops={c[1][0]:.3e} r2-r1={dfl:.3e} "
              f"R={R} -> total={flops:.3e}")
    return flops, nbytes, coll, counts


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             out_dir: Optional[str] = None, verbose: bool = True,
             skip_roofline: bool = False):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = supports_shape(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        print(f"SKIP {arch} × {shape_name} [{mesh_name}]: {why}")
        return "skip"
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    print(f"CELL {arch} × {shape_name} [{mesh_name}] kind={shape.kind}")

    # 1) full scanned program: shardability proof + true peak memory
    compiled, _ = lower_cell(cfg, shape, mesh, verbose=verbose)
    ma = compiled.memory_analysis()
    print(f"  memory_analysis(/dev): args={ma.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
          f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
          f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB")
    cost = compiled.cost_analysis()
    print(f"  cost_analysis(/dev, loop bodies once): "
          f"flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")
    mem = (ma.argument_size_in_bytes, ma.temp_size_in_bytes,
           ma.output_size_in_bytes)
    if skip_roofline or multi_pod:
        # multi-pod pass proves the "pod" axis shards; roofline is 1-pod only
        rep = None
    else:
        flops, nbytes, coll, counts = extrapolated_costs(
            cfg, shape, mesh, verbose=verbose)
        rep = RL.analyze_costs(flops, nbytes, coll, counts, cfg, shape,
                               mesh_name, chips, mem=mem)
        print(f"  roofline: t_comp={rep.t_compute:.4f}s "
              f"t_mem={rep.t_memory:.4f}s t_coll={rep.t_collective:.4f}s "
              f"-> {rep.bottleneck}-bound; useful={rep.useful_ratio:.3f} "
              f"frac={rep.roofline_fraction:.1%}")
        print(f"  collectives: {rep.collective_counts}")
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            fn = f"{arch}__{shape_name}__{mesh_name}.json"
            with open(os.path.join(out_dir, fn), "w") as f:
                json.dump(rep.to_dict(), f, indent=1)
    if out_dir and multi_pod:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{arch}__{shape_name}__{mesh_name}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump({"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "compiled": True,
                       "arg_bytes": ma.argument_size_in_bytes,
                       "temp_bytes": ma.temp_size_in_bytes,
                       "out_bytes": ma.output_size_in_bytes}, f, indent=1)
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    reports, failures, n_cells = [], [], 0
    for mp in pods:
        for a in archs:
            for s in shapes:
                try:
                    rep = run_cell(a, s, mp, out_dir=args.out,
                                   skip_roofline=args.skip_roofline)
                    if rep not in (None, "skip"):
                        reports.append(rep)
                    if rep != "skip":
                        n_cells += 1
                except Exception as e:
                    failures.append((a, s, mp, repr(e)))
                    traceback.print_exc()
    if reports:
        print("\n" + RL.format_table(reports))
    print(f"\n{n_cells} cells compiled, {len(failures)} failures")
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)


if __name__ == "__main__":
    main()
