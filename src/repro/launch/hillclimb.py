import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Perf hillclimbing driver (§Perf): re-lower a cell under candidate
changes (sharding rules, mesh/submesh geometry, accum, serve profile) and
report the roofline-term deltas vs the recorded baseline.

  python -m repro.launch.hillclimb --arch smollm-135m --shape train_4k \
      --mesh 4x4 --accum 1
"""
import argparse
import contextlib
import json
from typing import Dict, Optional

import jax
from jax.sharding import AxisType

from repro import roofline as RL
from repro import sharding as shd
from repro.configs import SHAPES, get_arch
from repro.launch import dryrun as DR
from repro.train import TrainHParams


def make_mesh(spec: str):
    dims = [int(x) for x in spec.split("x")]
    names = {1: ("model",), 2: ("data", "model"),
             3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(tuple(dims), names,
                         axis_types=(AxisType.Auto,) * len(dims))


@contextlib.contextmanager
def rule_override(profile: str, **updates):
    """Temporarily rewrite logical-axis rules, e.g. heads=('data','model')."""
    rules = shd.PROFILES[profile]
    saved = dict(rules)
    rules.update({k: tuple(v) if isinstance(v, (list, tuple)) else (v,)
                  for k, v in updates.items()})
    try:
        yield
    finally:
        rules.clear()
        rules.update(saved)


def run_variant(arch: str, shape_name: str, *, mesh_spec: str = "16x16",
                accum: Optional[int] = None, q_chunk: int = 512,
                rules: Optional[Dict] = None, profile: str = "train",
                label: str = "variant", verbose: bool = True,
                skip_full: bool = False, **hp_kwargs):
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_mesh(mesh_spec)
    hp = hp_v = None
    if shape.kind == "train":
        hp_accum = accum if accum is not None else cfg.grad_accum
        hp = TrainHParams(grad_accum=hp_accum, q_chunk=q_chunk, **hp_kwargs)
        hp_v = TrainHParams(grad_accum=hp_accum, q_chunk=q_chunk,
                            unroll=True, **hp_kwargs)
    ctx = rule_override(profile, **rules) if rules else contextlib.nullcontext()
    with ctx:
        flops, nbytes, coll, counts = DR.extrapolated_costs(
            cfg, shape, mesh, verbose=verbose, hp=hp_v)
        if skip_full:
            class _MA:  # memory analysis from variants is meaningless;
                argument_size_in_bytes = 0  # caller opted out
                temp_size_in_bytes = 0
                output_size_in_bytes = 0
            ma = _MA()
        else:
            compiled, _ = DR.lower_cell(cfg, shape, mesh, hp=hp,
                                        verbose=False)
            ma = compiled.memory_analysis()
    rep = RL.analyze_costs(
        flops, nbytes, coll, counts, cfg, shape, mesh_spec, mesh.size,
        mem=(ma.argument_size_in_bytes, ma.temp_size_in_bytes,
             ma.output_size_in_bytes), note=label)
    if verbose:
        print(f"[{label}] {arch}×{shape_name} @{mesh_spec}: "
              f"t_comp={rep.t_compute:.4f} t_mem={rep.t_memory:.4f} "
              f"t_coll={rep.t_collective:.4f} -> {rep.bottleneck}; "
              f"frac={rep.roofline_fraction:.2%} "
              f"HBM={(rep.arg_bytes+rep.temp_bytes)/2**30:.1f}GiB")
    return rep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--label", default="variant")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rep = run_variant(args.arch, args.shape, mesh_spec=args.mesh,
                      accum=args.accum, q_chunk=args.q_chunk,
                      label=args.label)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rep.to_dict(), f, indent=1)


if __name__ == "__main__":
    main()
