"""ShapeDtypeStruct stand-ins for every model input (no device allocation)
plus sharding assignments for the dry-run / launchers."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as shd
from repro.configs import ArchConfig, ShapeSpec
from repro.models import model as M
from repro.optim import AdamWState
from repro.train.state import TrainState

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    """Input ShapeDtypeStructs for a train/prefill batch."""
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": SDS((B, S), jnp.int32)}
    if shape.kind == "train":
        out["labels"] = SDS((B, S), jnp.int32)
    if cfg.frontend == "patch_stub":
        out["patches"] = SDS((B, cfg.n_prefix_tokens, cfg.d_model),
                             jnp.bfloat16)
    if cfg.enc_dec is not None:
        out["frames"] = SDS((B, cfg.enc_dec.enc_seq, cfg.d_model),
                            jnp.bfloat16)
    return out


def batch_shardings(mesh: Mesh, cfg: ArchConfig, shape: ShapeSpec):
    b_ax = shd.batch_axes_for(mesh, shape.global_batch)
    out = {"tokens": NamedSharding(mesh, P(b_ax, None))}
    if shape.kind == "train":
        out["labels"] = NamedSharding(mesh, P(b_ax, None))
    if cfg.frontend == "patch_stub":
        out["patches"] = NamedSharding(mesh, P(b_ax, None, None))
    if cfg.enc_dec is not None:
        out["frames"] = NamedSharding(mesh, P(b_ax, None, None))
    return out


def param_sds(cfg: ArchConfig, dtype=jnp.float32):
    """Abstract param shapes via eval_shape (never materialized)."""
    sds = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    if dtype != jnp.float32:
        sds = jax.tree.map(lambda s: SDS(s.shape, dtype), sds)
    return sds


def train_state_sds(cfg: ArchConfig):
    p = param_sds(cfg)
    f32 = lambda t: jax.tree.map(lambda s: SDS(s.shape, jnp.float32), t)
    return TrainState(params=p, opt=AdamWState(mu=f32(p), nu=f32(p),
                                               count=SDS((), jnp.int32)),
                      step=SDS((), jnp.int32))


def param_shardings(mesh: Mesh, cfg: ArchConfig, profile: str):
    axes = M.param_axes(cfg)
    specs = shd.build_param_specs(mesh, axes, param_sds(cfg), profile)
    return shd.shardings_from_specs(mesh, specs)


def train_state_shardings(mesh: Mesh, cfg: ArchConfig):
    ps = param_shardings(mesh, cfg, "train")
    return TrainState(params=ps, opt=AdamWState(
        mu=ps, nu=ps, count=NamedSharding(mesh, P())),
        step=NamedSharding(mesh, P()))


def cache_sds(cfg: ArchConfig, batch: int, cache_len: int,
              dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: M.init_cache(cfg, batch, cache_len, dtype))


def cache_shardings(mesh: Mesh, cfg: ArchConfig, batch: int,
                    long_context: bool = False):
    """Walk the cache pytree and assign decode-profile specs (DESIGN §4)."""
    sds = cache_sds(cfg, batch, 8, jnp.bfloat16)  # structure only

    def spec_for(d):
        out = {}
        for name, leaf in d.items():
            if name in ("k", "v", "xk", "xv"):
                kv, dh = leaf.shape[-2], leaf.shape[-1]
                out[name] = shd.kv_cache_spec(mesh, batch, kv, dh,
                                              long_context)
            elif name == "conv":
                out[name] = P(None, shd.batch_axes_for(mesh, batch),
                              None, "model")
            elif name == "h":
                n_heads = leaf.shape[-3]
                out[name] = shd.ssm_cache_specs(mesh, batch, n_heads)["h"]
            else:  # pragma: no cover
                out[name] = P(*([None] * len(leaf.shape)))
        return out

    specs = tuple(spec_for(d) for d in sds)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
