"""Serving launcher: batched prefill + decode with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data import make_batch
from repro.models import model as M
from repro.train.serve_step import greedy_generate


def serve_demo(arch: str, *, batch: int = 4, prompt_len: int = 64,
               gen: int = 32, full: bool = False, seed: int = 0):
    cfg = get_arch(arch) if full else get_arch(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    bd = make_batch(cfg, prompt_len, batch, 0, seed)
    bd.pop("labels", None)
    bd = {k: jnp.asarray(v) for k, v in bd.items()}

    t0 = time.perf_counter()
    toks, cache = greedy_generate(cfg, params, bd, steps=gen,
                                  cache_len=prompt_len + gen)
    toks = np.asarray(toks)
    dt = time.perf_counter() - t0
    print(f"{arch}: generated {toks.shape} in {dt:.2f}s "
          f"({batch * gen / dt:.1f} tok/s incl. compile)")
    assert np.all((toks >= 0) & (toks < cfg.padded_vocab))
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    serve_demo(args.arch, batch=args.batch, prompt_len=args.prompt_len,
               gen=args.gen, full=args.full)


if __name__ == "__main__":
    main()
