"""Training launcher: end-to-end driver with checkpointing, failure
injection, straggler monitoring, and (optionally) a mesh.

CPU-friendly: reduced configs by default (--full uses the assigned config —
only sensible on real hardware).

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import sharding as shd
from repro.checkpoint import CheckpointManager, FailureInjector, run_with_restarts
from repro.configs import get_arch
from repro.data import ShardedLoader
from repro.models import model as M
from repro.runtime.straggler import StragglerMonitor
from repro.train import TrainHParams, TrainState, init_train_state, make_train_step


def train_loop(arch: str, *, steps: int = 100, batch: int = 8,
               seq: int = 128, full: bool = False, ckpt_dir: Optional[str] = None,
               save_every: int = 50, p_fail: float = 0.0, seed: int = 0,
               mesh=None, hp: Optional[TrainHParams] = None, log_every: int = 10):
    cfg = get_arch(arch) if full else get_arch(arch).reduced()
    hp = hp or TrainHParams(peak_lr=1e-3, warmup_steps=20, total_steps=steps,
                            grad_accum=1, remat="none")
    loader = ShardedLoader(cfg, seq, batch, mesh=mesh, seed=seed)
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    state = init_train_state(params)
    step_fn = jax.jit(make_train_step(cfg, hp), donate_argnums=(0,))

    mon = StragglerMonitor(n_hosts=1)
    losses = []

    def one_step(state, step):
        t0 = time.perf_counter()
        batch_d = loader(step)
        state, metrics = step_fn(state, batch_d)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        mon.record_step(step, [dt])
        losses.append(loss)
        if step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
        return state, {"loss": loss, "t": dt}

    with shd.use_mesh(mesh):
        if ckpt_dir:
            mgr = CheckpointManager(ckpt_dir, save_every=save_every)
            inj = FailureInjector(p_fail=p_fail, seed=seed)
            state, history, restarts = run_with_restarts(
                init_state=state, train_one_step=one_step, ckpt_manager=mgr,
                n_steps=steps, injector=inj)
            print(f"done: {len(history)} step records, {restarts} restarts")
        else:
            for step in range(steps):
                state, _ = one_step(state, step)
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--p-fail", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, losses = train_loop(args.arch, steps=args.steps, batch=args.batch,
                           seq=args.seq, full=args.full,
                           ckpt_dir=args.ckpt_dir,
                           save_every=args.save_every, p_fail=args.p_fail,
                           seed=args.seed)
    print(f"first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}")


if __name__ == "__main__":
    main()
