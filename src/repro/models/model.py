"""Model assembly: decoder-only LMs (dense/MoE/hybrid/SSM/VLM) and the
whisper encoder-decoder, all as pure-JAX pytrees.

Layer stacks are `lax.scan`s over *pattern groups* (configs.scan_groups):
params for each pattern position are stacked [R, ...] so HLO size is
O(pattern length), not O(n_layers) — 80-layer internvl2 lowers as one
scanned group. Caches mirror the same structure.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(cfg: ArchConfig, kind: str, key) -> Params:
    mixer, ff = kind.split("+")
    ks = jax.random.split(key, 4)
    p: Params = {}
    if mixer == "attn":
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["attn"] = L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm)
    else:
        p["ln1"] = L.init_rmsnorm(cfg.d_model)
        p["ssm"] = SSM.init_ssm(ks[0], cfg.d_model, cfg.ssm)
    if ff == "mlp":
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
    elif ff == "moe":
        p["ln2"] = L.init_rmsnorm(cfg.d_model)
        p["moe"] = MOE.init_moe(ks[1], cfg.d_model, cfg.moe)
    return p


def _block_axes(cfg: ArchConfig, kind: str) -> Params:
    mixer, ff = kind.split("+")
    p: Params = {}
    if mixer == "attn":
        p["ln1"] = L.rmsnorm_axes()
        p["attn"] = L.attention_axes(cfg.qk_norm)
    else:
        p["ln1"] = L.rmsnorm_axes()
        p["ssm"] = SSM.ssm_axes()
    if ff in ("mlp", "moe"):
        p["ln2"] = L.rmsnorm_axes()
        p["mlp" if ff == "mlp" else "moe"] = (
            L.mlp_axes() if ff == "mlp" else MOE.moe_axes())
    return p


def _init_dec_xblock(cfg: ArchConfig, key) -> Params:
    """Whisper decoder block: self-attn + cross-attn + mlp."""
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm),
        "ln_x": L.init_rmsnorm(cfg.d_model),
        "xattn": L.init_attention(ks[1], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.head_dim, cfg.qk_norm),
        "ln2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


def _dec_xblock_axes(cfg: ArchConfig) -> Params:
    return {
        "ln1": L.rmsnorm_axes(), "attn": L.attention_axes(cfg.qk_norm),
        "ln_x": L.rmsnorm_axes(), "xattn": L.attention_axes(cfg.qk_norm),
        "ln2": L.rmsnorm_axes(), "mlp": L.mlp_axes(),
    }


def init_params(cfg: ArchConfig, key) -> Params:
    pattern, R = cfg.scan_groups()
    keys = jax.random.split(key, 8)
    p: Params = {
        "embed": L.init_embedding(keys[0], cfg.padded_vocab, cfg.d_model),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.padded_vocab), jnp.float32)
            * (cfg.d_model ** -0.5))
    if cfg.frontend is not None:
        p["frontend_proj"] = L._dense_init(
            keys[2], (cfg.d_model, cfg.d_model), cfg.d_model)

    if cfg.enc_dec is not None:
        ek = jax.random.split(keys[3], cfg.enc_dec.n_enc_layers)
        p["enc_blocks"] = (jax.vmap(
            lambda k: _init_block(cfg, "attn+mlp", k))(ek),)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model)
        dk = jax.random.split(keys[4], cfg.n_layers)
        p["blocks"] = (jax.vmap(lambda k: _init_dec_xblock(cfg, k))(dk),)
    else:
        bk = jax.random.split(keys[4], R)
        blocks = []
        for i, kind in enumerate(pattern):
            kk = jax.vmap(lambda k, i=i: jax.random.fold_in(k, i))(bk)
            blocks.append(jax.vmap(
                lambda k, kind=kind: _init_block(cfg, kind, k))(kk))
        p["blocks"] = tuple(blocks)
    return p


def param_axes(cfg: ArchConfig) -> Params:
    from repro import sharding as shd
    pattern, _ = cfg.scan_groups()
    ax: Params = {
        "embed": ("vocab_in", "embed_in"),
        "final_norm": L.rmsnorm_axes(),
    }
    if not cfg.tie_embeddings:
        ax["unembed"] = ("embed", "vocab")
    if cfg.frontend is not None:
        ax["frontend_proj"] = ("embed", None)
    if cfg.enc_dec is not None:
        ax["enc_blocks"] = (shd.stack_axes(_block_axes(cfg, "attn+mlp")),)
        ax["enc_norm"] = L.rmsnorm_axes()
        ax["blocks"] = (shd.stack_axes(_dec_xblock_axes(cfg)),)
    else:
        ax["blocks"] = tuple(shd.stack_axes(_block_axes(cfg, kind))
                             for kind in pattern)
    return ax


# ---------------------------------------------------------------------------
# Embedding front
# ---------------------------------------------------------------------------
def _embed_inputs(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
                  dtype) -> jax.Array:
    from repro import sharding as shd
    tokens = batch["tokens"]
    h = L.embed_tokens(params["embed"], tokens, dtype)
    if cfg.frontend == "patch_stub":
        n = cfg.n_prefix_tokens
        patches = jnp.einsum("bnd,de->bne", batch["patches"].astype(dtype),
                             params["frontend_proj"].astype(dtype))
        h = jnp.concatenate([patches, h[:, n:]], axis=1)
    if cfg.positional == "sinusoidal":
        h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model).astype(dtype)
    return shd.constrain_batch(h)


# ---------------------------------------------------------------------------
# Block application (full-sequence: train / prefill)
# ---------------------------------------------------------------------------
def _apply_block(cfg: ArchConfig, kind: str, p: Params, h, aux, *,
                 mode: str, cache_len: int = 0, q_chunk: int = 512,
                 unroll: bool = False):
    """mode: 'train' | 'prefill'. Returns (h, aux, new_cache|None)."""
    mixer, ff = kind.split("+")
    new_cache = None
    if mixer == "attn":
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        kw = dict(n_kv=cfg.n_kv_heads, theta=cfg.rope_theta,
                  qk_norm=cfg.qk_norm, use_rope=cfg.positional == "rope",
                  q_chunk=q_chunk, unroll=unroll)
        if mode == "prefill":
            attn_out, kv = L.attention_prefill(p["attn"], x,
                                               cache_len=cache_len, **kw)
            new_cache = {"k": kv[0], "v": kv[1]}
        else:
            attn_out = L.attention_fwd(p["attn"], x, causal=True, **kw)
        h = h + attn_out
    else:
        x = L.rmsnorm(p["ln1"], h, cfg.norm_eps)
        if mode == "prefill":
            out, st = SSM.ssm_fwd(p["ssm"], x, cfg.d_model, cfg.ssm,
                                  return_state=True, unroll=unroll)
            new_cache = st
        else:
            out = SSM.ssm_fwd(p["ssm"], x, cfg.d_model, cfg.ssm,
                              unroll=unroll)
        h = h + out
    if ff == "mlp":
        h = h + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], h, cfg.norm_eps))
    elif ff == "moe":
        y, a = MOE.moe_fwd(p["moe"], L.rmsnorm(p["ln2"], h, cfg.norm_eps),
                           cfg.moe)
        h = h + y
        aux = aux + a
    return h, aux, new_cache


# ---------------------------------------------------------------------------
# Forward (train) — logits over the full sequence
# ---------------------------------------------------------------------------
def forward(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            compute_dtype=jnp.bfloat16, remat: str = "none",
            q_chunk: int = 512, unroll: bool = False
            ) -> Tuple[jax.Array, jax.Array]:
    from repro import sharding as shd
    dtype = compute_dtype
    h = _embed_inputs(cfg, params, batch, dtype)

    if cfg.enc_dec is not None:
        enc_h = _encoder_fwd(cfg, params, batch, dtype, remat, q_chunk, unroll)
        h = _decoder_fwd_full(cfg, params, h, enc_h, remat, q_chunk, unroll)
        aux = jnp.float32(0.0)
    else:
        pattern, _ = cfg.scan_groups()

        def group_body(carry, group_params):
            hh, aux = carry
            for kind, p in zip(pattern, group_params):
                hh, aux, _ = _apply_block(cfg, kind, p, hh, aux,
                                          mode="train", q_chunk=q_chunk,
                                          unroll=unroll)
            hh = shd.constrain_batch(hh)
            return (hh, aux), None

        body = _maybe_remat(group_body, remat)
        (h, aux) = _scan_groups(body, (h, jnp.float32(0.0)),
                                params["blocks"], unroll)

    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.logits_fwd(table, h, cfg.tie_embeddings, cfg.vocab_size)
    return shd.constrain_batch(logits, extra=("model",)), aux


def _scan_groups(body, carry, blocks, unroll: bool):
    """lax.scan over stacked layer groups, or a python loop when `unroll`
    (used by the dry-run cost variants for exact trip-count accounting)."""
    if not unroll:
        carry, _ = jax.lax.scan(body, carry, blocks)
        return carry
    R = jax.tree.leaves(blocks)[0].shape[0]
    for r in range(R):
        carry, _ = body(carry, jax.tree.map(lambda x: x[r], blocks))
    return carry


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save nothing, recompute all


def _encoder_fwd(cfg, params, batch, dtype, remat, q_chunk, unroll=False):
    frames = batch["frames"].astype(dtype)
    h = jnp.einsum("bsd,de->bse", frames, params["frontend_proj"].astype(dtype))
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model).astype(dtype)

    def body(hh, p):
        x = L.rmsnorm(p["ln1"], hh, cfg.norm_eps)
        hh = hh + L.attention_fwd(
            p["attn"], x, n_kv=cfg.n_kv_heads, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, causal=False, use_rope=False,
            q_chunk=q_chunk, unroll=unroll)
        hh = hh + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], hh, cfg.norm_eps))
        return hh, None

    h = _scan_groups(_maybe_remat(body, remat), h,
                     params["enc_blocks"][0], unroll)
    return L.rmsnorm(params["enc_norm"], h, cfg.norm_eps)


def _decoder_fwd_full(cfg, params, h, enc_h, remat, q_chunk, unroll=False):
    def body(hh, p):
        x = L.rmsnorm(p["ln1"], hh, cfg.norm_eps)
        hh = hh + L.attention_fwd(
            p["attn"], x, n_kv=cfg.n_kv_heads, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, causal=True, use_rope=False,
            q_chunk=q_chunk, unroll=unroll)
        x = L.rmsnorm(p["ln_x"], hh, cfg.norm_eps)
        # cross-attention: kv from encoder output
        kx = jnp.einsum("bsd,dhk->bshk", enc_h, p["xattn"]["wk"].astype(enc_h.dtype))
        vx = jnp.einsum("bsd,dhk->bshk", enc_h, p["xattn"]["wv"].astype(enc_h.dtype))
        hh = hh + L.attention_fwd(
            p["xattn"], x, n_kv=cfg.n_kv_heads, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, causal=False, use_rope=False,
            kv_override=(kx, vx), q_chunk=q_chunk, unroll=unroll)
        hh = hh + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], hh, cfg.norm_eps))
        return hh, None

    return _scan_groups(_maybe_remat(body, remat), h, params["blocks"][0],
                        unroll)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------
def loss_fn(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array], *,
            compute_dtype=jnp.bfloat16, remat: str = "none",
            q_chunk: int = 512, unroll: bool = False):
    logits, aux = forward(cfg, params, batch, compute_dtype=compute_dtype,
                          remat=remat, q_chunk=q_chunk, unroll=unroll)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    # one-hot contraction instead of take_along_axis: a gather over the
    # model-sharded vocab dim forces SPMD rematerialization; the einsum
    # partitions cleanly (and XLA fuses the one-hot into the reduction).
    V = logits.shape[-1]
    onehot = jax.nn.one_hot(safe, V, dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    lse = jax.nn.logsumexp(logits, axis=-1)
    nll = lse - label_logit
    n_valid = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / n_valid
    return loss + aux, {"loss": loss, "aux_loss": aux,
                        "n_tokens": n_valid.astype(jnp.float32)}


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, cache_len: int, dtype) -> Any:
    pattern, R = cfg.scan_groups()
    if cfg.enc_dec is not None:
        e = cfg.enc_dec
        kv = lambda s: jnp.zeros((cfg.n_layers, batch, s, cfg.n_kv_heads,
                                  cfg.head_dim), dtype)
        return ({"k": kv(cache_len), "v": kv(cache_len),
                 "xk": kv(e.enc_seq), "xv": kv(e.enc_seq)},)
    caches = []
    for kind in pattern:
        mixer = kind.split("+")[0]
        if mixer == "attn":
            shape = (R, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
            caches.append({"k": jnp.zeros(shape, dtype),
                           "v": jnp.zeros(shape, dtype)})
        else:
            st = SSM.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
            caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (R,) + x.shape), st))
    return tuple(caches)


# ---------------------------------------------------------------------------
# Prefill — full forward that also writes the cache; returns last logits
# ---------------------------------------------------------------------------
def prefill(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array],
            cache_len: int, *, compute_dtype=jnp.bfloat16, q_chunk: int = 512,
            unroll: bool = False):
    from repro import sharding as shd
    dtype = compute_dtype
    h = _embed_inputs(cfg, params, batch, dtype)

    if cfg.enc_dec is not None:
        enc_h = _encoder_fwd(cfg, params, batch, dtype, "none", q_chunk,
                             unroll)
        return _encdec_prefill(cfg, params, h, enc_h, cache_len, q_chunk,
                               unroll)

    pattern, _ = cfg.scan_groups()

    def group_body(carry, group_params):
        hh, aux = carry
        new_caches = []
        for kind, p in zip(pattern, group_params):
            hh, aux, c = _apply_block(cfg, kind, p, hh, aux, mode="prefill",
                                      cache_len=cache_len, q_chunk=q_chunk,
                                      unroll=unroll)
            new_caches.append(c)
        hh = shd.constrain_batch(hh)
        return (hh, aux), tuple(new_caches)

    if unroll:
        R = jax.tree.leaves(params["blocks"])[0].shape[0]
        carry = (h, jnp.float32(0.0))
        caches = []
        for r in range(R):
            carry, c = group_body(
                carry, jax.tree.map(lambda x: x[r], params["blocks"]))
            caches.append(c)
        (h, aux) = carry
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        (h, aux), cache = jax.lax.scan(group_body, (h, jnp.float32(0.0)),
                                       params["blocks"])
    h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.logits_fwd(table, h, cfg.tie_embeddings, cfg.vocab_size)[:, 0]
    return logits, cache


def _encdec_prefill(cfg, params, h, enc_h, cache_len, q_chunk, unroll=False):
    dtype = h.dtype

    def body(hh, p):
        x = L.rmsnorm(p["ln1"], hh, cfg.norm_eps)
        attn_out, kv = L.attention_prefill(
            p["attn"], x, n_kv=cfg.n_kv_heads, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, use_rope=False, cache_len=cache_len,
            q_chunk=q_chunk)
        hh = hh + attn_out
        x = L.rmsnorm(p["ln_x"], hh, cfg.norm_eps)
        kx = jnp.einsum("bsd,dhk->bshk", enc_h, p["xattn"]["wk"].astype(dtype))
        vx = jnp.einsum("bsd,dhk->bshk", enc_h, p["xattn"]["wv"].astype(dtype))
        hh = hh + L.attention_fwd(
            p["xattn"], x, n_kv=cfg.n_kv_heads, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, causal=False, use_rope=False,
            kv_override=(kx, vx), q_chunk=q_chunk)
        hh = hh + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], hh, cfg.norm_eps))
        return hh, {"k": kv[0], "v": kv[1], "xk": kx, "xv": vx}

    if unroll:
        R = jax.tree.leaves(params["blocks"][0])[0].shape[0]
        caches = []
        for r in range(R):
            h, c = body(h, jax.tree.map(lambda x: x[r], params["blocks"][0]))
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        h, cache = jax.lax.scan(body, h, params["blocks"][0])
    h = L.rmsnorm(params["final_norm"], h[:, -1:], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.logits_fwd(table, h, cfg.tie_embeddings, cfg.vocab_size)[:, 0]
    return logits, (cache,)


# ---------------------------------------------------------------------------
# Decode — one token with cache
# ---------------------------------------------------------------------------
def decode_step(cfg: ArchConfig, params: Params, cache: Any,
                token: jax.Array, pos, *, compute_dtype=jnp.bfloat16,
                unroll: bool = False):
    """token: [B, 1] int32; pos: scalar int32 (current write index)."""
    dtype = compute_dtype
    h = L.embed_tokens(params["embed"], token, dtype)
    if cfg.positional == "sinusoidal":
        h = h + L.sinusoidal_positions(1, cfg.d_model, offset=pos).astype(dtype)

    if cfg.enc_dec is not None:
        return _encdec_decode(cfg, params, cache, h, pos, unroll)

    pattern, _ = cfg.scan_groups()

    def group_body(hh, xs):
        group_params, group_cache = xs
        new_caches = []
        for kind, p, c in zip(pattern, group_params, group_cache):
            mixer, ff = kind.split("+")
            if mixer == "attn":
                x = L.rmsnorm(p["ln1"], hh, cfg.norm_eps)
                out, (k, v) = L.attention_decode(
                    p["attn"], x, (c["k"], c["v"]), pos, theta=cfg.rope_theta,
                    qk_norm=cfg.qk_norm, use_rope=cfg.positional == "rope")
                hh = hh + out
                new_caches.append({"k": k, "v": v})
            else:
                x = L.rmsnorm(p["ln1"], hh, cfg.norm_eps)
                out, st = SSM.ssm_decode(p["ssm"], x, c, cfg.d_model, cfg.ssm)
                hh = hh + out
                new_caches.append(st)
            if ff == "mlp":
                hh = hh + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], hh,
                                                        cfg.norm_eps))
            elif ff == "moe":
                y, _ = MOE.moe_fwd(p["moe"], L.rmsnorm(p["ln2"], hh,
                                                       cfg.norm_eps), cfg.moe)
                hh = hh + y
        return hh, tuple(new_caches)

    h, new_cache = _scan_with_cache(group_body, h, params["blocks"], cache,
                                    unroll)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.logits_fwd(table, h, cfg.tie_embeddings, cfg.vocab_size)[:, 0]
    return logits, new_cache


def _scan_with_cache(body, h, blocks, cache, unroll: bool):
    """scan carrying h with (params, cache) as xs and new cache as ys."""
    if not unroll:
        return jax.lax.scan(body, h, (blocks, cache))
    R = jax.tree.leaves(blocks)[0].shape[0]
    outs = []
    for r in range(R):
        h, c = body(h, jax.tree.map(lambda x: x[r], (blocks, cache)))
        outs.append(c)
    return h, jax.tree.map(lambda *xs: jnp.stack(xs), *outs)


def _encdec_decode(cfg, params, cache, h, pos, unroll=False):
    def body(hh, xs):
        p, c = xs
        x = L.rmsnorm(p["ln1"], hh, cfg.norm_eps)
        out, (k, v) = L.attention_decode(
            p["attn"], x, (c["k"], c["v"]), pos, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, use_rope=False)
        hh = hh + out
        x = L.rmsnorm(p["ln_x"], hh, cfg.norm_eps)
        hh = hh + L.attention_readonly(
            p["xattn"], x, (c["xk"], c["xv"]),
            qk_norm=cfg.qk_norm)
        hh = hh + L.mlp_fwd(p["mlp"], L.rmsnorm(p["ln2"], hh, cfg.norm_eps))
        return hh, {"k": k, "v": v, "xk": c["xk"], "xv": c["xv"]}

    h, new_cache = _scan_with_cache(body, h, params["blocks"][0], cache[0],
                                    unroll)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.logits_fwd(table, h, cfg.tie_embeddings, cfg.vocab_size)[:, 0]
    return logits, (new_cache,)
