"""int8 KV-cache quantization (the §Perf Cell C queued lever).

Decode at 32k context is memory-bound on cache reads; int8 storage with
per-(position, head) scales halves the cache bytes vs bf16 (values +
scales) and therefore the t_memory floor. Post-RoPE quantization,
KIVI/KVQuant-style (arXiv:2402.02750) per-token-per-head absmax scaling —
the TPU-friendly layout (scales broadcast along the 128-wide head_dim
lane axis).

Quantized caches slot into the same pytree positions as the bf16 ones:
{"k": int8 [.., S, KV, dh], "k_s": bf16 [.., S, KV, 1], same for v}.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: [..., dh] → (int8 values, bf16 scale[..., 1]); absmax per row."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16
                  ) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def init_quant_kv_cache(batch: int, cache_len: int, n_kv: int, head_dim: int):
    return {
        "k": jnp.zeros((batch, cache_len, n_kv, head_dim), jnp.int8),
        "k_s": jnp.zeros((batch, cache_len, n_kv, 1), jnp.bfloat16),
        "v": jnp.zeros((batch, cache_len, n_kv, head_dim), jnp.int8),
        "v_s": jnp.zeros((batch, cache_len, n_kv, 1), jnp.bfloat16),
    }


def update_quant_cache(cache, k_new: jax.Array, v_new: jax.Array, pos):
    """Masked one-hot write (GSPMD-friendly, see layers.attention_decode)."""
    Smax = cache["k"].shape[1]
    write = (jnp.arange(Smax) == pos)[None, :, None, None]
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    return {
        "k": jnp.where(write, kq, cache["k"]),
        "k_s": jnp.where(write, ks, cache["k_s"]),
        "v": jnp.where(write, vq, cache["v"]),
        "v_s": jnp.where(write, vs, cache["v_s"]),
    }


def attend_quant(q: jax.Array, cache, pos, *, dtype=jnp.bfloat16):
    """Decode attention over an int8 cache. q: [B, 1, H, dh] (post-RoPE).

    Scores computed against dequantized K with the per-row scale folded in
    AFTER the int8 dot (q·(s·k) = s·(q·k)), so the MXU contraction runs on
    the narrow type and the scale multiplies the [B,H,1,S] scores — the
    bandwidth win is preserved end to end.
    """
    import math
    B, _, H, dh = q.shape
    KV = cache["k"].shape[2]
    rep = H // KV
    kq, ks = cache["k"], cache["k_s"]
    vq, vs = cache["v"], cache["v_s"]
    if rep > 1:
        kq = jnp.repeat(kq, rep, axis=2)
        ks = jnp.repeat(ks, rep, axis=2)
        vq = jnp.repeat(vq, rep, axis=2)
        vs = jnp.repeat(vs, rep, axis=2)
    scale = 1.0 / math.sqrt(dh)
    # int8 contraction; scales fold into the score
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kq.astype(jnp.float32)) * scale
    scores = scores * ks[..., 0].transpose(0, 2, 1)[:, :, None, :]
    Smax = kq.shape[1]
    valid = jnp.arange(Smax)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    # (p·s_v)·v_q: fold value scales into probabilities
    pv = probs * vs[..., 0].transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum("bhqk,bkhd->bqhd", pv.astype(jnp.float32),
                     vq.astype(jnp.float32))
    return out.astype(dtype)
