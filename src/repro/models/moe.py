"""Mixture-of-Experts: top-k router + capacity-bounded scatter dispatch.

TPU adaptation (DESIGN §2): instead of GShard's dense one-hot dispatch
einsums (whose dispatch GEMM FLOPs would dwarf the expert compute at
E=64/top-8 and poison the roofline), tokens are scatter-packed into a
per-expert [E, C, d] buffer and run through batched expert GEMMs — the
static-shape TPU analogue of MegaBlocks grouped-GEMM.

Expert parallelism is explicit: when a mesh with a "model" axis is active
(repro.sharding.current_mesh), the layer runs under shard_map with experts
sharded over "model"; each shard routes all (replicated-over-model) tokens,
packs only its local experts, and the partial outputs are psum'd over
"model" — the standard EP all-reduce. Without a mesh (CPU smoke tests) the
same local kernel runs with all experts.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import MoEConfig
from repro.models.layers import _dense_init

CAPACITY_FACTOR = 1.25


def init_moe(key, d_model: int, cfg: MoEConfig):
    ks = jax.random.split(key, 4)
    E, F = cfg.n_experts, cfg.d_ff_expert
    return {
        "router": _dense_init(ks[0], (d_model, E), d_model),
        "w_gate": _dense_init(ks[1], (E, d_model, F), d_model),
        "w_up": _dense_init(ks[2], (E, d_model, F), d_model),
        "w_down": _dense_init(ks[3], (E, F, d_model), F),
    }


def moe_axes():
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(math.ceil(tokens / cfg.n_experts * cfg.top_k * CAPACITY_FACTOR))
    c = max(cfg.top_k, ((c + 3) // 4) * 4)
    return min(c, tokens * cfg.top_k)


def _moe_local(params, xf: jax.Array, cfg: MoEConfig, n_local: int,
               shard_idx) -> Tuple[jax.Array, jax.Array]:
    """Route all tokens, compute only experts [e0, e0+n_local).

    xf: [T, d]. Returns (partial y [T, d], aux loss scalar).
    """
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    dtype = xf.dtype
    e0 = shard_idx * n_local
    C = _capacity(T, cfg)

    logits = jnp.einsum("td,de->te", xf, params["router"].astype(dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # [T, E]
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch): E · Σ_e f_e · p̄_e  — over local experts,
    # psum outside restores the global sum.
    local_ids = e0 + jnp.arange(n_local)
    me = jnp.mean(probs, axis=0)[local_ids]                       # [n_local]

    # Sequential-choice positions within each expert (GShard order).
    buf = jnp.zeros((n_local, C, d), dtype)
    base = jnp.zeros((E,), jnp.int32)
    ce = jnp.zeros((n_local,), jnp.float32)
    gathers = []
    for j in range(k):
        e_j = top_e[:, j]                                         # [T]
        onehot = (e_j[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
        pos_full = base[None, :] + jnp.cumsum(onehot, axis=0) - 1  # [T, E]
        base = base + jnp.sum(onehot, axis=0)
        pos_j = jnp.take_along_axis(pos_full, e_j[:, None], axis=1)[:, 0]
        is_local = (e_j >= e0) & (e_j < e0 + n_local)
        keep = is_local & (pos_j < C)
        ce = ce + (jnp.sum(onehot, axis=0).astype(jnp.float32) / (T * k))[local_ids]
        el = jnp.where(keep, e_j - e0, n_local)                   # OOB row drops
        pc = jnp.where(keep, pos_j, 0)
        src = jnp.where(keep[:, None], xf, 0)
        buf = buf.at[el, pc].add(src, mode="drop")
        gathers.append((el, pc, top_p[:, j], keep))

    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(dtype))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                    params["w_down"].astype(dtype))               # [nl, C, d]

    y = jnp.zeros((T, d), dtype)
    for el, pc, w, keep in gathers:
        contrib = ye[jnp.where(keep, el, 0), pc]                  # [T, d]
        y = y + jnp.where(keep[:, None], contrib * w[:, None].astype(dtype), 0)

    aux = E * jnp.sum(me * ce) * cfg.aux_loss_weight
    return y, aux


def moe_fwd(params, x: jax.Array, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] → (y, aux_loss). Expert-parallel over the mesh "model"
    axis when one is active; tokens stay sharded over data axes."""
    from repro import sharding as shd

    B, S, d = x.shape
    mesh = shd.current_mesh()
    E = cfg.n_experts

    if mesh is None or "model" not in mesh.axis_names or E % mesh.shape["model"]:
        y, aux = _moe_local(params, x.reshape(B * S, d), cfg, E, 0)
        return y.reshape(B, S, d), aux

    m = mesh.shape["model"]
    n_local = E // m
    batch_axes = shd.batch_axes_for(mesh, B)

    def shard_fn(p, xs):
        idx = jax.lax.axis_index("model")
        Bl, Sl, dl = xs.shape
        y, aux = _moe_local(p, xs.reshape(Bl * Sl, dl), cfg, n_local, idx)
        y = jax.lax.psum(y, "model")
        aux = jax.lax.psum(aux, "model")
        return y.reshape(Bl, Sl, dl), aux

    pspecs = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    y, aux = jax.shard_map(
        shard_fn, mesh=mesh,
        in_specs=({k: pspecs[k] for k in params}, P(batch_axes, None, None)),
        out_specs=(P(batch_axes, None, None), P()),
        check_vma=False,
    )(params, x)
    return y, aux
