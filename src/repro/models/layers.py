"""Core model layers: norms, positions, attention, MLP.

Pure-JAX pytree style: ``init_*`` returns a params dict (+ a parallel
"logical axes" dict used by repro.sharding), ``*_fwd`` applies it.

Attention is implemented *blockwise over query chunks* (lax.scan) so the
materialized score buffer is O(q_chunk × kv_len) rather than O(seq²) — this
is the pure-JAX oracle of the Pallas flash kernel and keeps the dry-run
memory analysis honest for 32k prefill without kernel support on CPU.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Params = dict
DEFAULT_Q_CHUNK = 512


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def _dense_init(key, shape, in_axis_size, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(max(1, in_axis_size))
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_axes() -> Params:
    return {"scale": ("embed",)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)


def rmsnorm_nc(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with an explicit scale vector (e.g. per-head qk-norm)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim//2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (broadcastable)."""
    freqs = rope_freqs(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., seq, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(seq, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angles = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(angles))
    pe = pe.at[:, 1::2].set(jnp.cos(angles[:, : (d - d // 2)]))
    return pe


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def init_attention(key, d_model: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d_model, n_heads, head_dim), d_model),
        "wk": _dense_init(ks[1], (d_model, n_kv, head_dim), d_model),
        "wv": _dense_init(ks[2], (d_model, n_kv, head_dim), d_model),
        "wo": _dense_init(ks[3], (n_heads, head_dim, d_model), n_heads * head_dim),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


def attention_axes(qk_norm: bool) -> Params:
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if qk_norm:
        p["q_norm"] = ("head_dim",)
        p["k_norm"] = ("head_dim",)
    return p


def _qkv(params: Params, x: jax.Array, positions, theta: float,
         qk_norm: bool, use_rope: bool, dtype) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dtype))
    if qk_norm:
        q = rmsnorm_nc(q, params["q_norm"])
        k = rmsnorm_nc(k, params["k_norm"])
    if use_rope:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool, q_chunk: int = DEFAULT_Q_CHUNK,
                      q_offset: int = 0, unroll: bool = False) -> jax.Array:
    """Blockwise attention over query chunks.

    q: [B, Sq, H, dh]; k/v: [B, Skv, KV, dh]; GQA via head-group reshape.
    Scores materialized per chunk: [B, H, q_chunk, Skv]. `unroll` replaces
    the lax.scan with a python loop (exact dry-run cost accounting).
    """
    B, Sq, H, dh = q.shape
    _, Skv, KV, _ = k.shape
    rep = H // KV
    scale = 1.0 / math.sqrt(dh)
    if Sq % q_chunk or Sq == q_chunk:
        q_chunk = Sq
    n_chunks = Sq // q_chunk

    # GQA via kv-head repeat (NOT a (KV, rep) reshape of q's head axis: that
    # reshape re-tiles the TP-sharded head dim and forces SPMD all-gathers;
    # repeating the — typically replicated — kv heads is comm-free and XLA
    # folds the broadcast into the dot).
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    qg = jnp.moveaxis(q.reshape(B, n_chunks, q_chunk, H, dh), 1, 0)
    kv_pos = jnp.arange(Skv)

    def chunk_fn(qc, i):
        scores = jnp.einsum("bqhd,bkhd->bhqk", qc, k) * scale
        scores = scores.astype(jnp.float32)
        if causal:
            q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            mask = kv_pos[None, :] <= q_pos[:, None]
            scores = jnp.where(mask[None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    if unroll:
        outs = jnp.stack([chunk_fn(qg[i], jnp.int32(i))
                          for i in range(n_chunks)])
    else:
        _, outs = jax.lax.scan(
            lambda c, xi: (c, chunk_fn(*xi)), None,
            (qg, jnp.arange(n_chunks)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, dh)
    return out


def attention_fwd(params: Params, x: jax.Array, *, n_kv: int, theta: float,
                  qk_norm: bool, causal: bool = True, use_rope: bool = True,
                  positions: Optional[jax.Array] = None,
                  kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                  q_chunk: int = DEFAULT_Q_CHUNK,
                  unroll: bool = False) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder / cross)."""
    B, S, _ = x.shape
    dtype = x.dtype
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, positions, theta, qk_norm, use_rope, dtype)
    if kv_override is not None:
        k, v = kv_override
    out = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk,
                            unroll=unroll)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


def attention_prefill(params: Params, x: jax.Array, *, n_kv: int, theta: float,
                      qk_norm: bool, use_rope: bool, cache_len: int,
                      q_chunk: int = DEFAULT_Q_CHUNK, unroll: bool = False):
    """Like attention_fwd (causal) but also returns k/v padded to cache_len."""
    B, S, _ = x.shape
    dtype = x.dtype
    positions = jnp.arange(S)[None, :]
    q, k, v = _qkv(params, x, positions, theta, qk_norm, use_rope, dtype)
    out = chunked_attention(q, k, v, causal=True, q_chunk=q_chunk,
                            unroll=unroll)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    pad = cache_len - S
    k_c = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v_c = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return out, (k_c, v_c)


def attention_decode(params: Params, x: jax.Array, cache_kv, pos, *,
                     theta: float, qk_norm: bool, use_rope: bool = True):
    """Single-token decode. x: [B, 1, D]; cache k/v: [B, Smax, KV, dh];
    pos: scalar int32 — current write index (tokens 0..pos-1 are valid)."""
    B, _, D = x.shape
    dtype = x.dtype
    k_cache, v_cache = cache_kv
    Smax = k_cache.shape[1]
    positions = jnp.full((1, 1), pos, dtype=jnp.int32)
    q, k, v = _qkv(params, x, positions, theta, qk_norm, use_rope, dtype)
    # one-hot masked write instead of dynamic_update_slice: a dynamic-index
    # write into a sequence-sharded cache forces SPMD to all-gather the whole
    # cache; the select shards cleanly over the seq dim (MaxText-style).
    write = (jnp.arange(Smax) == pos)[None, :, None, None]
    k_cache = jnp.where(write, k.astype(k_cache.dtype), k_cache)
    v_cache = jnp.where(write, v.astype(v_cache.dtype), v_cache)
    H, KV = q.shape[2], k_cache.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(q.shape[-1])
    kr, vr = k_cache, v_cache
    if rep > 1:  # GQA via repeat (see chunked_attention)
        kr = jnp.repeat(kr, rep, axis=2)
        vr = jnp.repeat(vr, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr) * scale
    scores = scores.astype(jnp.float32)
    valid = jnp.arange(Smax)[None, None, None, :] <= pos
    scores = jnp.where(valid, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)
    out = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))
    return out, (k_cache, v_cache)


def attention_readonly(params: Params, x: jax.Array, cache_kv, *,
                       qk_norm: bool):
    """Cross-attention during decode: attend over a fixed cache, no write,
    no positional encoding on q (whisper-style cross-attn)."""
    B, _, D = x.shape
    dtype = x.dtype
    k_cache, v_cache = cache_kv
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dtype))
    if qk_norm:
        q = rmsnorm_nc(q, params["q_norm"])
    H, KV = q.shape[2], k_cache.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(q.shape[-1])
    if rep > 1:  # GQA via repeat (see chunked_attention)
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache) * scale
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_cache)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d_model, d_ff), d_model),
        "w_up": _dense_init(ks[1], (d_model, d_ff), d_model),
        "w_down": _dense_init(ks[2], (d_ff, d_model), d_ff),
    }


def mlp_axes() -> Params:
    return {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
            "w_down": ("mlp", "embed")}


def mlp_fwd(params: Params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dtype))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def init_embedding(key, vocab: int, d_model: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02


def embed_tokens(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return table.astype(dtype)[tokens]


def logits_fwd(table_or_unembed: jax.Array, x: jax.Array, tied: bool,
               real_vocab: int) -> jax.Array:
    """Project to (padded) vocab; padded rows masked to -inf (fp32 logits)."""
    w = table_or_unembed.astype(x.dtype)
    if tied:
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, w)
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if V > real_vocab:
        mask = jnp.arange(V) < real_vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits
