"""Mamba-2 SSD (state-space duality) mixer, TPU-adapted.

The selective scan is recast as *chunked matmuls* (the SSD formulation,
arXiv:2405.21060) so the inner loops are MXU-shaped batched GEMMs:
  - within-chunk: (C·Bᵀ ⊙ decay-mask) · X   — dense [Q,Q] per chunk
  - across-chunk: state recurrence over chunk summaries (lax.scan)
This pure-jnp implementation is the oracle for kernels/ssd_scan and the
XLA path used by mamba2-1.3b and jamba's Mamba layers.

Projections are kept separate (z, x, B, C, dt) rather than one fused
in_proj so each output axis has a clean TP sharding (d_inner → "model";
B/C/dt are small and replicated) — fusing them would put the TP shard
boundary mid-concat and force GSPMD resharding at every split.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs import SSMConfig
from repro.models.layers import _dense_init

Params = dict


def init_ssm(key, d_model: int, cfg: SSMConfig) -> Params:
    din = cfg.d_inner(d_model)
    H = cfg.n_heads(d_model)
    G, N = cfg.n_groups, cfg.d_state
    ks = jax.random.split(key, 8)
    return {
        "w_z": _dense_init(ks[0], (d_model, din), d_model),
        "w_x": _dense_init(ks[1], (d_model, din), d_model),
        "w_B": _dense_init(ks[2], (d_model, G * N), d_model),
        "w_C": _dense_init(ks[3], (d_model, G * N), d_model),
        "w_dt": _dense_init(ks[4], (d_model, H), d_model),
        "conv_x": _dense_init(ks[5], (cfg.d_conv, din), cfg.d_conv),
        "conv_BC": _dense_init(ks[6], (cfg.d_conv, 2 * G * N), cfg.d_conv),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.ones((din,), jnp.float32),
        "out_proj": _dense_init(ks[7], (din, d_model), din),
    }


def ssm_axes() -> Params:
    return {
        "w_z": ("embed", "ssm_inner"),
        "w_x": ("embed", "ssm_inner"),
        "w_B": ("embed", None),
        "w_C": ("embed", None),
        "w_dt": ("embed", None),
        "conv_x": (None, "ssm_inner"),
        "conv_BC": (None, None),
        "A_log": (None,),
        "dt_bias": (None,),
        "D": (None,),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def _causal_conv(u: jax.Array, conv_w: jax.Array) -> jax.Array:
    """Depthwise causal conv via tap shifts. u: [B, L, C]; conv_w: [K, C]."""
    K = conv_w.shape[0]
    out = u * conv_w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(u, ((0, 0), (i, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * conv_w[K - 1 - i]
    return jax.nn.silu(out)


def _segsum(a: jax.Array) -> jax.Array:
    """segsum(a)[..., i, j] = sum_{j < k <= i} a_k  (−inf above diagonal)."""
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
                C: jax.Array, chunk: int, h0=None, unroll: bool = False
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: [B, L, H, P]; dt: [B, L, H] (post-softplus, fp32); A: [H] (negative);
    B_, C: [B, L, G, N]. Returns (y [B, L, H, P], h_final [B, H, P, N]).
    """
    Bb, L, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    Q = min(chunk, L)
    if L % Q:
        Q = L
    Nc = L // Q

    f32 = jnp.float32
    xc = x.reshape(Bb, Nc, Q, H, P)
    dtc = dt.reshape(Bb, Nc, Q, H).astype(f32)
    Bc = B_.reshape(Bb, Nc, Q, G, N)
    Cc = C.reshape(Bb, Nc, Q, G, N)

    a = dtc * A                                          # [B, Nc, Q, H]
    a_hq = jnp.moveaxis(a, -1, -2)                       # [B, Nc, H, Q]
    seg = _segsum(a_hq)                                  # [B, Nc, H, Q, Q]
    cum = jnp.cumsum(a_hq, axis=-1)                      # [B, Nc, H, Q]

    # --- diagonal (within-chunk) term ---------------------------------------
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc.astype(f32), Bc.astype(f32))
    CB = jnp.repeat(CB, rep, axis=2)                     # [B, Nc, H, Q, Q]
    M = CB * jnp.exp(seg) * jnp.moveaxis(dtc, -1, -2)[..., None, :]
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", M.astype(x.dtype), xc)

    # --- chunk state summaries ----------------------------------------------
    decay_out = jnp.exp(cum[..., -1:] - cum)             # [B, Nc, H, Q]
    wB = (jnp.repeat(Bc.astype(f32), rep, axis=3).reshape(Bb, Nc, Q, H, N)
          * (dtc * jnp.moveaxis(decay_out, -1, -2))[..., None])
    S = jnp.einsum("bcqhn,bcqhp->bchpn", wB.astype(x.dtype), xc)  # [B,Nc,H,P,N]

    # --- cross-chunk recurrence ----------------------------------------------
    chunk_decay = jnp.exp(cum[..., -1])                  # [B, Nc, H]
    if h0 is None:
        h0 = jnp.zeros((Bb, H, P, N), x.dtype)

    def step(h, inp):
        S_c, dec_c = inp
        h_enter = h
        h_new = h * dec_c[..., None, None].astype(x.dtype) + S_c
        return h_new, h_enter

    S_seq = jnp.moveaxis(S, 1, 0)
    dec_seq = jnp.moveaxis(chunk_decay, 1, 0)
    if unroll:
        h = h0
        entries = []
        for c in range(Nc):
            h, h_in = step(h, (S_seq[c], dec_seq[c]))
            entries.append(h_in)
        h_final, h_enter = h, jnp.stack(entries)
    else:
        h_final, h_enter = jax.lax.scan(step, h0, (S_seq, dec_seq))
    h_enter = jnp.moveaxis(h_enter, 0, 1)                # [B, Nc, H, P, N]

    # --- off-diagonal (carry-in) term ----------------------------------------
    Cin = (jnp.repeat(Cc.astype(f32), rep, axis=3).reshape(Bb, Nc, Q, H, N)
           * jnp.exp(jnp.moveaxis(cum, -1, -2))[..., None])
    y_off = jnp.einsum("bcqhn,bchpn->bcqhp", Cin.astype(x.dtype), h_enter)

    y = (y_diag + y_off).reshape(Bb, L, H, P)
    return y, h_final


def _gated_out(params, y: jax.Array, z: jax.Array, dtype) -> jax.Array:
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)
         * params["norm"]).astype(dtype)
    return jnp.einsum("bld,dp->blp", y, params["out_proj"].astype(dtype))


def ssm_fwd(params: Params, x: jax.Array, d_model: int, cfg: SSMConfig,
            return_state: bool = False, unroll: bool = False):
    """Full-sequence Mamba-2 block. x: [B, L, d_model]."""
    dtype = x.dtype
    Bb, L, _ = x.shape
    H, P = cfg.n_heads(d_model), cfg.head_dim
    G, N = cfg.n_groups, cfg.d_state
    din = cfg.d_inner(d_model)

    z = jnp.einsum("bld,dp->blp", x, params["w_z"].astype(dtype))
    xr = jnp.einsum("bld,dp->blp", x, params["w_x"].astype(dtype))
    BCr = jnp.concatenate(
        [jnp.einsum("bld,dp->blp", x, params["w_B"].astype(dtype)),
         jnp.einsum("bld,dp->blp", x, params["w_C"].astype(dtype))], axis=-1)
    dt_raw = jnp.einsum("bld,dp->blp", x, params["w_dt"].astype(dtype))

    xconv = _causal_conv(xr, params["conv_x"].astype(dtype))
    BC = _causal_conv(BCr, params["conv_BC"].astype(dtype))
    xs = xconv.reshape(Bb, L, H, P)
    B_ = BC[..., : G * N].reshape(Bb, L, G, N)
    C = BC[..., G * N:].reshape(Bb, L, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    y, h_final = ssd_chunked(xs, dt, A, B_, C, cfg.chunk_size, unroll=unroll)
    y = y + xs * params["D"].astype(dtype)[None, None, :, None]
    out = _gated_out(params, y.reshape(Bb, L, din), z, dtype)

    if return_state:
        tail = cfg.d_conv - 1
        conv_state = jnp.concatenate([xr[:, -tail:], BCr[:, -tail:]], axis=-1)
        return out, {"conv": conv_state, "h": h_final}
    return out


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> Params:
    H, P = cfg.n_heads(d_model), cfg.head_dim
    G, N = cfg.n_groups, cfg.d_state
    din = cfg.d_inner(d_model)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, din + 2 * G * N), dtype),
        "h": jnp.zeros((batch, H, P, N), dtype),
    }


def ssm_decode(params: Params, x: jax.Array, cache: Params, d_model: int,
               cfg: SSMConfig):
    """Single-token state update. x: [B, 1, d_model]."""
    dtype = x.dtype
    Bb = x.shape[0]
    H, P = cfg.n_heads(d_model), cfg.head_dim
    G, N = cfg.n_groups, cfg.d_state
    din = cfg.d_inner(d_model)

    z = jnp.einsum("bld,dp->blp", x, params["w_z"].astype(dtype))
    xr = jnp.einsum("bld,dp->blp", x, params["w_x"].astype(dtype))
    BCr = jnp.concatenate(
        [jnp.einsum("bld,dp->blp", x, params["w_B"].astype(dtype)),
         jnp.einsum("bld,dp->blp", x, params["w_C"].astype(dtype))], axis=-1)
    dt_raw = jnp.einsum("bld,dp->blp", x, params["w_dt"].astype(dtype))

    # conv over [cached K-1 inputs, current]
    new_row = jnp.concatenate([xr, BCr], axis=-1)          # [B, 1, din+2GN]
    window = jnp.concatenate([cache["conv"], new_row], axis=1)  # [B, K, C]
    conv_w = jnp.concatenate(
        [params["conv_x"], params["conv_BC"]], axis=-1).astype(dtype)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, conv_w))
    new_conv = window[:, 1:]

    xs = conv_out[..., :din].reshape(Bb, H, P)
    B_ = conv_out[..., din: din + G * N].reshape(Bb, G, N)
    C = conv_out[..., din + G * N:].reshape(Bb, G, N)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    rep = H // G

    decay = jnp.exp(dt * A)                                # [B, H]
    Bh = jnp.repeat(B_, rep, axis=1)                       # [B, H, N]
    dBx = (dt[..., None, None] * Bh[:, :, None, :].astype(jnp.float32)
           * xs[..., None].astype(jnp.float32))            # [B, H, P, N]
    h = cache["h"].astype(jnp.float32) * decay[..., None, None] + dBx
    Ch = jnp.repeat(C, rep, axis=1)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(jnp.float32)).astype(dtype)
    y = y + xs * params["D"].astype(dtype)[None, :, None]
    out = _gated_out(params, y.reshape(Bb, 1, din), z, dtype)
    return out, {"conv": new_conv, "h": h.astype(dtype)}
