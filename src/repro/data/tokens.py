"""Deterministic synthetic LM data: a mixture of Markov chains over the
vocabulary so the loss has learnable structure (tests assert it drops).
Fully seeded — restart from a checkpoint reproduces the exact stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs import ArchConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    n_states: int = 8
    order_bias: float = 0.85   # prob of following the chain vs uniform
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # each "state" is a cyclic walk over a random permutation slice
        self.next_tok = rng.integers(0, self.vocab_size,
                                     (self.n_states, self.vocab_size),
                                     dtype=np.int64)

    def batch(self, step: int, batch_size: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        state = rng.integers(0, self.n_states, (batch_size,))
        toks = np.empty((batch_size, self.seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, (batch_size,))
        follow = rng.random((batch_size, self.seq_len)) < self.order_bias
        rand = rng.integers(0, self.vocab_size, (batch_size, self.seq_len))
        for t in range(self.seq_len):
            chain = self.next_tok[state, toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], chain, rand[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_batch(cfg: ArchConfig, seq_len: int, batch_size: int, step: int,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """Full model batch incl. stub-frontend inputs."""
    data = SyntheticLM(cfg.vocab_size, seq_len, seed=seed)
    batch = data.batch(step, batch_size)
    rng = np.random.default_rng((seed, step, 1))
    if cfg.frontend == "patch_stub":
        batch["patches"] = rng.standard_normal(
            (batch_size, cfg.n_prefix_tokens, cfg.d_model),
            dtype=np.float32) * 0.1
        batch["labels"][:, :cfg.n_prefix_tokens] = -100  # mask prefix
    if cfg.enc_dec is not None:
        batch["frames"] = rng.standard_normal(
            (batch_size, cfg.enc_dec.enc_seq, cfg.d_model),
            dtype=np.float32) * 0.1
    return batch
