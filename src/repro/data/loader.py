"""Sharded host loader: each host materializes only its slice of the
global batch and the arrays are assembled into a globally-sharded
jax.Array (make_array_from_callback) — no host ever holds the full batch.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig
from repro.data.tokens import make_batch


class ShardedLoader:
    def __init__(self, cfg: ArchConfig, seq_len: int, global_batch: int,
                 mesh: Optional[Mesh] = None, seed: int = 0):
        self.cfg = cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.mesh = mesh
        self.seed = seed

    def __call__(self, step: int) -> Dict[str, jax.Array]:
        host = make_batch(self.cfg, self.seq_len, self.global_batch, step,
                          self.seed)
        if self.mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in host.items()}
        from repro import sharding as shd
        b_ax = shd.batch_axes_for(self.mesh, self.global_batch)
        out = {}
        for k, v in host.items():
            spec = P(b_ax, *([None] * (v.ndim - 1)))
            sharding = NamedSharding(self.mesh, spec)
            out[k] = jax.make_array_from_callback(
                v.shape, sharding, lambda idx, v=v: v[idx])
        return out
