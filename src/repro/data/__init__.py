from repro.data.tokens import SyntheticLM, make_batch
from repro.data.loader import ShardedLoader
