"""HLO-text collective analysis.

``cost_analysis()`` has no collective-traffic entry, so the roofline's
collective term is derived by parsing the compiled (post-SPMD, per-device)
HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op contributes ring-model bytes-on-the-wire.

Shapes in the compiled module are already per-partition, so the sums are
per-device traffic — exactly what the per-chip link bandwidth divides.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "f8e4m3fnuz": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "bf16[16,4096,128]{2,1,0}" or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# matches "%name = <shape or tuple> kind(" — kind may have -start suffix
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over all array shapes appearing in a (possibly tuple) type."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Ring-model per-device bytes moved for each collective op.

    all-reduce: 2·size·(g−1)/g (reduce-scatter + all-gather phases);
    all-gather: out·(g−1)/g; reduce-scatter: in·(g−1)/g;
    all-to-all: size·(g−1)/g; collective-permute: size.
    """
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    nbytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVE_KINDS}
    for line in hlo_text.splitlines():
        if "-done(" in line:  # async pair: count the -start only
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(shape_str)
        g = _group_size(line)
        frac = (g - 1) / g if g > 0 else 1.0
        if kind == "all-reduce":
            moved = 2 * size * frac
        elif kind == "collective-permute":
            moved = size
        else:
            moved = size * frac
        counts[kind] += 1
        nbytes[kind] += moved
    return CollectiveStats(counts=counts, bytes_by_kind=nbytes)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
