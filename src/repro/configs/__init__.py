"""Architecture & shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig` registered under
its public id (``--arch <id>``). Shapes are the four assigned input regimes.
``reduced()`` yields a family-preserving tiny config for CPU smoke tests;
the FULL configs are exercised only via the dry-run (ShapeDtypeStruct, no
allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Dict, List, Optional, Tuple

VOCAB_PAD_MULTIPLE = 256


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    every_n_layers: int = 1      # MoE replaces the MLP on layers where
                                 # (layer_idx % every_n_layers) == moe_offset
    moe_offset: int = 0
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) mixer configuration."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: one attention layer per ``attn_period``."""
    attn_period: int = 8
    attn_offset: int = 4         # Jamba: attention at index 4 of each period


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 24
    enc_seq: int = 1500          # whisper: 1500 frame embeddings (stub)


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | audio | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    enc_dec: Optional[EncDecConfig] = None
    frontend: Optional[str] = None       # None | audio_stub | patch_stub
    n_prefix_tokens: int = 0             # stub frontend prefix length
    positional: str = "rope"             # rope | sinusoidal
    grad_accum: int = 4                  # microbatches per train step (sized
                                         # so remat residuals fit 16GiB HBM)
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> List[str]:
        """Per-layer mixer/mlp kind string, e.g. 'attn+mlp', 'ssm+moe'."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "ssm"
            elif self.hybrid is not None:
                h = self.hybrid
                mixer = "attn" if (i % h.attn_period) == h.attn_offset else "ssm"
            else:
                mixer = "attn"
            if self.moe is not None and (i % self.moe.every_n_layers) == self.moe.moe_offset:
                ff = "moe"
            elif self.d_ff > 0:
                ff = "mlp"
            else:
                ff = "none"  # e.g. mamba2: the SSD mixer is the whole block
            kinds.append(f"{mixer}+{ff}")
        return kinds

    def scan_groups(self) -> Tuple[List[str], int]:
        """Return (pattern, n_repeat): the layer stack is `pattern * n_repeat`.

        Models scan over n_repeat with the pattern unrolled inside, keeping
        HLO size O(len(pattern)) rather than O(n_layers).
        """
        kinds = self.layer_kinds()
        for plen in range(1, len(kinds) + 1):
            if len(kinds) % plen:
                continue
            pat = kinds[:plen]
            if pat * (len(kinds) // plen) == kinds:
                return pat, len(kinds) // plen
        return kinds, 1  # pragma: no cover

    # ---- parameter counting (for MODEL_FLOPS = 6·N·D) ----------------------
    def param_counts(self) -> Dict[str, float]:
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        H, KV, dh = self.n_heads, self.n_kv_heads, self.head_dim
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D  # wq wk wv wo
        if self.qk_norm:
            attn += 2 * dh
        mlp = 3 * D * F  # SwiGLU gate/up/down
        ssm_p = 0.0
        if self.ssm is not None:
            s = self.ssm
            din, G, S, Hs = s.d_inner(D), s.n_groups, s.d_state, s.n_heads(D)
            in_proj = D * (2 * din + 2 * G * S + Hs)
            conv = s.d_conv * (din + 2 * G * S)
            ssm_p = in_proj + conv + 3 * Hs + din + din * D  # +A,D,dt_bias,norm,out
        moe_p = 0.0
        if self.moe is not None:
            m = self.moe
            moe_p = D * m.n_experts + m.n_experts * 3 * D * m.d_ff_expert
        total = 0.0
        active = 0.0
        for kind in self.layer_kinds():
            mixer, ff = kind.split("+")
            mx = attn if mixer == "attn" else ssm_p
            if ff == "moe":
                m = self.moe
                ffp = moe_p
                ffa = D * m.n_experts + m.top_k * 3 * D * m.d_ff_expert
            elif ff == "mlp":
                ffp = ffa = mlp
            else:
                ffp = ffa = 0.0
            total += mx + ffp + 2 * D
            active += mx + ffa + 2 * D
        emb = V * D
        unemb = 0 if self.tie_embeddings else V * D
        total += emb + unemb + D
        active += emb + unemb + D
        if self.enc_dec is not None:
            e = self.enc_dec
            enc_layer = attn + mlp + 2 * D
            cross = attn
            total += e.n_enc_layers * enc_layer + self.n_layers * (cross + D)
            active += e.n_enc_layers * enc_layer + self.n_layers * (cross + D)
        return {"total": total, "active": active}

    # ---- smoke-test reduction ----------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for 1-device CPU smoke tests."""
        pat, _ = self.scan_groups()
        n_layers = len(pat) * min(2, max(1, self.n_layers // len(pat)))
        kv = max(1, min(self.n_kv_heads, 2))
        nh = max(kv, min(self.n_heads, 4))
        nh = (nh // kv) * kv or kv
        repl = {
            "n_layers": n_layers,
            "d_model": 64,
            "n_heads": nh,
            "n_kv_heads": kv,
            "d_head": 16,
            "d_ff": 128 if self.d_ff > 0 else 0,  # keep attention-free blocks
            "vocab_size": 512,
        }
        if self.moe is not None:
            repl["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64)
        if self.ssm is not None:
            repl["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk_size=32)
        if self.enc_dec is not None:
            repl["enc_dec"] = dataclasses.replace(self.enc_dec, n_enc_layers=2, enc_seq=16)
        if self.n_prefix_tokens:
            repl["n_prefix_tokens"] = 4
        return dataclasses.replace(self, **repl)


# ---------------------------------------------------------------------------
# Shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def supports_shape(arch: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic attention (SSM/hybrid only)."""
    if shape.name == "long_500k" and arch.family not in ("ssm", "hybrid"):
        return False, "full-attention arch: 512k dense-KV decode is quadratic — skipped (DESIGN §4)"
    return True, ""


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_ARCH_MODULES = [
    "smollm_135m", "qwen3_1p7b", "yi_6b", "qwen3_14b", "olmoe_1b_7b",
    "granite_moe_1b_a400m", "jamba_v0_1_52b", "whisper_medium",
    "internvl2_76b", "mamba2_1p3b",
]

_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    _ensure_loaded()
    key = name.replace("_", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    for mod in _ARCH_MODULES:
        importlib.import_module(f"{__name__}.{mod}")
