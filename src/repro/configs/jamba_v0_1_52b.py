"""Jamba-v0.1-52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.
[arXiv:2403.19887; hf]

The Mamba mixer is realized with the SSD (Mamba-2) formulation — the TPU
adaptation recasts the selective scan as chunked matmuls mapping onto the
MXU (DESIGN §2). Attention at index 4 of every 8-layer period; MoE replaces
the MLP on every other layer (offset 1).
"""
from repro.configs import ArchConfig, HybridConfig, MoEConfig, SSMConfig, register

JAMBA_V0_1 = register(ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336,
                  every_n_layers=2, moe_offset=1),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    hybrid=HybridConfig(attn_period=8, attn_offset=4),
    source="arXiv:2403.19887",
))
