"""Mamba2-1.3B — attention-free SSD (state-space duality).
[arXiv:2405.21060; unverified]

48 layers, d_model=2048, d_inner=4096, head_dim=64 (64 SSD heads),
d_state=128, attention-free (d_ff=0: the SSD mixer is the whole block,
matching the published Mamba-2 block which has no separate MLP).
"""
from repro.configs import ArchConfig, SSMConfig, register

MAMBA2_1P3B = register(ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,        # unused (attention-free)
    n_kv_heads=1,
    d_head=64,
    d_ff=0,           # no MLP: pure SSD blocks
    vocab_size=50280,  # padded to 50432 for TP sharding
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk_size=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
))
