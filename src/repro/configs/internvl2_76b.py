"""InternVL2-76B — VLM; InternViT frontend STUB + 76B LM backbone.
[arXiv:2404.16821; unverified]

The assigned cell is the LM backbone (80L / d=8192 / 64H GQA kv=8 /
d_ff=28672 / vocab=128256, llama-3-70B-class). The vision tower is stubbed:
``input_specs()`` provides 256 pre-projected patch embeddings as a prefix.
"""
from repro.configs import ArchConfig, register

INTERNVL2_76B = register(ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    frontend="patch_stub",
    n_prefix_tokens=256,
    grad_accum=16,  # 80 layers × d=8192: remat residuals need small microbatches
    source="arXiv:2404.16821",
))
