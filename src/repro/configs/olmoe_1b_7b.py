"""OLMoE-1B-7B — MoE 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.configs import ArchConfig, MoEConfig, register

OLMOE_1B_7B = register(ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    source="arXiv:2409.02060",
))
