"""Granite-3.0-1B-A400M — MoE 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs import ArchConfig, MoEConfig, register

GRANITE_MOE = register(ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab_size=49155,  # padded to 49408 for TP sharding (DESIGN §4)
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
