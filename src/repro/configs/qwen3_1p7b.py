"""Qwen3-1.7B — dense, GQA kv=8, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs import ArchConfig, register

QWEN3_1P7B = register(ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B",
))
