"""Whisper-medium — enc-dec audio backbone; conv frontend STUB.
[arXiv:2212.04356; unverified]

24 encoder + 24 decoder layers (d=1024, 16H MHA, d_ff=4096). The conv
frontend is stubbed: ``input_specs()`` supplies precomputed 1500-frame
embeddings. Sinusoidal positions (whisper uses no RoPE). Decode shapes
exercise the decoder self-attn KV + cross-attn cache; 32k decode KV is
architecturally inflated vs. real Whisper (448 ctx) but lowered as assigned.
"""
from repro.configs import ArchConfig, EncDecConfig, register

WHISPER_MEDIUM = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,  # padded to 51968 for TP sharding
    enc_dec=EncDecConfig(n_enc_layers=24, enc_seq=1500),
    frontend="audio_stub",
    positional="sinusoidal",
    source="arXiv:2212.04356",
))
