"""Fault tolerance: failure injection + restart-from-checkpoint policy.

`run_with_restarts` drives a training loop through injected failures the
way a real cluster controller would: on failure, state is discarded, the
newest complete checkpoint is restored (possibly onto a DIFFERENT mesh —
elastic restart after losing a slice), and the loop resumes. The data
stream is step-keyed, so replayed steps see identical batches.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, List, Optional, Tuple


@dataclasses.dataclass
class FailureInjector:
    """Bernoulli per-step failure (node crash / preemption)."""
    p_fail: float = 0.0
    seed: int = 0
    fail_steps: Optional[List[int]] = None   # deterministic alternative

    def __post_init__(self):
        self._fired = set()

    def should_fail(self, step: int) -> bool:
        if self.fail_steps is not None:
            # each listed step fails once (a replayed step after restart
            # succeeds — the node was replaced)
            if step in self.fail_steps and step not in self._fired:
                self._fired.add(step)
                return True
            return False
        if self.p_fail <= 0.0:
            return False
        # Step-keyed draw: replaying a step after a restart probes the
        # SAME coin the uninterrupted run would, so chaos schedules are
        # deterministic under replay. Fire-once per step (like
        # fail_steps) — the replacement node survives the replay.
        if step in self._fired:
            return False
        if random.Random(self._key(step)).random() < self.p_fail:
            self._fired.add(step)
            return True
        return False

    def _key(self, step: int) -> int:
        # int key (tuple seeding is hash-based and deprecated)
        return (self.seed << 32) ^ step

    def fail_times(self, n_steps: int):
        """The deterministic set of steps that would fire over `n_steps`
        probes, independent of any consumed state (step-keyed draws)."""
        if self.fail_steps is not None:
            return sorted(s for s in set(self.fail_steps)
                          if 0 <= s < n_steps)
        if self.p_fail <= 0.0:
            return []
        return [s for s in range(n_steps)
                if random.Random(self._key(s)).random() < self.p_fail]


class NodeFailure(RuntimeError):
    pass


def run_with_restarts(*, init_state, train_one_step: Callable,
                      ckpt_manager, n_steps: int,
                      injector: Optional[FailureInjector] = None,
                      restore_template=None, shardings=None,
                      max_restarts: int = 10):
    """Run `n_steps`, checkpointing via `ckpt_manager`, surviving injected
    failures. Returns (state, history, n_restarts)."""
    injector = injector or FailureInjector()
    state = init_state
    history = []
    restarts = 0
    step = 0
    # always have a restore point BEFORE the first step: with buffer
    # donation, init_state's buffers die inside step 0 — a failure before
    # the first periodic checkpoint must restore from step 0, not from the
    # (donated) python object.
    ckpt_manager.maybe_save(0, state)
    while step < n_steps:
        try:
            if injector.should_fail(step):
                raise NodeFailure(f"injected failure at step {step}")
            state, metrics = train_one_step(state, step)
            history.append((step, metrics))
            step += 1
            ckpt_manager.maybe_save(step, state)
        except NodeFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            template = restore_template if restore_template is not None \
                else state
            try:
                state, ck_step = ckpt_manager.restore_latest(
                    template, shardings=shardings)
            except FileNotFoundError:
                state, ck_step = init_state, 0
            step = ck_step
            # drop history for steps the restore rewound past — the
            # replay will re-append them (history stays strictly
            # increasing in step)
            while history and history[-1][0] >= ck_step:
                history.pop()
    ckpt_manager.finalize()
    return state, history, restarts
