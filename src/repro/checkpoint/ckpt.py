"""Checkpointing: step-atomic pytree snapshots with a manifest, async
writes, retention, and ELASTIC restore — a checkpoint written under any
mesh loads onto any other mesh (the VDC composer re-sizes jobs this way).

Format: one .npz per checkpoint (leaves flattened by keypath) + manifest
json. Leaves are fully gathered on save (fine at the scales we execute on
this host; a production deployment would write per-shard OCDBT — the
interface is the same).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    leaves = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(template):
        key = jax.tree_util.keystr(path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    blocking: bool = True, executor=None):
    """Write `tree` at `step` atomically (tmp + rename). With
    blocking=False and an `executor`, the device→host transfer happens
    now but the file write is async (returns a future). The caller owns
    the executor's lifecycle; without one the write is synchronous."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)  # device→host sync point

    def _write():
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
        final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, final)
        with open(os.path.join(ckpt_dir, "manifest.json"), "w") as f:
            json.dump({"latest_step": step,
                       "steps": sorted(all_steps(ckpt_dir))}, f)
        return final

    if blocking or executor is None:
        return _write()
    return executor.submit(_write)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for fn in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)\.npz$", fn)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, template, step: Optional[int] = None,
                       shardings=None):
    """Restore into `template`'s structure. With `shardings` (a pytree of
    NamedSharding), leaves are placed sharded — THE ELASTIC PATH: the mesh
    may differ arbitrarily from the one that wrote the checkpoint."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step


class CheckpointManager:
    """Retention + cadence policy around save/restore."""

    def __init__(self, ckpt_dir: str, save_every: int = 100,
                 keep: int = 3, async_write: bool = True):
        self.dir = ckpt_dir
        self.save_every = save_every
        self.keep = keep
        self.async_write = async_write
        self._pending = None
        # Each manager owns its write thread (created lazily, shut down
        # in finalize) so async writes from different managers never
        # serialize through a shared module-level executor.
        self._executor = None

    def maybe_save(self, step: int, tree) -> bool:
        if step % self.save_every:
            return False
        if self._pending is not None:
            self._pending.result()  # one write in flight at a time
            self._pending = None
        if self.async_write and self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(1)
        res = save_checkpoint(self.dir, step, tree,
                              blocking=not self.async_write,
                              executor=self._executor)
        if not isinstance(res, str):
            self._pending = res
        self._gc()
        return True

    def finalize(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        self._gc()

    def _gc(self):
        steps = all_steps(self.dir)
        for s in steps[:-self.keep]:
            try:
                os.remove(os.path.join(self.dir, f"step_{s:08d}.npz"))
            except OSError:
                pass

    def restore_latest(self, template, shardings=None):
        self.finalize()
        return restore_checkpoint(self.dir, template, shardings=shardings)
