from repro.checkpoint.ckpt import (save_checkpoint, restore_checkpoint,
                                   latest_step, CheckpointManager)
from repro.checkpoint.failure import (FailureInjector, NodeFailure,
                                      run_with_restarts)
