"""Record-conservation accounting shared by every co-simulation.

The functional dataflow always executes in-process through the real
:class:`~repro.pipeline.composition.Pipeline`; these taps instrument the
broker queues and service fires so the engine can attribute every record
to exactly one terminal bucket (set partitions, not tallies), and the
drive helper advances the pipeline deterministically over the horizon.

Moved here from ``repro.placement.cosim`` (which re-exports for
backward compatibility).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.pipeline.composition import Pipeline

_EPS = 1e-6


# ---------------------------------------------------------------------------
# Record-conservation ledger
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ServiceLedger:
    """Exact per-service record accounting (set partitions, not tallies)."""
    service: str
    queue: str = ""           # input queue (shared queues fan out)
    produced: int = 0         # published into the service's input queue
    overflow: int = 0         # queue capacity drops, never fetched
    unread: int = 0           # still sitting in the queue
    fetched: int = 0
    processed_edge: int = 0   # covered by a fire executed on the edge
    processed_dc: int = 0     # covered by a fire whose DC task completed
    dropped_dc: int = 0       # shipped, but the DC scheduler dropped it
    inflight_dc: int = 0      # shipped, task still pending at the horizon
    buffered: int = 0         # in the service buffer, not yet covered
    evicted_stored: int = 0   # spilled to the post-mortem store (retained)
    evicted_lost: int = 0     # evicted with no store attached
    # records processed TWICE under an at-least-once cold cutover (the
    # replay re-covers records the dead source already covered). Outside
    # the conservation partition on purpose: each record still lands in
    # exactly one terminal bucket; this counts the extra passes.
    duplicates: int = 0

    @property
    def covered(self) -> int:
        return (self.processed_edge + self.processed_dc
                + self.dropped_dc + self.inflight_dc)

    @property
    def in_flight(self) -> int:
        return (self.unread + self.buffered + self.inflight_dc
                + self.evicted_stored)

    @property
    def dropped(self) -> int:
        return self.overflow + self.dropped_dc + self.evicted_lost

    def conserved(self) -> bool:
        return (self.produced == self.overflow + self.unread + self.fetched
                and self.fetched == self.covered + self.buffered
                + self.evicted_stored + self.evicted_lost)


@dataclasses.dataclass
class RecordLedger:
    services: Dict[str, ServiceLedger] = dataclasses.field(default_factory=dict)

    def conserved(self) -> bool:
        return all(s.conserved() for s in self.services.values())

    def totals(self) -> Dict[str, int]:
        """Rolled-up counts. Queue-level keys (produced/overflow/unread)
        are deduplicated per queue so shared queues are not counted once
        per consumer; the remaining keys are per-consumer deliveries and
        may legitimately exceed `produced` when a queue fans out."""
        consumer_keys = ("fetched", "processed_edge", "processed_dc",
                         "dropped_dc", "inflight_dc", "buffered",
                         "evicted_stored", "evicted_lost")
        out = {k: sum(getattr(s, k) for s in self.services.values())
               for k in consumer_keys}
        seen = set()
        for k in ("produced", "overflow", "unread"):
            out[k] = 0
        for s in self.services.values():
            if s.queue in seen:
                continue
            seen.add(s.queue)
            for k in ("produced", "overflow", "unread"):
                out[k] += getattr(s, k)
        # at-least-once accounting: emitted only when nonzero so
        # chaos-free totals stay byte-identical to recorded benchmarks
        dup = sum(s.duplicates for s in self.services.values())
        if dup:
            out["duplicates"] = dup
        return out


class _PublisherContext:
    """Which service's fire is currently publishing (None = a producer
    farm). Lets queue taps attribute each record to its origin, which
    the uplink model needs to tell edge-origin records from results that
    never left the DC."""
    current: Optional[str] = None


class _QueueTap:
    """Instruments one broker queue: identity and origin of every
    published, dropped and per-consumer fetched record. Consumers (the
    service taps) may register a per-consumer listener to observe each
    fetched batch incrementally instead of re-scanning buffers."""

    def __init__(self, q, ctx: _PublisherContext):
        self.q = q
        self.pub_refs: List[object] = []
        self.drop_refs: List[object] = []
        self.origin: Dict[int, Optional[str]] = {}
        self.fetched: Dict[str, Dict[int, object]] = {}
        self.listeners: Dict[str, object] = {}
        orig_pub, orig_fetch = q.publish, q.fetch
        pub_append = self.pub_refs.append
        origin = self.origin
        buf = q.buf    # the deque is mutated in place, never reassigned

        def publish(rec):
            # detect overflow from the queue's own counter (drop-oldest:
            # the victim is the head snapshotted before the publish);
            # below capacity no drop is possible, skip the snapshots
            if len(buf) >= q.capacity:
                oldest = buf[0] if buf else None
                before = q.dropped
                orig_pub(rec)
                if q.dropped > before:
                    self.drop_refs.append(oldest)
            else:
                orig_pub(rec)
            pub_append(rec)
            origin[id(rec)] = ctx.current

        def fetch(consumer, max_n=1 << 30):
            recs = orig_fetch(consumer, max_n)
            if recs:
                got = self.fetched.get(consumer)
                if got is None:
                    got = self.fetched[consumer] = {}
                got.update(zip(map(id, recs), recs))
                lis = self.listeners.get(consumer)
                if lis is not None:
                    lis(recs)
            else:
                self.fetched.setdefault(consumer, {})
            return recs

        q.publish, q.fetch = publish, fetch


@dataclasses.dataclass
class FireRec:
    """One recorded service fire."""
    ts: float
    n_window: int   # values the operator aggregated (incl. store history)
    n_new: int      # records newly covered by this fire (first coverage)
    # n_new split by origin: None = farm/source, else producing service
    origins: Dict[Optional[str], int] = dataclasses.field(default_factory=dict)


class _ServiceTap:
    """Wraps StreamService.fire to log fires, first-coverage counts and
    per-origin attribution; marks the service as publisher while its
    sinks run.

    Coverage tracking is incremental: the queue tap's fetch listener
    feeds each newly fetched batch into an insertion-ordered uncovered
    map and the service's spill hook retires evictions, so a fire scans
    only the handful of records still awaiting coverage instead of the
    whole operator buffer (which is mostly already-covered window
    history). The counts and the per-origin attribution are identical
    to the original full-buffer scan: the uncovered map preserves
    buffer order, so records are covered in the same order."""

    def __init__(self, svc, qtap: _QueueTap, ctx: _PublisherContext):
        self.svc = svc
        self.fires: List[FireRec] = []
        self.covered: Dict[int, object] = {}
        self._uncovered: Dict[int, object] = {}
        orig_fire = svc.fire
        origin_get = qtap.origin.get
        unc = self._uncovered
        covered = self.covered

        def on_fetched(recs):
            unc.update(zip(map(id, recs), recs))

        qtap.listeners[svc.cfg.name] = on_fetched

        def on_spill(spill):
            for r in spill:
                unc.pop(id(r), None)

        svc._spill_hook = on_spill

        def fire(now):
            n_new = 0
            origins: Dict[Optional[str], int] = {}
            if unc:
                newly = [rid for rid, r in unc.items() if r.ts < now]
                n_new = len(newly)
                for rid in newly:
                    covered[rid] = unc.pop(rid)
                    o = origin_get(rid)
                    origins[o] = origins.get(o, 0) + 1
            prev = ctx.current
            ctx.current = svc.cfg.name
            try:
                res = orig_fire(now)
            finally:
                ctx.current = prev
            self.fires.append(FireRec(ts=now, n_window=res["n"],
                                      n_new=n_new, origins=origins))
            return res

        svc.fire = fire


def _topo_order(topology: Dict[str, List[str]],
                insertion: Sequence[str]) -> List[str]:
    """Kahn's algorithm, stable w.r.t. pipeline insertion order."""
    for n, ups in topology.items():
        for u in ups:
            if u not in topology:
                raise ValueError(
                    f"upstream {u!r} of {n!r} was connect()ed but never "
                    "add_service()d to the pipeline")
    indeg = {n: len(ups) for n, ups in topology.items()}
    order, ready = [], [n for n in insertion if indeg[n] == 0]
    while ready:
        n = ready.pop(0)
        order.append(n)
        for m in insertion:
            if n in topology[m]:
                indeg[m] -= topology[m].count(n)
                if indeg[m] == 0 and m not in order and m not in ready:
                    ready.append(m)
    if len(order) != len(topology):
        raise ValueError("pipeline topology has a cycle")
    return order


def tap_pipeline(pipe: Pipeline
                 ) -> Tuple[Dict[str, _ServiceTap], Dict[str, _QueueTap]]:
    """Instrument every queue/service of ``pipe`` without driving it.
    Returns the service taps and the per-service queue taps. This is the
    shared half of :func:`tap_and_drive`; the live serving runtime
    (``repro.serve``) taps the pipeline the same way but lets its event
    loop do the driving, so engine and runtime emit one ledger schema."""
    ctx = _PublisherContext()
    qtaps: Dict[int, _QueueTap] = {}
    for s in pipe.services:
        if id(s.q) not in qtaps:
            qtaps[id(s.q)] = _QueueTap(s.q, ctx)
    staps = {s.cfg.name: _ServiceTap(s, qtaps[id(s.q)], ctx)
             for s in pipe.services}
    by_service = {s.cfg.name: qtaps[id(s.q)] for s in pipe.services}
    return staps, by_service


def tap_and_drive(pipe: Pipeline, horizon_s: float,
                  step_s: Optional[float] = None
                  ) -> Tuple[Dict[str, _ServiceTap], Dict[str, _QueueTap]]:
    """Instrument every queue/service of ``pipe`` and drive the
    functional dataflow to ``horizon_s`` in ``step_s`` increments
    (default: the minimum service slide). Returns the service taps and
    the per-service queue taps — the placement-independent fire trace
    every engine run replays."""
    staps, by_service = tap_pipeline(pipe)
    step = step_s or min(s.cfg.window.slide_s for s in pipe.services)
    t = 0.0
    while t < horizon_s - _EPS:
        t = min(t + step, horizon_s)
        pipe.advance_to(t)
    return staps, by_service
