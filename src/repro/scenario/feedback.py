"""Closed-loop forecast calibration: feed the measured calibration gap
back into plan ranking.

PR 3/4 built the *measurement* half of the ROADMAP's fleet-aware
forecast-calibration item: every online epoch records the forecast VoS
of the played plan, the realized co-sim VoS, and their gap. This module
closes the loop. A :class:`CalibrationLoop` accumulates, per service,
the pairing of

  * what the analytic forecast *predicted* for the played plan (raw
    per-fire latency, per-epoch VoS), against
  * what the DES engine *realized* for that epoch (mean fire latency,
    terminal drop fraction, per-epoch VoS — the per-service ledger
    residuals the engine now exposes through
    ``EpochObservation.realized_window``),

and fits three per-service correction terms by recursive least squares
with exponential forgetting:

  q_mult       queueing-inflation multiplier on the modeled latency —
               absorbs the systematic under/over-estimate of the
               analytic queueing terms (FIFO uplink waits, VDC
               composition backpressure, serial rank blocking)
  lat_bias_s   additive network-latency bias — absorbs fixed per-fire
               transport costs the closed forms miss (handoff hops,
               admission waits)
  drop_offset  drop-probability offset — the realized fraction of
               terminal fires the DC scheduler dropped, which the
               forecast (which never predicts drops) prices at full
               value

The corrections are *injected into both ranking tiers*: the online
controller's :class:`~repro.online.controller.ForecastModel` applies
them per service when scoring candidate plans, and the vectorized
tier-1 :class:`~repro.scenario.screen.ScreeningModel` applies them
inside ``score_matrix`` (threaded through
``repro.placement.search.screened_search``), so the two-tier search
ranks with calibrated terms while the exact DES tier stays ground
truth.

Everything here is plain deterministic float math — same spec + seed
produces an identical correction history (pinned by a regression test).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

_LAT_CAP_S = 1e6     # ignore cliffed forecasts (q_factor NEVER_S)


@dataclasses.dataclass(frozen=True)
class ServiceCorrection:
    """One set of calibration terms applied on top of an analytic
    latency/value model. The identity correction is a no-op."""
    q_mult: float = 1.0        # queueing-inflation multiplier
    lat_bias_s: float = 0.0    # additive network-latency bias
    drop_offset: float = 0.0   # probability a fire realizes zero value

    def latency(self, lat_s: float) -> float:
        """Calibrated latency for a raw model latency (never negative)."""
        return max(0.0, self.q_mult * lat_s + self.lat_bias_s)

    @property
    def keep_prob(self) -> float:
        return max(0.0, 1.0 - self.drop_offset)

    @property
    def is_identity(self) -> bool:
        return (self.q_mult == 1.0 and self.lat_bias_s == 0.0
                and self.drop_offset == 0.0)

    def tier(self, is_edge: bool) -> "ServiceCorrection":
        """Flat corrections apply to both placement tiers (duck-shared
        with :class:`ServiceCalibration`)."""
        return self

    def to_dict(self) -> Dict[str, float]:
        return {"q_mult": round(self.q_mult, 4),
                "lat_bias_s": round(self.lat_bias_s, 4),
                "drop_offset": round(self.drop_offset, 4)}


_IDENTITY = ServiceCorrection()


@dataclasses.dataclass(frozen=True)
class ServiceCalibration:
    """A service's corrections, resolved per placement *tier*. The
    forecast's error structure is fundamentally different for an
    edge-hosted fire (serial device + rank blocking + cross-site hauls)
    and a DC-offloaded one (uplink transfer + VDC composition pressure
    + scheduler drops), so the loop learns the two tiers independently
    and a candidate plan is scored with the corrections of the tier it
    actually places the service on — DC drop fractions must not tax an
    edge placement."""
    edge: ServiceCorrection = _IDENTITY
    dc: ServiceCorrection = _IDENTITY

    def tier(self, is_edge: bool) -> ServiceCorrection:
        return self.edge if is_edge else self.dc

    def to_dict(self) -> Dict[str, Dict[str, float]]:
        return {"edge": self.edge.to_dict(), "dc": self.dc.to_dict()}


class _Rls2:
    """2-parameter recursive least squares with exponential forgetting:
    y ≈ theta0·x + theta1. The prior covariance is *diagonal and
    asymmetric* — a tight prior on the multiplier (a 2-point history
    must not extrapolate a slope-7 line through noisy epochs) and a
    looser one on the bias. Plain-float implementation (no RNG, no
    global state) so the loop is bit-deterministic."""

    def __init__(self, forgetting: float, p0_mult: float, p0_bias: float,
                 theta0: Tuple[float, float] = (1.0, 0.0)):
        self.lam = forgetting
        self.theta = [theta0[0], theta0[1]]
        # P starts as diag(p0_mult, p0_bias); stays symmetric [[a,b],[b,c]]
        self.p = [p0_mult, 0.0, p0_bias]

    def update(self, x: float, y: float) -> None:
        a, b, c = self.p
        t0, t1 = self.theta
        # P @ [x, 1]
        px0 = a * x + b
        px1 = b * x + c
        denom = self.lam + x * px0 + px1
        if denom <= 0.0 or not math.isfinite(denom):
            return
        k0, k1 = px0 / denom, px1 / denom
        err = y - (t0 * x + t1)
        self.theta = [t0 + k0 * err, t1 + k1 * err]
        # P <- (P - K (P x)^T) / lam, keeping symmetry explicitly
        self.p = [(a - k0 * px0) / self.lam,
                  (b - (k0 * px1 + k1 * px0) / 2.0) / self.lam,
                  (c - k1 * px1) / self.lam]


class _Rls1:
    """1-parameter RLS (constant regressor) — an exponentially forgotten
    running mean, used for the realized drop fraction."""

    def __init__(self, forgetting: float, p0: float, theta0: float = 0.0):
        self.lam = forgetting
        self.theta = theta0
        self.p = p0

    def update(self, y: float) -> None:
        k = self.p / (self.lam + self.p)
        self.theta += k * (y - self.theta)
        self.p = (self.p - k * self.p) / self.lam


class CalibrationLoop:
    """Online per-service correction fitting (see the module docstring).

    ``observe`` is fed once per *completed* epoch with the stored raw
    forecast detail of the plan that was played and the engine's
    realized per-service residuals; ``corrections`` returns the current
    clamped :class:`ServiceCorrection` per service. ``history`` keeps
    one entry per observation (epoch, per-service observed pairs, the
    corrections in force after the update) — the determinism regression
    compares two runs' histories for exact equality.
    """

    def __init__(self, services: Sequence[str], forgetting: float = 0.85,
                 p0_mult: float = 0.1, p0_bias: float = 0.25,
                 p0_drop: float = 25.0, stale_decay: float = 0.7,
                 q_mult_bounds: Tuple[float, float] = (0.3, 3.0),
                 lat_bias_bounds: Tuple[float, float] = (-5.0, 30.0),
                 drop_bounds: Tuple[float, float] = (0.0, 0.9),
                 q_mult_deadband: float = 0.25,
                 lat_bias_deadband_s: float = 0.5,
                 drop_deadband: float = 0.1):
        if not 0.0 < forgetting <= 1.0:
            raise ValueError("forgetting must be in (0, 1]")
        if not 0.0 <= stale_decay <= 1.0:
            raise ValueError("stale_decay must be in [0, 1]")
        self.services = list(services)
        self.forgetting = forgetting
        self.p0_mult = p0_mult
        self.p0_bias = p0_bias
        self.p0_drop = p0_drop
        self.stale_decay = stale_decay
        self.q_mult_bounds = q_mult_bounds
        self.lat_bias_bounds = lat_bias_bounds
        self.drop_bounds = drop_bounds
        # deadbands: a term stays *exactly* identity until its fitted
        # deviation is significant. A forecast that is already well
        # calibrated must be left bit-identical — near-zero corrections
        # would only perturb near-zero gaps and flip near-tie plan
        # decisions without evidence.
        self.q_mult_deadband = q_mult_deadband
        self.lat_bias_deadband_s = lat_bias_deadband_s
        self.drop_deadband = drop_deadband
        self.reset()

    def reset(self) -> None:
        """Forget everything (``controller.bind`` marks a run start)."""
        self._lat = {(s, t): _Rls2(self.forgetting, self.p0_mult,
                                   self.p0_bias)
                     for s in self.services for t in ("edge", "dc")}
        self._drop = {(s, t): _Rls1(self.forgetting, self.p0_drop)
                      for s in self.services for t in ("edge", "dc")}
        # epochs since a tier last learned anything: unobserved tiers
        # decay toward identity so the controller can re-explore a tier
        # it abandoned (a DC drop storm at the tide's peak must not
        # condemn the DC forever once the tide recedes)
        self._stale = {(s, t): 0 for s in self.services
                       for t in ("edge", "dc")}
        self.observations = 0
        self.history: List[Dict] = []

    # ----------------------------------------------------------- learning
    def observe(self, epoch: int, predicted: Mapping[str, Mapping],
                realized: Mapping[str, Mapping]) -> None:
        """One completed epoch. ``predicted[svc]`` carries the raw
        (uncorrected) forecast for the plan that was played — at least
        ``lat_s`` and the placement ``tier`` (``"edge"``/``"dc"``);
        ``vos`` if available. ``realized[svc]`` carries the engine's
        residuals: ``lat_mean_s``, ``completed``, ``dropped``,
        ``inflight``, ``vos``. Only the tier the plan actually placed
        the service on learns from the epoch."""
        seen: Dict[str, Dict] = {}
        learned = set()
        for svc in self.services:
            p, r = predicted.get(svc), realized.get(svc)
            if not p or not r:
                continue
            tier = p.get("tier", "edge")
            pred_lat = float(p.get("lat_s", float("nan")))
            done = int(r.get("completed", 0))
            dropped = int(r.get("dropped", 0))
            lat_mean = float(r.get("lat_mean_s", float("nan")))
            if (done > 0 and math.isfinite(pred_lat)
                    and math.isfinite(lat_mean)
                    and 0.0 <= pred_lat < _LAT_CAP_S
                    and 0.0 <= lat_mean < _LAT_CAP_S):
                self._lat[(svc, tier)].update(pred_lat, lat_mean)
                learned.add((svc, tier))
            terminal = done + dropped
            if terminal > 0:
                self._drop[(svc, tier)].update(dropped / terminal)
                learned.add((svc, tier))
            seen[svc] = {
                "tier": tier,
                "pred_lat_s": round(pred_lat, 4)
                if math.isfinite(pred_lat) else None,
                "lat_mean_s": round(lat_mean, 4)
                if math.isfinite(lat_mean) else None,
                "pred_vos": p.get("vos_raw", p.get("vos")),
                "vos": r.get("vos"),
                "completed": done, "dropped": dropped,
            }
        for key in self._stale:
            self._stale[key] = 0 if key in learned else self._stale[key] + 1
        self.observations += 1
        self.history.append({
            "epoch": epoch,
            "observed": seen,
            "corrections": {s: c.to_dict()
                            for s, c in self.corrections().items()},
        })

    def set_variance_prior(self, prior: Mapping[str, Mapping[str, float]],
                           scale: float = 0.5,
                           max_inflation: float = 4.0
                           ) -> Dict[Tuple[str, str], float]:
        """Inflate the RLS covariance of volatile (service, tier) pairs.

        ``prior[svc][tier]`` is a relative predictive-uncertainty signal
        in [0, 1] — e.g. the fluid-ensemble VoS spread from
        :func:`repro.fluid.robust.calibration_prior`. Each named pair's
        latency *and* drop covariance is multiplied by
        ``min(1 + scale·rel, max_inflation)``, so services whose
        forecast varies a lot across drift realizations keep larger RLS
        gains and re-calibrate faster, while ``rel == 0`` pairs are left
        bit-identical. Calling this every epoch is the intended use: it
        counteracts covariance shrinkage exactly for the pairs the
        ensemble says are still uncertain. Plain float math —
        deterministic. Returns the applied inflation factors."""
        applied: Dict[Tuple[str, str], float] = {}
        for svc, tiers in sorted(prior.items()):
            for tier, rel in sorted(tiers.items()):
                key = (svc, tier)
                if key not in self._lat:
                    continue
                f = min(1.0 + scale * max(0.0, float(rel)), max_inflation)
                if f == 1.0:
                    continue
                lat = self._lat[key]
                lat.p = [lat.p[0] * f, lat.p[1] * f, lat.p[2] * f]
                self._drop[key].p *= f
                applied[key] = f
        return applied

    # ---------------------------------------------------------- injection
    def _tier_correction(self, svc: str, tier: str) -> ServiceCorrection:
        lo_q, hi_q = self.q_mult_bounds
        lo_b, hi_b = self.lat_bias_bounds
        lo_d, hi_d = self.drop_bounds
        lat = self._lat[(svc, tier)]
        drop = self._drop[(svc, tier)]
        # shrink stale tiers toward identity (re-exploration), then
        # zero out sub-deadband terms (see __init__)
        w = self.stale_decay ** self._stale[(svc, tier)]
        q = 1.0 + w * (min(max(lat.theta[0], lo_q), hi_q) - 1.0)
        b = w * min(max(lat.theta[1], lo_b), hi_b)
        d = w * min(max(drop.theta, lo_d), hi_d)
        return ServiceCorrection(
            q_mult=q if abs(q - 1.0) > self.q_mult_deadband else 1.0,
            lat_bias_s=b if abs(b) > self.lat_bias_deadband_s else 0.0,
            drop_offset=d if d > self.drop_deadband else 0.0)

    def correction(self, svc: str) -> ServiceCalibration:
        return ServiceCalibration(
            edge=self._tier_correction(svc, "edge"),
            dc=self._tier_correction(svc, "dc"))

    def corrections(self) -> Dict[str, ServiceCalibration]:
        """Current clamped per-service, per-tier corrections (identity
        until the first observation of that tier lands)."""
        return {s: self.correction(s) for s in self.services}
