"""Tier-1 plan screening: score whole batches of placement plans in
vectorized numpy passes over the placement-independent fire trace.

The unified engine drives the functional dataflow exactly once per
scenario (the fire trace — timestamps, window sizes, newly-covered
record counts and their origins — does not depend on placement). A
:class:`ScreeningModel` precomputes per-service, per-placement-option
arrays from that trace (fire durations, energies, energy-curve values)
and evaluates the latency / energy / VoS of N candidate plans as array
ops, folding in the same analytic queueing terms the online
controller's ``ForecastModel`` uses (device saturation, shared-uplink
serialization load, DC composition pressure, serial-device rank
blocking) — but trace-driven rather than rate-driven, so actual window
sizes and fire counts are respected.

The screen is a *ranking* model: the exact DES engine re-scores only
the top-K screened survivors (plus the anchors / incumbent), which
bounds the damage of any screening mis-rank — see
``repro.placement.search.screened_search``. Screening is deterministic
(pure array math, no RNG).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.placement.plan import SITE_DC, PlacementPlan, ServicePlacement
from repro.region.hier import regions_view

# Deterministic-arrival queueing inflation lives in
# repro.scenario.queueing (one knee shared by ForecastModel, this
# screen, and the jax fluid engine); re-exported here for callers that
# historically imported it from the screen.
from repro.scenario.queueing import (  # noqa: F401  (re-export)
    NEVER_S, Q_CLIFF, Q_KNEE, q_factor, q_factor_np as _q_factor,
)


@dataclasses.dataclass
class ScreenResult:
    """Duck-typed stand-in for CoSimResult (what the search scorer
    reads); ``vos`` here is the *screened* estimate, not DES truth."""
    vos: float
    feasible: bool
    plan_label: str = ""
    infeasible_reason: str = ""


@dataclasses.dataclass
class _OptionData:
    """Per-(service, option) trace arrays."""
    dur: np.ndarray       # per-fire service time on this option
    v_e: np.ndarray       # per-fire energy-curve value (plan-independent)
    busy: float           # dur.sum() — device / VDC occupancy seconds
    mean_dur: float


class ScreeningModel:
    """Vectorized batch scorer over one compiled scenario's fire trace.

    Built via :meth:`ScenarioEngine.screening_model` (cached on the
    engine, sharing its one functional drive). ``score_batch`` maps a
    sequence of plans to screened VoS estimates; ``score_matrix`` is
    the allocation-free core for index-matrix candidates (what the
    sampled / hill-climbing search uses on large fleets).

    ``set_corrections`` installs per-service forecast-calibration terms
    (:class:`~repro.scenario.feedback.ServiceCorrection`, duck-typed:
    ``q_mult`` / ``lat_bias_s`` / ``drop_offset``): each service's
    per-fire latency matrix is mapped through ``q_mult·lat + bias`` and
    its value scaled by ``1 − drop_offset`` before summation, so tier-1
    ranking uses the same calibrated terms as the online controller's
    ``ForecastModel`` — ``screened_search`` threads them through per
    search and restores the previous state afterwards. With no
    corrections installed the scores are bit-identical to the
    uncalibrated model.
    """

    def __init__(self, engine, corrections=None):
        engine._ensure_driven()
        _, staps, _ = engine._driven
        cfg = engine.cfg
        self.engine = engine
        self.order: List[str] = list(engine.order)
        self.rank = {s: i for i, s in enumerate(self.order)}
        self.topology = engine.topology
        self.horizon_s = float(cfg.horizon_s)
        self.grid_chips = cfg.grid_shape[0] * cfg.grid_shape[1]
        self.records_per_step = cfg.records_per_step
        self.cost = engine.cost

        fleet = cfg.fleet
        self.site_names: List[str] = list(fleet.site_names)
        self._site_idx = {n: j for j, n in enumerate(self.site_names)}
        self._edge = [fleet.site(n).edge for n in self.site_names]
        self._link = [fleet.site(n).link for n in self.site_names]
        self._ram = np.array([e.ram_bytes for e in self._edge])
        user = self._site_idx[fleet.result_site]
        self.dl_user_s = (self._link[user].rtt_s / 2
                          + self._link[user].result_bytes
                          / self._link[user].downlink_bps)

        # hierarchy: per-region edge tiers + RAP trunks. A flat fleet is
        # the degenerate single transparent region — every added term is
        # zero there and the screened scores stay bit-identical.
        regions = regions_view(fleet)
        self.n_regions = len(regions)
        self.region_names: List[str] = [r.name for r in regions]
        rmap = {s: i for i, r in enumerate(regions) for s in r.sites}
        self._region_of = np.array([rmap[n] for n in self.site_names],
                                   dtype=int)
        self._rap = [None if r.transparent else r.rap for r in regions]
        self._hier = any(r is not None for r in self._rap)
        nsites = len(self.site_names)
        # one-result trunk legs per *site* (src-up / dst-down), so the
        # hop term can index them vectorized
        self._rap_res_up = np.zeros(nsites)
        self._rap_res_dn = np.zeros(nsites)
        for j in range(nsites):
            rap = self._rap[self._region_of[j]]
            if rap is not None:
                self._rap_res_up[j] = (rap.rtt_s / 2
                                       + self._link[j].result_bytes
                                       / rap.uplink_bps)
                self._rap_res_dn[j] = (rap.rtt_s / 2
                                       + self._link[j].result_bytes
                                       / rap.downlink_bps)
        rap_u = self._rap[self._region_of[user]]
        if rap_u is not None:
            # DC results ride the user's region trunk down before the
            # last-mile downlink (mirrors Fleet.downlink_time)
            self.dl_user_s += (rap_u.rtt_s / 2
                               + self._link[user].result_bytes
                               / rap_u.downlink_bps)

        self._svc: Dict[str, Dict] = {}
        for s in self.order:
            prof = engine.profiles[s]
            info = engine.services_info[s]
            fires = staps[s].fires
            nw = np.array([f.n_window for f in fires], dtype=float)
            origin_keys = [None] + list(self.topology[s])
            origins = {k: np.array([f.origins.get(k, 0) for f in fires],
                                   dtype=float) for k in origin_keys}
            spec = prof.slo.value_spec()
            self._svc[s] = {
                "profile": prof, "info": info, "nw": nw,
                "origins": origins, "spec": spec,
                "farm_site": self._site_idx[fleet.farm_site(info.queue)],
                "budget": float(info.buffer_budget),
                "slide": float(info.slide_s),
            }
        self._opt_cache: Dict[Tuple, _OptionData] = {}
        self._corr: Dict[str, object] = dict(corrections or {})
        self._corr_gen = 0          # bumped per set_corrections (memo key)
        self._pin_cache: Dict[Tuple, Dict] = {}
        # delta-screening telemetry (see score_block)
        self.delta_calls = 0
        self.dense_fallbacks = 0
        self.delta_pin_hits = 0
        self.delta_pin_misses = 0
        self.delta_cells_saved = 0

    def set_corrections(self, corrections) -> Dict[str, object]:
        """Install (or with ``None`` clear) per-service calibration
        corrections; returns the previously installed mapping so a
        caller can restore it."""
        prev = self._corr
        self._corr = dict(corrections or {})
        self._corr_gen += 1
        return prev

    def delta_stats(self) -> Dict[str, int]:
        """Cumulative delta-screening counters (honest accounting: a
        dense fallback is counted, never hidden)."""
        return {"delta_calls": self.delta_calls,
                "dense_fallbacks": self.dense_fallbacks,
                "pin_hits": self.delta_pin_hits,
                "pin_misses": self.delta_pin_misses,
                "cells_saved": self.delta_cells_saved}

    # ------------------------------------------------------ option tables
    def _opt(self, svc: str, p: ServicePlacement) -> _OptionData:
        key = (svc, p.site, p.chips if not p.is_edge else 0,
               p.dvfs_f if not p.is_edge else 0.0)
        d = self._opt_cache.get(key)
        if d is not None:
            return d
        sv = self._svc[svc]
        nw, prof, spec = sv["nw"], sv["profile"], sv["spec"]
        if p.is_edge:
            e = self._edge[self._site_idx[p.site]]
            dur = (np.maximum(nw / e.throughput_rps,
                              nw * prof.flops_per_record / e.flops_per_s)
                   + e.fire_overhead_s)
            energy = nw * e.energy_per_record_j + dur * e.active_power_w
        else:
            steps = np.maximum(1.0, np.ceil(nw / self.records_per_step))
            t_step = self.cost.time_per_step(f"svc:{svc}", "window",
                                             p.chips, p.dvfs_f)
            dur = steps * t_step
            energy = steps * self.cost.energy_per_step(
                f"svc:{svc}", "window", p.chips, p.dvfs_f)
        d = _OptionData(dur=dur, v_e=spec.energy_curve.value_array(energy),
                        busy=float(dur.sum()),
                        mean_dur=float(dur.mean()) if len(dur) else 0.0)
        self._opt_cache[key] = d
        return d

    # --------------------------------------------------------------- core
    def score_matrix(self, P: np.ndarray,
                     options: Sequence[ServicePlacement]) -> np.ndarray:
        """Screened VoS for ``P[n, s]`` = option index of service
        ``order[s]`` in plan ``n``. Infeasible plans (site RAM) score
        ``-inf``. Deterministic. Every term is per-plan, so the batch
        is chunked along the plan axis to bound the O(plans × fires)
        temporaries (a 65k-plan enumeration over a small-slide trace
        would otherwise allocate multi-GB latency matrices)."""
        max_fires = max((len(sv["nw"]) for sv in self._svc.values()),
                        default=1)
        chunk = max(256, 2_000_000 // max(1, max_fires))
        if len(P) > chunk:
            return np.concatenate(
                [self._score_chunk(P[i:i + chunk], options)
                 for i in range(0, len(P), chunk)])
        return self._score_chunk(P, options)

    def _score_chunk(self, P: np.ndarray,
                     options: Sequence[ServicePlacement]) -> np.ndarray:
        N, S = P.shape
        assert S == len(self.order)
        nsites = len(self.site_names)
        site_for = np.array([self._site_idx.get(o.site, -1)
                             for o in options])        # -1 = DC
        chips_for = np.array([o.chips if not o.is_edge else 0
                              for o in options])

        # plan-level context terms -------------------------------------
        util = np.zeros((N, nsites))
        dc_demand = np.zeros(N)
        ram_need = np.zeros((N, nsites))
        up_load = np.zeros((N, self.n_regions))   # per-region edge tier
        rap_load = np.zeros((N, self.n_regions))  # per-region RAP trunk
        exec_site = np.empty((N, S), dtype=int)   # -1 = DC
        for si, s in enumerate(self.order):
            col = P[:, si]
            exec_site[:, si] = site_for[col]
            sv = self._svc[s]
            for o in np.unique(col):
                mask = col == o
                d = self._opt(s, options[o])
                j = site_for[o]
                if j >= 0:
                    util[mask, j] += d.busy / self.horizon_s
                    ram_need[mask, j] += (sv["budget"]
                                          * self._edge[j].record_bytes)
                else:
                    dc_demand[mask] += chips_for[o] * d.busy / self.horizon_s

        # shared-pipe serialization load: raw records hauled off their
        # origin site load the origin *region's* edge tier (flat fleets:
        # the one region = the one shared uplink, bit-identically), and
        # region-leaving moves additionally load the origin RAP trunk
        for si, s in enumerate(self.order):
            sv = self._svc[s]
            dst = exec_site[:, si]
            for okey, counts in sv["origins"].items():
                total = float(counts.sum())
                if total == 0.0:
                    continue
                osite = (np.full(N, sv["farm_site"]) if okey is None
                         else exec_site[:, self.rank[okey]])
                for j in np.unique(osite):
                    if j < 0:
                        continue
                    m = (osite == j) & (dst != j)
                    if not m.any():
                        continue
                    ln = self._link[j]
                    rj = self._region_of[j]
                    wire = total * ln.record_bytes * ln.compression
                    up_load[m, rj] += wire / ln.uplink_bps / self.horizon_s
                    rap = self._rap[rj]
                    if rap is not None:
                        dstm = dst[m]
                        crossing = ((dstm < 0) | (self._region_of[
                            np.clip(dstm, 0, None)] != rj))
                        rows = np.where(m)[0][crossing]
                        rap_load[rows, rj] += (wire / rap.uplink_bps
                                               / self.horizon_s)

        q_site = _q_factor(util)
        q_up = _q_factor(up_load)
        q_rap = _q_factor(rap_load)
        dc_over = np.maximum(1.0, dc_demand / self.grid_chips)
        feasible = (ram_need <= self._ram[None, :]).all(axis=1)

        # serial-device rank blocking: a service queued behind an
        # earlier-rank co-located service eats its fire time
        rank_wait = np.zeros((N, S))
        for si, s in enumerate(self.order):
            slide_s = self._svc[s]["slide"]
            for oi, o in enumerate(self.order):
                if oi >= si:
                    continue
                both = ((exec_site[:, si] >= 0)
                        & (exec_site[:, oi] == exec_site[:, si]))
                if not both.any():
                    continue
                align = min(1.0, slide_s / self._svc[o]["slide"])
                col = P[:, oi]
                for opt in np.unique(col[both]):
                    m = both & (col == opt)
                    rank_wait[m, si] += align * self._opt(
                        o, options[opt]).mean_dur

        # upstream result-handoff hop (max over upstream cuts; a DC
        # destination pays nothing extra here — its downlink is folded
        # into dl_user, exactly like ForecastModel)
        hop = np.zeros((N, S))
        rtt = np.array([self._link[j].rtt_s for j in range(nsites)])
        for si, s in enumerate(self.order):
            my = exec_site[:, si]
            rtt_my = np.where(my >= 0, rtt[np.clip(my, 0, None)], 0.0)
            for u in self.topology[s]:
                us = exec_site[:, self.rank[u]]
                rtt_us = np.where(us >= 0, rtt[np.clip(us, 0, None)], 0.0)
                h = np.where((us != my) & (my >= 0),
                             rtt_my / 2 + np.where(us >= 0, rtt_us / 2, 0.0),
                             0.0)
                if self._hier:
                    # cross-region (or DC-transiting) result handoffs
                    # additionally ride the src RAP up and dst RAP down
                    r_my = self._region_of[np.clip(my, 0, None)]
                    r_us = self._region_of[np.clip(us, 0, None)]
                    crossing = (us < 0) | (my < 0) | (r_us != r_my)
                    extra = (np.where(crossing & (us >= 0),
                                      self._rap_res_up[np.clip(us, 0, None)],
                                      0.0)
                             + np.where(crossing & (my >= 0),
                                        self._rap_res_dn[np.clip(my, 0, None)],
                                        0.0))
                    h = h + np.where((us != my) & (my >= 0), extra, 0.0)
                hop[:, si] = np.maximum(hop[:, si], h)

        # per-service, per-option value accumulation -------------------
        vos = np.zeros(N)
        for si, s in enumerate(self.order):
            sv = self._svc[s]
            spec = sv["spec"]
            col = P[:, si]
            dst = exec_site[:, si]
            # cross-site raw-record haul / edge→DC transfer, per fire
            # per plan (depends on the origin sites, i.e. the plan)
            haul = np.zeros((N, len(sv["nw"])))
            for okey, counts in sv["origins"].items():
                if not counts.any():
                    continue
                osite = (np.full(N, sv["farm_site"]) if okey is None
                         else exec_site[:, self.rank[okey]])
                for j in np.unique(osite):
                    if j < 0:
                        continue
                    m = (osite == j) & (dst != j)
                    if not m.any():
                        continue
                    ln = self._link[j]
                    rj = self._region_of[j]
                    wire = counts * ln.record_bytes * ln.compression
                    leg = (ln.rtt_s / 2
                           + wire[None, :] / ln.uplink_bps
                           * q_up[m, rj][:, None])
                    rap = self._rap[rj]
                    if rap is not None:
                        # region-leaving hauls ride the origin RAP trunk
                        # (contended) on top of the edge-tier leg
                        dstm = dst[m]
                        crossing = ((dstm < 0) | (self._region_of[
                            np.clip(dstm, 0, None)] != rj))
                        if crossing.any():
                            leg[crossing] = (leg[crossing] + rap.rtt_s / 2
                                             + wire[None, :] / rap.uplink_bps
                                             * q_rap[m, rj][crossing, None])
                    # onto another edge site: relay over its downlink
                    # (cross-region: plus its region's RAP trunk down)
                    e_m = m & (dst >= 0)
                    if e_m.any():
                        dn = np.zeros((int(e_m.sum()), len(counts)))
                        sub = dst[e_m]
                        for jj in np.unique(sub):
                            lnd = self._link[jj]
                            sel = sub == jj
                            dn[sel] = (lnd.rtt_s / 2
                                       + counts[None, :]
                                       * lnd.record_bytes
                                       / lnd.downlink_bps)
                            rapd = self._rap[self._region_of[jj]]
                            if rapd is not None and self._region_of[jj] != rj:
                                dn[sel] += (rapd.rtt_s / 2
                                            + counts[None, :]
                                            * lnd.record_bytes
                                            / rapd.downlink_bps)
                        haul[e_m] += leg[dst[m] >= 0] + dn
                    d_m = m & (dst < 0)
                    if d_m.any():
                        haul[d_m] += leg[dst[m] < 0]
            cal = self._corr.get(s)
            for o in np.unique(col):
                mask = col == o
                d = self._opt(s, options[o])
                j = site_for[o]
                if j >= 0:
                    lat = ((d.dur[None, :] + rank_wait[mask, si, None])
                           * q_site[mask, j, None]
                           + hop[mask, si, None] + haul[mask])
                else:
                    lat = (haul[mask]
                           + d.dur[None, :] * dc_over[mask, None]
                           + self.dl_user_s)
                corr = cal.tier(j >= 0) if cal is not None else None
                if corr is not None:
                    # calibrated latency (same per-service, per-tier map
                    # as the online ForecastModel; never negative)
                    lat = np.maximum(
                        corr.q_mult * lat + corr.lat_bias_s, 0.0)
                v_p = spec.perf_curve.value_array(lat)
                v = np.where((v_p > 0.0) & (d.v_e[None, :] > 0.0),
                             spec.gamma * (spec.w_p * v_p
                                           + spec.w_e * d.v_e[None, :]),
                             0.0)
                if corr is not None and corr.drop_offset > 0.0:
                    v = v * max(0.0, 1.0 - corr.drop_offset)
                vos[mask] += v.sum(axis=1)
        vos[~feasible] = float("-inf")
        return vos

    # ------------------------------------------------- delta screening
    def _delta_guard(self, P: np.ndarray, cols: Sequence[int],
                     pinned: Sequence[int], site_for: np.ndarray
                     ) -> bool:
        """True when the block/pinned split decomposes exactly:

        * every pinned column really is constant across the batch;
        * the service DAG never crosses the split (a block service's
          upstreams are all in the block, a pinned service's are all
          pinned), so hop / haul / shared-pipe terms never mix;
        * the *regions* touched by the block (candidate edge sites +
          record-producing farm sites) are disjoint from the regions
          the pinned services occupy or haul from, so every util /
          RAM / edge-tier / RAP-trunk column is fed by only one side
          and the float accumulation order matches the dense pass.

        When any condition fails ``score_block`` falls back to the
        dense ``score_matrix`` — correctness never depends on the
        caller picking a clean block.
        """
        base = P[0]
        if not (P[:, list(pinned)] == base[list(pinned)]).all():
            return False
        colset = set(cols)
        for si, s in enumerate(self.order):
            ups = [self.rank[u] for u in self.topology[s]]
            if si in colset:
                if not all(u in colset for u in ups):
                    return False
            elif any(u in colset for u in ups):
                return False
        block_sites = {int(j) for j in site_for[np.unique(P[:, list(cols)])]
                       if j >= 0}
        for si in cols:
            sv = self._svc[self.order[si]]
            farm_counts = sv["origins"].get(None)
            if farm_counts is not None and farm_counts.any():
                block_sites.add(sv["farm_site"])
        pinned_sites = set()
        for si in pinned:
            j = int(site_for[int(base[si])])
            if j >= 0:
                pinned_sites.add(j)
            sv = self._svc[self.order[si]]
            farm_counts = sv["origins"].get(None)
            if farm_counts is not None and farm_counts.any():
                pinned_sites.add(sv["farm_site"])
        block_regions = {int(self._region_of[j]) for j in block_sites}
        pinned_regions = {int(self._region_of[j]) for j in pinned_sites}
        return not (block_regions & pinned_regions)

    def _hop_scalar(self, s: str, exec_base: np.ndarray) -> float:
        """Upstream handoff hop for one service of a single constant
        row — mirrors the dense hop block term by term."""
        si = self.rank[s]
        my = int(exec_base[si])
        rtt_my = self._link[my].rtt_s if my >= 0 else 0.0
        hop = 0.0
        for u in self.topology[s]:
            us = int(exec_base[self.rank[u]])
            if us == my or my < 0:
                continue
            rtt_us = self._link[us].rtt_s if us >= 0 else 0.0
            h = rtt_my / 2 + (rtt_us / 2 if us >= 0 else 0.0)
            if self._hier:
                r_my = int(self._region_of[max(my, 0)])
                r_us = int(self._region_of[max(us, 0)])
                crossing = (us < 0) or (my < 0) or (r_us != r_my)
                extra = ((self._rap_res_up[max(us, 0)]
                          if crossing and us >= 0 else 0.0)
                         + (self._rap_res_dn[max(my, 0)]
                            if crossing and my >= 0 else 0.0))
                h = h + extra
            hop = max(hop, h)
        return hop

    def _haul_row(self, s: str, exec_base: np.ndarray,
                  q_up_pin: np.ndarray, q_rap_pin: np.ndarray
                  ) -> np.ndarray:
        """Per-fire cross-site haul latency of one pinned service
        (constant across the batch) — mirrors the dense haul block."""
        sv = self._svc[s]
        dst = int(exec_base[self.rank[s]])
        haul = np.zeros(len(sv["nw"]))
        for okey, counts in sv["origins"].items():
            if not counts.any():
                continue
            oj = (sv["farm_site"] if okey is None
                  else int(exec_base[self.rank[okey]]))
            if oj < 0 or dst == oj:
                continue
            ln = self._link[oj]
            rj = int(self._region_of[oj])
            wire = counts * ln.record_bytes * ln.compression
            leg = ln.rtt_s / 2 + wire / ln.uplink_bps * q_up_pin[rj]
            rap = self._rap[rj]
            if rap is not None:
                crossing = (dst < 0
                            or int(self._region_of[dst]) != rj)
                if crossing:
                    leg = (leg + rap.rtt_s / 2
                           + wire / rap.uplink_bps * q_rap_pin[rj])
            if dst >= 0:
                lnd = self._link[dst]
                dn = (lnd.rtt_s / 2
                      + counts * lnd.record_bytes / lnd.downlink_bps)
                rapd = self._rap[self._region_of[dst]]
                if rapd is not None and int(self._region_of[dst]) != rj:
                    dn = dn + (rapd.rtt_s / 2
                               + counts * lnd.record_bytes
                               / rapd.downlink_bps)
                haul += leg + dn
            else:
                haul += leg
        return haul

    def _pinned_bundle(self, cols_key: Tuple[int, ...], base: np.ndarray,
                       options: Sequence[ServicePlacement],
                       site_for: np.ndarray) -> Dict:
        """Everything about the pinned services that depends only on
        the constant part of the batch row: single-row context terms,
        queueing factors, rank waits, hops, and — for edge-resident
        pinned services — the finished per-service VoS scalar. Memoized
        on (block columns, pinned row, calibration generation), so
        successive block-coordinate sweeps that revisit a region with
        an unchanged complement reuse it outright."""
        pinned = [si for si in range(len(self.order)) if si not in cols_key]
        # keyed on the pinned *placements*, not option indices — the
        # same model can be called with differently ordered option
        # tables and a stale index-keyed hit would score the wrong plan
        key = (cols_key, self._corr_gen, tuple(
            (o.site, o.chips if not o.is_edge else 0,
             o.dvfs_f if not o.is_edge else 0.0)
            for o in (options[int(base[si])] for si in pinned)))
        hit = self._pin_cache.get(key)
        if hit is not None:
            self.delta_pin_hits += 1
            return hit
        self.delta_pin_misses += 1
        h = self.horizon_s
        nsites = len(self.site_names)
        exec_base = np.array([int(site_for[int(base[si])])
                              for si in range(len(self.order))])
        util_pin = np.zeros(nsites)
        ram_pin = np.zeros(nsites)
        upl_pin = np.zeros(self.n_regions)
        rapl_pin = np.zeros(self.n_regions)
        for si in pinned:
            s = self.order[si]
            sv = self._svc[s]
            o = int(base[si])
            d = self._opt(s, options[o])
            j = int(site_for[o])
            if j >= 0:
                util_pin[j] += d.busy / h
                ram_pin[j] += sv["budget"] * self._edge[j].record_bytes
        for si in pinned:
            s = self.order[si]
            sv = self._svc[s]
            dst = int(exec_base[si])
            for okey, counts in sv["origins"].items():
                total = float(counts.sum())
                if total == 0.0:
                    continue
                oj = (sv["farm_site"] if okey is None
                      else int(exec_base[self.rank[okey]]))
                if oj < 0 or dst == oj:
                    continue
                ln = self._link[oj]
                rj = int(self._region_of[oj])
                wire = total * ln.record_bytes * ln.compression
                upl_pin[rj] += wire / ln.uplink_bps / h
                rap = self._rap[rj]
                if rap is not None:
                    if dst < 0 or int(self._region_of[dst]) != rj:
                        rapl_pin[rj] += wire / rap.uplink_bps / h
        q_site_pin = _q_factor(util_pin)
        q_up_pin = _q_factor(upl_pin)
        q_rap_pin = _q_factor(rapl_pin)
        ram_ok = bool((ram_pin <= self._ram).all())
        # pinned×pinned rank blocking (block services can never share a
        # site with a pinned service under the delta guard)
        rw_pin = {si: 0.0 for si in pinned}
        for si in pinned:
            s = self.order[si]
            slide_s = self._svc[s]["slide"]
            my = int(exec_base[si])
            if my < 0:
                continue
            for oi in pinned:
                if oi >= si or int(exec_base[oi]) != my:
                    continue
                o = self.order[oi]
                align = min(1.0, slide_s / self._svc[o]["slide"])
                rw_pin[si] += align * self._opt(
                    o, options[int(base[oi])]).mean_dur
        hop_pin = {si: self._hop_scalar(self.order[si], exec_base)
                   for si in pinned}
        edge_vos: Dict[int, float] = {}
        dc_pieces: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        for si in pinned:
            s = self.order[si]
            sv = self._svc[s]
            o = int(base[si])
            d = self._opt(s, options[o])
            j = int(exec_base[si])
            haul = self._haul_row(s, exec_base, q_up_pin, q_rap_pin)
            cal = self._corr.get(s)
            corr = cal.tier(j >= 0) if cal is not None else None
            if j >= 0:
                lat = ((d.dur + rw_pin[si]) * q_site_pin[j]
                       + hop_pin[si] + haul)
                if corr is not None:
                    lat = np.maximum(
                        corr.q_mult * lat + corr.lat_bias_s, 0.0)
                spec = sv["spec"]
                v_p = spec.perf_curve.value_array(lat)
                v = np.where((v_p > 0.0) & (d.v_e > 0.0),
                             spec.gamma * (spec.w_p * v_p
                                           + spec.w_e * d.v_e),
                             0.0)
                if corr is not None and corr.drop_offset > 0.0:
                    v = v * max(0.0, 1.0 - corr.drop_offset)
                edge_vos[si] = float(v.sum())
            else:
                dc_pieces[si] = (haul, d.dur)
        bundle = {"exec_base": exec_base, "util_pin": util_pin,
                  "q_site_pin": q_site_pin, "q_up_pin": q_up_pin,
                  "q_rap_pin": q_rap_pin, "ram_ok": ram_ok,
                  "rw_pin": rw_pin, "hop_pin": hop_pin,
                  "edge_vos": edge_vos, "dc_pieces": dc_pieces}
        if len(self._pin_cache) > 64:
            self._pin_cache.clear()
        self._pin_cache[key] = bundle
        return bundle

    def score_block(self, P: np.ndarray, cols: Sequence[int],
                    options: Sequence[ServicePlacement]) -> np.ndarray:
        """Delta-aware twin of :meth:`score_matrix` for block-coordinate
        batches: every row of ``P`` differs only in ``cols`` (one
        region's services). The pinned complement is scored once per
        distinct pinned row (memoized across sweeps); only the changed
        block is rescored per row. **Bit-identical** to
        ``score_matrix(P, options)``: every accumulation runs in the
        same service order with the same float operations, and the
        delta guard (see :meth:`_delta_guard`) falls back to the dense
        pass whenever the split would mix a util / load column or cross
        the DAG."""
        cols = sorted(int(c) for c in cols)
        colset = set(cols)
        S = len(self.order)
        pinned = [si for si in range(S) if si not in colset]
        site_for = np.array([self._site_idx.get(o.site, -1)
                             for o in options])
        chips_for = np.array([o.chips if not o.is_edge else 0
                              for o in options])
        if (len(P) == 0 or not cols or not pinned
                or not self._delta_guard(P, cols, pinned, site_for)):
            self.dense_fallbacks += 1
            return self.score_matrix(P, options)
        self.delta_calls += 1
        N = len(P)
        base = P[0]
        h = self.horizon_s
        pin = self._pinned_bundle(tuple(cols), base, options, site_for)
        exec_base = pin["exec_base"]
        max_fires = max(len(self._svc[s]["nw"]) for s in self.order)
        self.delta_cells_saved += N * len(pinned) * max_fires

        # block context terms, per row ---------------------------------
        bsites = sorted({int(j) for j in site_for[np.unique(P[:, cols])]
                         if j >= 0})
        bcol = {j: k for k, j in enumerate(bsites)}
        util_blk = np.zeros((N, len(bsites)))
        ram_blk = np.zeros((N, len(bsites)))
        upl_blk = np.zeros((N, self.n_regions))
        rapl_blk = np.zeros((N, self.n_regions))
        exec_blk = np.empty((N, S), dtype=int)   # block cols per row,
        exec_blk[:] = exec_base[None, :]         # pinned cols constant
        dc_demand = np.zeros(N)
        # dc_demand folds pinned scalars and block columns interleaved
        # in service order — the sum is order-sensitive in float
        for si, s in enumerate(self.order):
            sv = self._svc[s]
            if si not in colset:
                o = int(base[si])
                if site_for[o] < 0:
                    dc_demand += (chips_for[o]
                                  * self._opt(s, options[o]).busy / h)
                continue
            col = P[:, si]
            exec_blk[:, si] = site_for[col]
            for o in np.unique(col):
                mask = col == o
                d = self._opt(s, options[int(o)])
                j = int(site_for[o])
                if j >= 0:
                    util_blk[mask, bcol[j]] += d.busy / h
                    ram_blk[mask, bcol[j]] += (sv["budget"]
                                               * self._edge[j].record_bytes)
                else:
                    dc_demand[mask] += chips_for[o] * d.busy / h

        # block shared-pipe loads (block origins only touch block
        # regions under the guard, so these columns are exact)
        for si in cols:
            s = self.order[si]
            sv = self._svc[s]
            dst = exec_blk[:, si]
            for okey, counts in sv["origins"].items():
                total = float(counts.sum())
                if total == 0.0:
                    continue
                osite = (np.full(N, sv["farm_site"]) if okey is None
                         else exec_blk[:, self.rank[okey]])
                for j in np.unique(osite):
                    if j < 0:
                        continue
                    m = (osite == j) & (dst != j)
                    if not m.any():
                        continue
                    ln = self._link[j]
                    rj = self._region_of[j]
                    wire = total * ln.record_bytes * ln.compression
                    upl_blk[m, rj] += wire / ln.uplink_bps / h
                    rap = self._rap[rj]
                    if rap is not None:
                        dstm = dst[m]
                        crossing = ((dstm < 0) | (self._region_of[
                            np.clip(dstm, 0, None)] != rj))
                        rows = np.where(m)[0][crossing]
                        rapl_blk[rows, rj] += (wire / rap.uplink_bps / h)

        q_site_blk = _q_factor(util_blk)
        q_up_blk = _q_factor(upl_blk)
        q_rap_blk = _q_factor(rapl_blk)
        dc_over = np.maximum(1.0, dc_demand / self.grid_chips)
        feasible = pin["ram_ok"] & (ram_blk
                                    <= self._ram[bsites][None, :]).all(axis=1)

        # block×block rank blocking (earlier block services only; the
        # guard rules out pinned co-location)
        rank_wait = {si: np.zeros(N) for si in cols}
        for si in cols:
            slide_s = self._svc[self.order[si]]["slide"]
            for oi in cols:
                if oi >= si:
                    continue
                both = ((exec_blk[:, si] >= 0)
                        & (exec_blk[:, oi] == exec_blk[:, si]))
                if not both.any():
                    continue
                o = self.order[oi]
                align = min(1.0, slide_s / self._svc[o]["slide"])
                col = P[:, oi]
                for opt in np.unique(col[both]):
                    m = both & (col == opt)
                    rank_wait[si][m] += align * self._opt(
                        o, options[int(opt)]).mean_dur

        # block hops (upstreams are in the block under the guard)
        nsites = len(self.site_names)
        rtt = np.array([self._link[j].rtt_s for j in range(nsites)])
        hop = {si: np.zeros(N) for si in cols}
        for si in cols:
            s = self.order[si]
            my = exec_blk[:, si]
            rtt_my = np.where(my >= 0, rtt[np.clip(my, 0, None)], 0.0)
            for u in self.topology[s]:
                us = exec_blk[:, self.rank[u]]
                rtt_us = np.where(us >= 0, rtt[np.clip(us, 0, None)], 0.0)
                hh = np.where((us != my) & (my >= 0),
                              rtt_my / 2 + np.where(us >= 0, rtt_us / 2, 0.0),
                              0.0)
                if self._hier:
                    r_my = self._region_of[np.clip(my, 0, None)]
                    r_us = self._region_of[np.clip(us, 0, None)]
                    crossing = (us < 0) | (my < 0) | (r_us != r_my)
                    extra = (np.where(crossing & (us >= 0),
                                      self._rap_res_up[np.clip(us, 0, None)],
                                      0.0)
                             + np.where(crossing & (my >= 0),
                                        self._rap_res_dn[np.clip(my, 0, None)],
                                        0.0))
                    hh = hh + np.where((us != my) & (my >= 0), extra, 0.0)
                hop[si] = np.maximum(hop[si], hh)

        # per-service value accumulation, in global service order ------
        vos = np.zeros(N)
        for si, s in enumerate(self.order):
            sv = self._svc[s]
            if si not in colset:
                ev = pin["edge_vos"].get(si)
                if ev is not None:
                    vos += ev
                    continue
                haul, dur = pin["dc_pieces"][si]
                cal = self._corr.get(s)
                corr = cal.tier(False) if cal is not None else None
                spec = sv["spec"]
                d = self._opt(s, options[int(base[si])])
                uvals, inv = np.unique(dc_over, return_inverse=True)
                per = np.empty(len(uvals))
                for ui, u in enumerate(uvals):
                    lat = haul + dur * u + self.dl_user_s
                    if corr is not None:
                        lat = np.maximum(
                            corr.q_mult * lat + corr.lat_bias_s, 0.0)
                    v_p = spec.perf_curve.value_array(lat)
                    v = np.where((v_p > 0.0) & (d.v_e > 0.0),
                                 spec.gamma * (spec.w_p * v_p
                                               + spec.w_e * d.v_e),
                                 0.0)
                    if corr is not None and corr.drop_offset > 0.0:
                        v = v * max(0.0, 1.0 - corr.drop_offset)
                    per[ui] = v.sum()
                vos += per[inv]
                continue
            spec = sv["spec"]
            col = P[:, si]
            dst = exec_blk[:, si]
            haul = np.zeros((N, len(sv["nw"])))
            for okey, counts in sv["origins"].items():
                if not counts.any():
                    continue
                osite = (np.full(N, sv["farm_site"]) if okey is None
                         else exec_blk[:, self.rank[okey]])
                for j in np.unique(osite):
                    if j < 0:
                        continue
                    m = (osite == j) & (dst != j)
                    if not m.any():
                        continue
                    ln = self._link[j]
                    rj = self._region_of[j]
                    wire = counts * ln.record_bytes * ln.compression
                    leg = (ln.rtt_s / 2
                           + wire[None, :] / ln.uplink_bps
                           * q_up_blk[m, rj][:, None])
                    rap = self._rap[rj]
                    if rap is not None:
                        dstm = dst[m]
                        crossing = ((dstm < 0) | (self._region_of[
                            np.clip(dstm, 0, None)] != rj))
                        if crossing.any():
                            leg[crossing] = (leg[crossing] + rap.rtt_s / 2
                                             + wire[None, :] / rap.uplink_bps
                                             * q_rap_blk[m, rj][crossing,
                                                                None])
                    e_m = m & (dst >= 0)
                    if e_m.any():
                        dn = np.zeros((int(e_m.sum()), len(counts)))
                        sub = dst[e_m]
                        for jj in np.unique(sub):
                            lnd = self._link[jj]
                            sel = sub == jj
                            dn[sel] = (lnd.rtt_s / 2
                                       + counts[None, :]
                                       * lnd.record_bytes
                                       / lnd.downlink_bps)
                            rapd = self._rap[self._region_of[jj]]
                            if rapd is not None and self._region_of[jj] != rj:
                                dn[sel] += (rapd.rtt_s / 2
                                            + counts[None, :]
                                            * lnd.record_bytes
                                            / rapd.downlink_bps)
                        haul[e_m] += leg[dst[m] >= 0] + dn
                    d_m = m & (dst < 0)
                    if d_m.any():
                        haul[d_m] += leg[dst[m] < 0]
            cal = self._corr.get(s)
            for o in np.unique(col):
                mask = col == o
                d = self._opt(s, options[int(o)])
                j = int(site_for[o])
                if j >= 0:
                    lat = ((d.dur[None, :] + rank_wait[si][mask, None])
                           * q_site_blk[mask, bcol[j], None]
                           + hop[si][mask, None] + haul[mask])
                else:
                    lat = (haul[mask]
                           + d.dur[None, :] * dc_over[mask, None]
                           + self.dl_user_s)
                corr = cal.tier(j >= 0) if cal is not None else None
                if corr is not None:
                    lat = np.maximum(
                        corr.q_mult * lat + corr.lat_bias_s, 0.0)
                v_p = spec.perf_curve.value_array(lat)
                v = np.where((v_p > 0.0) & (d.v_e[None, :] > 0.0),
                             spec.gamma * (spec.w_p * v_p
                                           + spec.w_e * d.v_e[None, :]),
                             0.0)
                if corr is not None and corr.drop_offset > 0.0:
                    v = v * max(0.0, 1.0 - corr.drop_offset)
                vos[mask] += v.sum(axis=1)
        vos[~feasible] = float("-inf")
        return vos

    # ------------------------------------------------------------ fronts
    def matrix_of(self, plans: Sequence[PlacementPlan],
                  options: Sequence[ServicePlacement]) -> np.ndarray:
        idx = {(o.site, o.chips if not o.is_edge else 0,
                o.dvfs_f if not o.is_edge else 0.0): i
               for i, o in enumerate(options)}
        P = np.empty((len(plans), len(self.order)), dtype=int)
        for n, plan in enumerate(plans):
            for si, s in enumerate(self.order):
                p = plan.placement(s)
                P[n, si] = idx[(p.site, p.chips if not p.is_edge else 0,
                                p.dvfs_f if not p.is_edge else 0.0)]
        return P

    def score_batch(self, plans: Sequence[PlacementPlan]) -> np.ndarray:
        """Screened VoS for arbitrary plans (options inferred)."""
        seen: Dict[Tuple, ServicePlacement] = {}
        for plan in plans:
            for p in plan.assignments.values():
                seen.setdefault((p.site, p.chips if not p.is_edge else 0,
                                 p.dvfs_f if not p.is_edge else 0.0), p)
        options = list(seen.values())
        return self.score_matrix(self.matrix_of(plans, options), options)

    def run(self, plan: PlacementPlan) -> ScreenResult:
        """Single-plan front (duck-compatible with the search scorer)."""
        vos = float(self.score_batch([plan])[0])
        if math.isinf(vos) and vos < 0:
            return ScreenResult(vos, False, plan.label, "site RAM")
        return ScreenResult(vos, True, plan.label)
