"""The unified co-simulation engine: one event-feed DES bridge for every
scenario — single-gateway static placements, multi-site fleets, and
online re-placement schedules alike.

Every co-simulation in the repo now runs through this engine (the
single-site two-pass estimator that used to live in
``repro.placement.cosim`` is retired; that module is a deprecation
shim). The functional dataflow (farms → brokers → services) is driven
exactly once — it does not depend on placement — and the timing / energy
of every fire is replayed under a *plan schedule*: at each epoch
boundary a controller (fixed-plan, static, online, or oracle — see
``repro.online.controller``) decides the placement for the coming epoch.

DC-placed fires submit *incrementally* into one persistent JITA-4DS
:class:`~repro.core.simulator.Simulator`: a fire's task enters the live
event heap the moment its inputs exist (``Simulator.inject``), and a
downstream fire waits for the task's *actual* completion event — VDC
composition pressure, power-cap contention and scheduler drops are
co-simulated, never estimated. Grid occupancy and pending backlog
persist across epochs, so a placement switch inherits the DC's real
queue state. Site moves ship operator state over the contended uplink
and stall the service for a warm-up (cost math from
``repro.core.elastic``).

Fire life-cycle::

    new ──deps settled──► queued  (edge)  ──device──► done
                      └─► inflight (dc, task injected) ─► done | failed

A fire's dependencies are every upstream fire with an earlier timestamp;
cross-site results and record hauls route through the fleet (FIFO-
contended shared uplink). Record conservation is tracked per service
*and* per site with exact set partitions.
"""
from __future__ import annotations

import bisect
import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro import hardware as hw
from repro.chaos.inject import ChaosTimeline, FaultObservation
from repro.chaos.migrate import plan_chaos_migrations
from repro.chaos.spec import ChaosSpec
from repro.core.costmodel import CellCost, CostModel
from repro.core.elastic import (SERVICE_WARMUP_S, ServiceMigration,
                                plan_replacement)
from repro.core.heuristics import HEURISTICS, VPTRHeuristic
from repro.core.simulator import SimResult, Simulator
from repro.core.tasks import Task, TaskType
from repro.core.value import task_value
from repro.core.vdc import PodGrid
from repro.online.fleet import Fleet, FleetSpec, SiteSpec
from repro.pipeline.composition import Pipeline
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.plan import SITE_DC, SITE_EDGE, PlacementPlan
from repro.scenario.ledger import (RecordLedger, ServiceLedger, _QueueTap,
                                   _ServiceTap, _topo_order, tap_and_drive)
from repro.scenario.observe import (BridgeInfo, EpochObservation, ServiceInfo,
                                    attach_forecast, epoch_bounds, epoch_of,
                                    merge_realized_vos)
from repro.scenario.profiles import ServiceProfile

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class EngineConfig:
    """Engine knobs. ``epoch_s=None`` runs the whole horizon as one
    epoch (the static single-plan co-sim); setting it enables epoch-based
    re-placement. The default matches the historical ``OnlineConfig``
    (600 s epochs) so legacy fleet callers keep re-placing; spec-compiled
    engines always pass ``epoch_s`` explicitly."""
    fleet: FleetSpec
    horizon_s: float = 3600.0
    epoch_s: Optional[float] = 600.0
    drive_step_s: Optional[float] = None   # None -> min service slide
    heuristic: str = "hinted"
    power_cap_w: Optional[float] = None
    records_per_step: int = 5_000
    dc_step_floor_s: float = 1e-3
    mxu_efficiency: float = 0.5
    grid_shape: Tuple[int, int] = (hw.POD_X, hw.POD_Y)
    migration_warmup_s: float = SERVICE_WARMUP_S
    # Wire footprint of migrated operator state per buffered record. The
    # operator ships compacted window state (partial aggregates + record
    # index), not the raw 64 B in-RAM records.
    state_bytes_per_record: float = 16.0
    # Unplanned-fault injection (None = no chaos; every chaos code path
    # is dormant and the engine is bit-identical to the pre-chaos one).
    chaos: Optional[ChaosSpec] = None


def single_site_fleet(edge: Optional[EdgeSpec] = None,
                      link: Optional[LinkSpec] = None,
                      site: str = SITE_EDGE) -> FleetSpec:
    """The classic paper deployment: one gateway next to the farm."""
    return FleetSpec(sites=(SiteSpec(site, edge or EdgeSpec(),
                                     link or LinkSpec()),))


# ---------------------------------------------------------------------------
# DC-side glue: analytics cost cells + hint-honouring heuristic
# ---------------------------------------------------------------------------
def analytics_cost_model(profiles: Dict[str, ServiceProfile],
                         cfg) -> CostModel:
    """One roofline cell per service: a DC task step processes
    ``records_per_step`` window values of that service's operator. The
    collective term models the VDC composition / kernel-launch floor, so
    tiny windows don't pretend to finish in nanoseconds."""
    cells = {}
    ref = 256
    for name, prof in profiles.items():
        r = cfg.records_per_step
        t_c = (r * prof.flops_per_record
               / (ref * hw.PEAK_FLOPS_BF16 * cfg.mxu_efficiency))
        t_m = r * prof.bytes_per_record / (ref * hw.HBM_BW)
        cells[(f"svc:{name}", "window")] = CellCost(
            t_c, t_m, cfg.dc_step_floor_s, r * prof.bytes_per_record)
    return CostModel(cells)


class HintedVPTR(VPTRHeuristic):
    """VPTR that honours the placement plan's per-task DVFS hint."""
    name = "VPTR-hint"
    can_scale_f = True

    def _freqs(self, task, headroom_fn):
        return (getattr(task, "dvfs_hint", 1.0),)


def _fresh_heuristic(name: str):
    if name == "hinted":
        return HintedVPTR()
    return type(HEURISTICS[name])()


# ---------------------------------------------------------------------------
# Per-service facts the controllers plan with: ServiceInfo, BridgeInfo and
# EpochObservation now live in repro.scenario.observe (the shared protocol
# between this engine and the live serving runtime) and are re-exported
# above for backward compatibility.
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _OFire:
    svc: str
    idx: int
    ts: float
    epoch: int
    n_window: int
    n_new: int
    origins: Dict[Optional[str], int]
    site: str = ""
    state: str = "new"            # new|queued|inflight|done|failed
    start: float = 0.0
    ready_out: Optional[float] = None
    energy_j: float = 0.0
    value: float = 0.0
    dropped: bool = False
    pending: bool = False
    lat_s: Optional[float] = None   # settled realized latency (NaN: no sample)
    arrival_at: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def terminal(self) -> bool:
        return self.state in ("done", "failed")


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------
def _num(x):
    return None if math.isnan(x) or math.isinf(x) else round(x, 4)


@dataclasses.dataclass
class EngineResult:
    """Full co-simulation outcome of one plan schedule."""
    label: str
    vos: float
    vos_normalized: float
    fires_total: int
    fires_completed: int
    fires_dropped: int
    fires_inflight: int
    latency_p50: float
    latency_p95: float
    latency_p99: float
    edge_energy_j: float
    network_energy_j: float
    dc_energy_j: float
    bytes_up: float
    bytes_down: float
    uplink_wait_s: float
    uplink_transfers: int
    migrations: int
    ledger: RecordLedger
    per_site: Dict[str, Dict]
    per_service: Dict[str, Dict]
    epochs: List[Dict]
    dc: Optional[SimResult] = None

    @property
    def energy_total_j(self) -> float:
        return self.edge_energy_j + self.network_energy_j + self.dc_energy_j

    def summary(self) -> Dict:
        return {
            "label": self.label,
            "vos": round(self.vos, 4),
            "vos_normalized": round(self.vos_normalized, 4),
            "fires": {"total": self.fires_total,
                      "completed": self.fires_completed,
                      "dropped": self.fires_dropped,
                      "inflight": self.fires_inflight},
            "latency_s": {"p50": _num(self.latency_p50),
                          "p95": _num(self.latency_p95),
                          "p99": _num(self.latency_p99)},
            "energy_j": {"edge": round(self.edge_energy_j, 2),
                         "network": round(self.network_energy_j, 2),
                         "dc": round(self.dc_energy_j, 2)},
            "bytes": {"up": int(self.bytes_up), "down": int(self.bytes_down)},
            "uplink": {"fifo_wait_s": round(self.uplink_wait_s, 3),
                       "transfers": self.uplink_transfers},
            "migrations": self.migrations,
            "records": self.ledger.totals(),
            "per_site": self.per_site,
            "epochs": self.epochs,
        }


@dataclasses.dataclass
class CoSimResult:
    """Single-plan result (the historical ``placement.cosim`` surface:
    what the placement search scores)."""
    plan_label: str
    feasible: bool
    vos: float
    vos_normalized: float
    fires_total: int
    fires_completed: int
    fires_dropped: int       # DC scheduler drops (value decayed to zero)
    fires_inflight: int      # DC tasks the horizon truncated mid-queue
    latency_p50: float
    latency_p95: float
    latency_p99: float
    edge_energy_j: float
    network_energy_j: float
    dc_energy_j: float
    bytes_up: float
    bytes_down: float
    ledger: RecordLedger = dataclasses.field(default_factory=RecordLedger)
    dc: Optional[SimResult] = None
    per_service: Dict[str, Dict] = dataclasses.field(default_factory=dict)
    infeasible_reason: str = ""

    @property
    def energy_total_j(self) -> float:
        return self.edge_energy_j + self.network_energy_j + self.dc_energy_j

    def summary(self) -> Dict:
        """JSON-safe digest for benchmark output (strict RFC 8259: NaN
        percentiles of infeasible/fire-less runs become null)."""
        return {
            "plan": self.plan_label,
            "feasible": self.feasible,
            "vos": None if not self.feasible else round(self.vos, 4),
            "vos_normalized": None if not self.feasible
            else round(self.vos_normalized, 4),
            "fires": {"total": self.fires_total,
                      "completed": self.fires_completed,
                      "dropped": self.fires_dropped,
                      "inflight": self.fires_inflight},
            "latency_s": {"p50": _num(self.latency_p50),
                          "p95": _num(self.latency_p95),
                          "p99": _num(self.latency_p99)},
            "energy_j": {"edge": round(self.edge_energy_j, 2),
                         "network": round(self.network_energy_j, 2),
                         "dc": round(self.dc_energy_j, 2)},
            "bytes": {"up": int(self.bytes_up), "down": int(self.bytes_down)},
            "records": self.ledger.totals(),
            "infeasible_reason": self.infeasible_reason,
        }


# fields a single-plan CoSimResult copies verbatim from the EngineResult
# (derived, so a metric added to both dataclasses flows automatically)
_SHARED_FIELDS = tuple(
    {f.name for f in dataclasses.fields(CoSimResult)}
    & {f.name for f in dataclasses.fields(EngineResult)})


def _infeasible(plan: PlacementPlan, reason: str) -> CoSimResult:
    return CoSimResult(plan_label=plan.label, feasible=False,
                       vos=float("-inf"), vos_normalized=float("-inf"),
                       fires_total=0, fires_completed=0, fires_dropped=0,
                       fires_inflight=0,
                       latency_p50=float("nan"), latency_p95=float("nan"),
                       latency_p99=float("nan"), edge_energy_j=0.0,
                       network_energy_j=0.0, dc_energy_j=0.0,
                       bytes_up=0.0, bytes_down=0.0,
                       infeasible_reason=reason)


class _FixedPlan:
    """Trivial controller: one plan for every epoch, no migrations."""
    charge_migrations = True

    def __init__(self, plan: PlacementPlan, label: str = ""):
        self.plan = plan
        self.label = label or plan.label

    def decide(self, obs: EpochObservation) -> PlacementPlan:
        return self.plan


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------
class ScenarioEngine:
    """Co-simulates one scenario's pipeline across its site topology
    under a controller-produced plan schedule. ``build`` must return a
    fresh Pipeline (with its farms) on every call; the functional drive
    is cached so several controllers / plans replay identical record
    streams. Usually constructed via ``ScenarioSpec.compile()``."""

    def __init__(self, build: Callable[[], Pipeline],
                 profiles: Dict[str, ServiceProfile],
                 cfg: EngineConfig,
                 outages: Optional[Mapping[str, Sequence[Tuple[float, float]]]]
                 = None):
        self.build = build
        self.profiles = dict(profiles)
        self.cfg = cfg
        self.outages = {k: tuple(v) for k, v in (outages or {}).items()}
        pipe = build()
        self.topology = pipe.topology()
        names = [s.cfg.name for s in pipe.services]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate service names: {names}")
        missing = set(self.topology) - set(self.profiles)
        if missing:
            raise ValueError(f"no ServiceProfile for {sorted(missing)}")
        self.order = _topo_order(self.topology, names)
        self.rank = {s: i for i, s in enumerate(self.order)}
        self.cost = analytics_cost_model(self.profiles, cfg)
        self.services_info = {
            s.cfg.name: ServiceInfo(queue=s.cfg.queue,
                                    slide_s=s.cfg.window.slide_s,
                                    width_s=s.cfg.window.width_s,
                                    buffer_budget=s.cfg.buffer_budget)
            for s in pipe.services}
        # epoch boundaries (last epoch absorbs any sub-epoch remainder)
        self.epoch_s = cfg.epoch_s or cfg.horizon_s
        self.epochs = epoch_bounds(cfg.horizon_s, cfg.epoch_s)
        self._fresh_pipe: Optional[Pipeline] = pipe
        self._driven = None
        self._true_rates: Optional[List[Dict[str, float]]] = None
        self._ledger_static: Optional[Dict[str, Dict]] = None
        self._screen = None
        self._fluid: Dict = {}

    @property
    def all_sites(self) -> Tuple[str, ...]:
        return tuple(self.cfg.fleet.site_names) + (SITE_DC,)

    # --------------------------------------------------------------- driving
    def _ensure_driven(self):
        if self._driven is None:
            pipe, self._fresh_pipe = self._fresh_pipe or self.build(), None
            staps, by_service = tap_and_drive(pipe, self.cfg.horizon_s,
                                              self.cfg.drive_step_s)
            self._driven = (pipe, staps, by_service)
        return self._driven

    def _epoch_of(self, ts: float) -> int:
        return epoch_of(self.epochs, ts)

    def true_epoch_rates(self) -> List[Dict[str, float]]:
        """Ground-truth newly-covered-records/s per service per epoch
        (drive-derived; what the oracle plans with). Plan-independent,
        so computed once — a search calls run_plan per candidate."""
        if self._true_rates is None:
            _, staps, _ = self._ensure_driven()
            out = [{s: 0.0 for s in self.order} for _ in self.epochs]
            for svc, tap in staps.items():
                for fr in tap.fires:
                    k = self._epoch_of(fr.ts)
                    out[k][svc] += fr.n_new
            for k, (t0, t1) in enumerate(self.epochs):
                for svc in out[k]:
                    out[k][svc] /= max(t1 - t0, _EPS)
            self._true_rates = out
        return [dict(r) for r in self._true_rates]

    def screening_model(self):
        """Cached tier-1 vectorized plan screener over this engine's
        (placement-independent) fire trace — see
        :class:`repro.scenario.screen.ScreeningModel`. The screened
        search (``repro.placement.search.screened_search``) uses it to
        score whole candidate batches in one numpy pass and reserves
        the exact DES replay for the top-K survivors."""
        if self._screen is None:
            from repro.scenario.screen import ScreeningModel
            self._screen = ScreeningModel(self)
        return self._screen

    def fluid_engine(self, dt_s=None):
        """Cached fluid lowering of this engine (one per ``dt_s``) —
        see :class:`repro.fluid.engine.FluidEngine`. Drift ensembles
        (:class:`repro.fluid.ensemble.ScenarioEnsemble`) built on this
        engine route through here, so an epoch loop that re-ranks
        finalists every epoch reuses the lowered trace arrays and the
        jit cache instead of re-lowering per ensemble."""
        fl = self._fluid.get(dt_s)
        if fl is None:
            from repro.fluid.engine import FluidEngine
            fl = self._fluid[dt_s] = FluidEngine.compile(self, dt_s=dt_s)
        return fl

    def info(self) -> BridgeInfo:
        return BridgeInfo(topology=self.topology, profiles=self.profiles,
                          fleet=self.cfg.fleet, services=self.services_info,
                          cost=self.cost,
                          grid_chips=(self.cfg.grid_shape[0]
                                      * self.cfg.grid_shape[1]),
                          epoch_s=self.epoch_s,
                          records_per_step=self.cfg.records_per_step,
                          outages=self.outages)

    # ------------------------------------------------------------- plumbing
    def _site_ram_ok(self, plan: PlacementPlan) -> Optional[str]:
        for name in self.cfg.fleet.site_names:
            spec = self.cfg.fleet.site(name).edge
            budget = sum(self.services_info[s].buffer_budget
                         for s in self.order if plan.site(s) == name)
            if spec.ram_required(budget) > spec.ram_bytes:
                return (f"site {name} RAM: buffer budgets need "
                        f"{spec.ram_required(budget)/2**20:.0f} MiB, device "
                        f"has {spec.ram_bytes/2**20:.0f} MiB")
        return None

    def _state_bytes(self, svc: str) -> float:
        info = self.services_info[svc]
        return info.buffer_budget * self.cfg.state_bytes_per_record

    def _plan_at(self, ts: float) -> PlacementPlan:
        """Plan governing a fire with timestamp ``ts``. Plans are keyed
        by *adoption time* (epoch boundaries, plus mid-epoch chaos
        re-plans), so with one plan per epoch this is exactly the old
        ``self._plans[fire.epoch]`` lookup."""
        i = bisect.bisect_right(self._plan_times, ts) - 1
        return self._plans[i if i >= 0 else 0]

    def _origin_site(self, f: _OFire, origin: Optional[str]) -> str:
        if origin is None:
            return self.cfg.fleet.farm_site(self.services_info[f.svc].queue)
        return self._plan_at(f.ts).site(origin)

    def _avail(self, svc: str, ts: float) -> float:
        t = 0.0
        for t_mig, ready in self._stalls.get(svc, ()):
            if t_mig <= ts:
                t = max(t, ready)
        return t

    # ----------------------------------------------------------- resolution
    def _deps_settled(self, f: _OFire) -> bool:
        for u in self.topology[f.svc]:
            k = bisect.bisect_left(self._ts[u], f.ts)
            arr = self._fires[u]
            p = self._term[u]
            while p < len(arr) and arr[p].terminal:
                p += 1
            self._term[u] = p
            if p < k:
                return False
        return True

    def _result_arrival(self, g: _OFire, dst: str) -> float:
        src = g.site
        if src == dst or dst == SITE_DC:
            # same site, or the result ships with the DC consumer's
            # record uplink (edge upstream) / never left the DC
            return g.ready_out
        if src == SITE_DC:
            return g.ready_out + self._fleet.downlink_time(dst)
        if dst not in g.arrival_at:
            g.arrival_at[dst] = self._fleet.ship_result(src, dst, g.ready_out)
        return g.arrival_at[dst]

    def _dep_time(self, f: _OFire, dst: str) -> float:
        """Latest arrival (at ``dst``) of any settled upstream result.
        Incremental per (consumer, upstream, dst): the settled prefix of
        an upstream only grows as the consumer's fires advance in ts
        order, so each upstream fire is visited once per destination
        instead of rescanned per dispatch. ``_result_arrival`` caching
        keeps the FIFO-uplink side effects identical to a full rescan."""
        t = f.ts
        for u in self.topology[f.svc]:
            k = bisect.bisect_left(self._ts[u], f.ts)
            key = (f.svc, u, dst)
            ptr, mx = self._dep_ptr.get(key, (0, float("-inf")))
            arr = self._fires[u]
            while ptr < k:
                g = arr[ptr]
                if g.state == "done" and g.ready_out is not None:
                    a = self._result_arrival(g, dst)
                    if a > mx:
                        mx = a
                ptr += 1
            self._dep_ptr[key] = (ptr, mx)
            if mx > t:
                t = mx
        return t

    def _ship_inputs(self, f: _OFire, base: float) -> float:
        """Haul this fire's newly covered records that live on a
        different site than the fire executes on; DC-origin results
        arrive via the result hop instead (no re-ship)."""
        groups: Dict[str, int] = {}
        for o, c in f.origins.items():
            so = self._origin_site(f, o)
            if so == f.site or so == SITE_DC or c == 0:
                continue
            groups[so] = groups.get(so, 0) + c
        t = base
        for so in sorted(groups):
            t = max(t, self._fleet.ship_records(so, f.site, groups[so], base))
        return t

    def _make_task(self, f: _OFire, arrival: float) -> Task:
        p = self._plan_at(f.ts).placement(f.svc)
        prof = self.profiles[f.svc]
        shift = ((arrival - f.ts)
                 + self._fleet.downlink_time(self.cfg.fleet.result_site))
        steps = max(1, math.ceil(f.n_window / self.cfg.records_per_step))
        tt = TaskType(f"svc:{f.svc}", "window", allowable_chips=(p.chips,))
        task = Task(tid=self._next_tid, ttype=tt, steps=steps,
                    arrival=arrival, value=prof.slo.value_spec(shift),
                    hbm_bytes=self.cost.hbm_bytes(f"svc:{f.svc}", "window"))
        task.dvfs_hint = p.dvfs_f
        self._next_tid += 1
        return task

    def _dispatch(self, limit_ts: float) -> bool:
        """Dispatch every currently-dispatchable fire in global
        (ts, topo-rank) order — one at a time, so shared-uplink FIFO
        admissions happen in causal time order rather than per-service
        sweep order (a service must not reserve the pipe for a *future*
        haul ahead of another service's earlier transfer)."""
        progressed = False
        while True:
            best: Optional[_OFire] = None
            for svc in self.order:
                i = self._disp[svc]
                arr = self._fires[svc]
                if i >= len(arr):
                    continue
                f = arr[i]
                if f.ts >= limit_ts or f.epoch >= self._epochs_planned:
                    continue
                if not self._deps_settled(f):
                    continue
                if best is None or (f.ts, self.rank[f.svc]) < (best.ts,
                                                               self.rank[best.svc]):
                    best = f
            if best is None:
                return progressed
            f = best
            svc, i = f.svc, f.idx
            f.site = self._plan_at(f.ts).site(svc)
            base = max(self._dep_time(f, f.site), self._avail(svc, f.ts))
            in_ready = self._ship_inputs(f, base)
            if f.site == SITE_DC:
                task = self._make_task(f, in_ready)
                self._sim.inject(task)
                f.state = "inflight"
                self._waiting[(svc, i)] = task
                self._task_by_key[(svc, i)] = task
            else:
                f.start = in_ready
                f.state = "queued"
                heapq.heappush(self._equeue,
                               (in_ready, f.ts, self.rank[svc],
                                f.site, svc, i))
            self._disp[svc] = i + 1
            progressed = True

    def _next_fire_ts(self, limit_ts: float) -> Optional[float]:
        """Timestamp of the earliest not-yet-dispatched fire below
        ``limit_ts`` (dispatchable or not — its ts is still a time the
        cursor must visit)."""
        out: Optional[float] = None
        for svc in self.order:
            i = self._disp[svc]
            if i >= len(self._fires[svc]):
                continue
            ts = self._fires[svc][i].ts
            if ts < limit_ts and (out is None or ts < out):
                out = ts
        return out

    def _exec_edge_one(self, max_ready: float = float("inf")) -> bool:
        """Execute the queued edge fire with the smallest readiness, but
        only once the time cursor has reached it — executing a far-future
        fire early would occupy the serial device out of order."""
        if not self._equeue or self._equeue[0][0] > max_ready:
            return False
        in_ready, _, _, site, svc, i = heapq.heappop(self._equeue)
        f = self._fires[svc][i]
        prof = self.profiles[svc]
        ex = self._fleet.site(site).execute_fire(in_ready, f.n_window,
                                                 prof.flops_per_record)
        f.start, f.ready_out, f.energy_j = ex.start, ex.finish, ex.energy_j
        f.state = "done"
        return True

    def _collect_dc(self) -> bool:
        progressed = False
        for (svc, i), task in list(self._waiting.items()):
            f = self._fires[svc][i]
            if task.dropped:
                f.state, f.dropped = "failed", True
            elif (task.finish is not None
                  and task.finish <= self._sim.now + _EPS):
                f.state = "done"
                f.ready_out = task.finish
                # the completed aggregate surfaces at the user's site
                self._fleet.site(self.cfg.fleet.result_site).net.downlink(1)
            else:
                continue
            del self._waiting[(svc, i)]
            progressed = True
        return progressed

    def _starve_waiting(self) -> bool:
        """Event heap is empty and tasks are still pending: nothing will
        ever schedule them (no event retriggers the heuristic). Withdraw
        and classify exactly like a drained one-shot trace's tail."""
        if not self._waiting:
            return False
        now = self._sim.now
        progressed = False
        for (svc, i), task in list(self._waiting.items()):
            if not self._sim.withdraw(task):
                continue    # actually scheduled: its completion event
                # is still in flight, let the advance loop collect it
            progressed = True
            f = self._fires[svc][i]
            chips = task.ttype.allowable_chips[0]
            fh = getattr(task, "dvfs_hint", 1.0)
            dur = task.steps * self.cost.time_per_step(
                task.ttype.arch, task.ttype.shape, chips, fh)
            energy = task.steps * self.cost.energy_per_step(
                task.ttype.arch, task.ttype.shape, chips, fh)
            v = task_value(task.value, (now - task.arrival) + dur, energy)
            f.state = "failed"
            f.pending = v > 0          # horizon starvation, not decay
            f.dropped = not f.pending
            del self._waiting[(svc, i)]
        return progressed

    def _advance(self, t_from: float, t_to: float) -> None:
        """Co-advance the fire graph, the edge devices and the DES from
        ``t_from`` to ``t_to`` behind one global time cursor: fires
        dispatch when the cursor reaches their timestamp, queued edge
        fires execute when it reaches their readiness, DC completions
        collect as the event heap catches up. The cursor keeps shared-
        uplink FIFO admissions in causal time order — no transfer may
        reserve the pipe for a haul the simulation hasn't reached."""
        cursor = t_from
        while True:
            p = self._dispatch(limit_ts=cursor + _EPS)
            if self._exec_edge_one(max_ready=cursor + _EPS):
                p = True
            if self._collect_dc():
                p = True
            if p:
                continue
            ne = self._sim.next_event_time()
            if ne is not None and ne <= self._sim.now + _EPS:
                # late injections land at the current instant — process
                # them before deciding the clock is stuck
                self._sim.run_until(self._sim.now)
                continue
            nxt: List[float] = []
            nf = self._next_fire_ts(t_to)
            if nf is not None:
                nxt.append(nf)
            if self._equeue:
                nxt.append(self._equeue[0][0])
            if ne is not None:
                nxt.append(ne)
            # only strictly-future times can advance the cursor (a fire
            # at the cursor that didn't dispatch is blocked on something
            # later; its timestamp must not pin the loop)
            nxt = [t for t in nxt if cursor + _EPS < t <= t_to]
            if not nxt:
                return
            cursor = min(nxt)
            self._sim.run_until(cursor)

    # ------------------------------------------------------------ chaos path
    def _advance_epoch(self, controller, k: int, t0: float, t1: float,
                       charge: bool, rates_k: Dict[str, float]) -> List[Dict]:
        """Advance one epoch, cutting at realized fault boundaries so a
        chaos-aware controller (one exposing ``decide_fault``) can
        re-plan mid-epoch. The controller sees only the realized world at
        the cut (a :class:`FaultObservation`), never the fault schedule.
        Chaos-free runs — and controllers without ``decide_fault`` —
        take the single-segment path, bit-identical to the old loop."""
        react = (self._timeline is not None
                 and getattr(controller, "decide_fault", None) is not None)
        cuts = self._timeline.boundaries(t0, t1) if react else []
        log: List[Dict] = []
        cur = t0
        names = self.cfg.fleet.site_names
        for T in cuts:
            self._advance(cur, T)
            self._sim.run_until(T)
            self._collect_dc()
            cur = T
            fobs = FaultObservation(
                t=T, epoch=k,
                down_now={s: self._fleet.site(s).failed_at(T)
                          for s in names},
                partitioned_now={s: self._fleet.site(s).partitioned_at(T)
                                 for s in names},
                straggle_now={s: self._fleet.site(s).straggle_factor(T)
                              for s in names},
                events=self._timeline.events_at(T))
            plan = controller.decide_fault(fobs)
            if plan is not None:
                log.append(self._adopt_replan(plan, T, k, fobs, charge,
                                              rates_k))
        self._advance(cur, t1)
        return log

    def _adopt_replan(self, plan: PlacementPlan, T: float, k: int,
                      fobs: FaultObservation, charge: bool,
                      rates_k: Dict[str, float]) -> Dict:
        """Adopt an emergency mid-epoch plan at time ``T``: charge the
        checkpoint-aware live/cold migrations (never the raw-state
        epoch-boundary cost model) and key the plan by adoption time so
        only fires with ``ts >= T`` execute under it."""
        plan.validate(self.topology,
                      grid_chips=self.cfg.grid_shape[0]
                      * self.cfg.grid_shape[1],
                      sites=self.all_sites)
        bad = self._site_ram_ok(plan)
        if bad is not None:
            raise ValueError(f"epoch {k}: infeasible fault re-plan: {bad}")
        old = self._plans[-1]
        chaos = self.cfg.chaos
        ck = max(1, chaos.checkpoint_every)

        def _replay_records(svc: str) -> int:
            # fires the source covered since its newest checkpoint
            # (cadence: one save every `ck` fires)
            i_t = bisect.bisect_right(self._ts[svc], T)
            return sum(f.n_new
                       for f in self._fires[svc][(i_t // ck) * ck:i_t])

        def _replay_time(svc: str, n: int, dst: str) -> float:
            if dst == SITE_DC:
                p = plan.placement(svc)
                steps = max(1, math.ceil(n / self.cfg.records_per_step))
                return steps * self.cost.time_per_step(
                    f"svc:{svc}", "window", p.chips, p.dvfs_f)
            return self._fleet.site(dst).node.fire_time(
                n, self.profiles[svc].flops_per_record)

        def _drain(svc: str) -> float:
            src = old.site(svc)
            if src == SITE_DC:
                return 0.0
            return max(0.0, self._fleet.site(src).node.busy_until - T)

        def _src_dead(s: str) -> bool:
            if s == SITE_DC:
                return False
            site = self._fleet.site(s)
            return site.crashed_at(T) or site.partitioned_at(T)

        def _local_origin(svc: str, dst: str) -> bool:
            return (not self.topology[svc]
                    and self.cfg.fleet.farm_site(
                        self.services_info[svc].queue) == dst)

        def _ckpt_bytes(svc: str) -> float:
            return (self.services_info[svc].buffer_budget
                    * chaos.checkpoint_bytes_per_record)

        migs = plan_chaos_migrations(
            chaos, old.assignments, plan.assignments, T,
            src_dead=_src_dead, ship=self._fleet.ship_state,
            state_bytes=self._state_bytes, ckpt_bytes=_ckpt_bytes,
            replay_records=_replay_records, replay_time=_replay_time,
            rate_rps=lambda svc: rates_k.get(svc, 0.0),
            drain_s=_drain, dc_site=SITE_DC, local_origin=_local_origin,
            warmup_s=self.cfg.migration_warmup_s, charge=charge)
        for m in migs:
            if charge:
                self._stalls.setdefault(m.service, []).append(
                    (T, T + m.stall_s))
            if m.duplicates:
                self._duplicates[m.service] = (
                    self._duplicates.get(m.service, 0) + m.duplicates)
        self._plans.append(plan)
        self._plan_times.append(T)
        return {"t": round(T, 6), "plan": plan.label,
                "trigger": list(fobs.events),
                "migrations": [m.digest() for m in migs]}

    def _snap_link_secs(self) -> None:
        """Close the epoch's uplink telemetry window: mean serialization
        seconds per transfer at each site since the previous boundary
        (a straggling link surfaces here, and only here)."""
        out: Dict[str, float] = {}
        for s in self.cfg.fleet.site_names:
            site = self._fleet.site(s)
            b0, n0 = self._link_snap[s]
            db, dn = site.link_busy_s - b0, site.link_transfers - n0
            self._link_snap[s] = (site.link_busy_s, site.link_transfers)
            out[s] = db / dn if dn > 0 else 0.0
        self._link_secs.append(out)

    # ------------------------------------------------------- realized value
    def _settle_value(self, svc: str, f: _OFire) -> None:
        """Realized value + end-to-end latency of a terminal fire,
        computed once and cached on the fire (the per-epoch realized
        feedback and the final ``_score`` share the same numbers)."""
        if f.lat_s is not None or not f.terminal:
            return
        if f.state == "done" and f.site != SITE_DC:
            f.lat_s = f.ready_out - f.ts
            f.value = task_value(self._vspec[svc], f.lat_s, f.energy_j)
        elif f.state == "done":
            f.value = self._task_by_key[(svc, f.idx)].earned
            f.lat_s = f.ready_out + self._dl_user - f.ts
        else:
            f.lat_s = float("nan")      # dropped/starved: no latency sample

    def _epoch_residuals(self, epoch: int) -> Dict[str, Dict]:
        """Per-service realized residuals of one epoch as of the
        current simulation time: the VoS earned, the terminal fire
        counts (the per-service ledger residuals) and the mean realized
        latency. Fires still in flight count as ``inflight`` with no
        value realized."""
        out = {s: {"vos": 0.0, "completed": 0, "dropped": 0,
                   "inflight": 0, "lat_mean_s": float("nan"),
                   "_lat_sum": 0.0}
               for s in self.order}
        for svc, f in self._fires_by_epoch.get(epoch, ()):
            d = out[svc]
            self._settle_value(svc, f)
            if f.state == "done":
                d["completed"] += 1
                d["vos"] += f.value
                d["_lat_sum"] += f.lat_s
            elif f.dropped:
                d["dropped"] += 1
            else:
                d["inflight"] += 1
        for d in out.values():
            if d["completed"]:
                d["lat_mean_s"] = d["_lat_sum"] / d["completed"]
            del d["_lat_sum"]
            d["vos"] = round(d["vos"], 6)
        return out

    def _realized_upto(self, upto_epoch: int) -> List[Dict[str, Dict]]:
        """Frozen residual snapshots for every epoch < ``upto``. Each
        epoch is materialized exactly once, at the first boundary after
        it completes, and never rescanned: fires that straddle that
        boundary stay counted ``inflight`` in the snapshot (the
        calibration loop reads each epoch exactly once anyway, and
        freezing keeps the per-run cost at one pass over the fires
        instead of one pass per boundary)."""
        while len(self._realized) < upto_epoch:
            self._realized.append(self._epoch_residuals(len(self._realized)))
        return [{s: dict(d) for s, d in per.items()}
                for per in self._realized[:upto_epoch]]

    # ------------------------------------------------------------------ run
    def run(self, controller) -> EngineResult:
        """Co-simulate one plan schedule: ``controller.decide`` is asked
        for a plan at every epoch boundary (single-plan runs come in via
        :meth:`run_plan`). Raises ValueError on an infeasible plan."""
        pipe, staps, qtaps = self._ensure_driven()
        cfg = self.cfg
        self._timeline = (ChaosTimeline.compile(
            cfg.chaos, cfg.fleet.site_names, cfg.horizon_s, self.epochs)
            if cfg.chaos is not None else None)
        self._fleet = Fleet(cfg.fleet, self.outages, chaos=self._timeline)
        self._dl_user = self._fleet.downlink_time(cfg.fleet.result_site)
        self._vspec = {s: self.profiles[s].slo.value_spec()
                       for s in self.order}
        self._sim = Simulator(_fresh_heuristic(cfg.heuristic), self.cost,
                              power_cap_w=cfg.power_cap_w,
                              grid=PodGrid(*cfg.grid_shape))
        self._sim.begin()
        self._fires = {
            svc: [_OFire(svc=svc, idx=i, ts=fr.ts,
                         epoch=self._epoch_of(fr.ts), n_window=fr.n_window,
                         n_new=fr.n_new, origins=fr.origins)
                  for i, fr in enumerate(staps[svc].fires)]
            for svc in self.order}
        self._ts = {s: [f.ts for f in fl] for s, fl in self._fires.items()}
        self._fires_by_epoch: Dict[int, List[Tuple[str, _OFire]]] = {}
        for svc, fl in self._fires.items():
            for f in fl:
                self._fires_by_epoch.setdefault(f.epoch, []).append((svc, f))
        self._realized: List[Dict[str, Dict]] = []
        self._term = {s: 0 for s in self.order}
        self._disp = {s: 0 for s in self.order}
        self._equeue: List[Tuple] = []
        self._waiting: Dict[Tuple[str, int], Task] = {}
        self._task_by_key: Dict[Tuple[str, int], Task] = {}
        self._dep_ptr: Dict[Tuple[str, str, str], Tuple[int, float]] = {}
        self._stalls: Dict[str, List[Tuple[float, float]]] = {}
        self._plans: List[PlacementPlan] = []
        self._plan_times: List[float] = []      # adoption time of each plan
        self._epochs_planned = 0                # epoch-boundary decisions only
        self._duplicates: Dict[str, int] = {}   # at-least-once double passes
        self._link_secs: List[Dict[str, float]] = []
        self._link_snap = {s: (0.0, 0) for s in cfg.fleet.site_names}
        self._next_tid = 0
        true_rates = self.true_epoch_rates()
        charge = getattr(controller, "charge_migrations", True)
        bind = getattr(controller, "bind", None)
        if bind is not None:
            bind(self.info())

        epoch_meta: List[Dict] = []
        n_migs = 0
        rates_window: List[Dict[str, float]] = []
        for k, (t0, t1) in enumerate(self.epochs):
            obs = EpochObservation(
                epoch=k, t0=t0, t1=t1,
                rates_window=list(rates_window),
                realized_window=self._realized_upto(k),
                down_now={s: self._fleet.site(s).failed_at(t0)
                          for s in cfg.fleet.site_names},
                rates_oracle=dict(true_rates[k]),
                down_oracle={s: any(d < t1 and u > t0
                                    for d, u in self._fleet.site(s).outages)
                             for s in cfg.fleet.site_names},
                partitioned_now={s: self._fleet.site(s).partitioned_at(t0)
                                 for s in cfg.fleet.site_names},
                link_secs_window=[dict(d) for d in self._link_secs])
            plan = controller.decide(obs)
            plan.validate(self.topology,
                          grid_chips=cfg.grid_shape[0] * cfg.grid_shape[1],
                          sites=self.all_sites)
            bad = self._site_ram_ok(plan)
            if bad is not None:
                raise ValueError(f"epoch {k}: infeasible plan from "
                                 f"{type(controller).__name__}: {bad}")
            migs: List[ServiceMigration] = []
            if self._plans:
                def _xfer(src: str, dst: str, nbytes: float,
                          _t0: float = t0) -> float:
                    if not charge:
                        return 0.0
                    return self._fleet.ship_state(src, dst, nbytes, _t0) - _t0
                migs = plan_replacement(self._plans[-1].assignments,
                                        plan.assignments,
                                        self._state_bytes, _xfer,
                                        warmup_s=cfg.migration_warmup_s)
                if charge:
                    for m in migs:
                        self._stalls.setdefault(m.service, []).append(
                            (t0, t0 + m.stall_s))
            n_migs += len(migs)
            self._plans.append(plan)
            self._plan_times.append(t0)
            self._epochs_planned += 1

            chaos_log = self._advance_epoch(controller, k, t0, t1, charge,
                                            true_rates[k])
            self._sim.run_until(t1)
            self._collect_dc()
            self._snap_link_secs()
            rates_window.append(dict(true_rates[k]))
            meta = {
                "epoch": k, "t0": t0, "t1": t1, "plan": plan.label,
                "migrations": [
                    {"service": m.service, "src": m.src, "dst": m.dst,
                     "stall_s": round(m.stall_s, 3)} for m in migs],
            }
            if chaos_log:
                meta["chaos"] = chaos_log
                n_migs += sum(len(e["migrations"]) for e in chaos_log)
            # regret telemetry: controllers that score plans against a
            # forecast expose it per epoch; the realized per-epoch VoS
            # is merged in by _score once fires settle
            attach_forecast(controller, k, meta)
            epoch_meta.append(meta)

        # ---- final sweep: drain cross-epoch stragglers -------------------
        while True:
            self._advance(self.epochs[-1][1], float("inf"))
            if not self._starve_waiting():
                break
        self._sim.drain()
        self._collect_dc()      # safety: completions the loop never saw
        sim_result = self._sim.finalize()

        return self._score(pipe, staps, qtaps, sim_result, epoch_meta,
                           n_migs, controller)

    def run_plan(self, plan: PlacementPlan,
                 label: Optional[str] = None) -> CoSimResult:
        """One fixed plan for the whole horizon. Infeasible plans (site
        RAM) come back as a ``feasible=False`` result rather than
        raising — this is what the placement search scores."""
        plan.validate(self.topology,
                      grid_chips=self.cfg.grid_shape[0]
                      * self.cfg.grid_shape[1],
                      sites=self.all_sites)
        bad = self._site_ram_ok(plan)
        if bad is not None:
            return _infeasible(plan, bad)
        res = self.run(_FixedPlan(plan, label=label or plan.label))
        return CoSimResult(plan_label=label or plan.label, feasible=True,
                           **{k: getattr(res, k) for k in _SHARED_FIELDS})

    # -------------------------------------------------------------- scoring
    def _score(self, pipe, staps, qtaps, sim_result: SimResult,
               epoch_meta: List[Dict], n_migs: int,
               controller) -> EngineResult:
        cfg = self.cfg
        vos = max_vos = 0.0
        latencies: List[float] = []
        completed = dropped = inflight = 0
        ep_vos = [0.0] * len(self.epochs)
        per_service: Dict[str, Dict] = {}
        for svc in self.order:
            prof = self.profiles[svc]
            s_lat: List[float] = []
            s_done = s_drop = s_wait = 0
            for f in self._fires[svc]:
                max_vos += prof.slo.max_value
                self._settle_value(svc, f)
                if f.state == "done":
                    s_done += 1
                    s_lat.append(f.lat_s)
                elif f.dropped:
                    s_drop += 1
                else:
                    s_wait += 1
                ep_vos[f.epoch] += f.value
                vos += f.value
            completed += s_done
            dropped += s_drop
            inflight += s_wait
            latencies.extend(s_lat)
            s_vos = sum(f.value for f in self._fires[svc])
            per_service[svc] = {
                "site": self._plans[-1].placement(svc).label
                if self._plans else "",
                "fires": len(self._fires[svc]), "completed": s_done,
                "dropped": s_drop, "inflight": s_wait,
                "vos": round(s_vos, 4),
                "latency_p95": round(float(np.percentile(s_lat, 95)), 4)
                if s_lat else float("nan"),
            }
        merge_realized_vos(epoch_meta, ep_vos)

        ledger, per_site = self._ledger(pipe, staps, qtaps)
        lat = (np.asarray(latencies) if latencies
               else np.asarray([float("nan")]))
        p50, p95, p99 = np.percentile(lat, (50, 95, 99))
        return EngineResult(
            label=getattr(controller, "label", type(controller).__name__),
            vos=vos, vos_normalized=vos / max(max_vos, 1e-6),
            fires_total=sum(len(fl) for fl in self._fires.values()),
            fires_completed=completed, fires_dropped=dropped,
            fires_inflight=inflight,
            latency_p50=float(p50), latency_p95=float(p95),
            latency_p99=float(p99),
            edge_energy_j=self._fleet.edge_energy_j,
            network_energy_j=self._fleet.network_energy_j,
            dc_energy_j=sim_result.total_energy_j,
            bytes_up=self._fleet.bytes_up, bytes_down=self._fleet.bytes_down,
            uplink_wait_s=self._fleet.uplink_wait_s,
            uplink_transfers=self._fleet.uplink_transfers,
            migrations=n_migs, ledger=ledger, per_site=per_site,
            per_service=per_service, epochs=epoch_meta, dc=sim_result)

    def _ledger_skeleton(self) -> Dict[str, Dict]:
        """Plan-independent ledger fields (record identity partitions
        over the engine's one cached drive). Computed once and copied
        per run — a search over many plans used to redo the id()-set
        algebra on every evaluation."""
        if self._ledger_static is not None:
            return self._ledger_static
        pipe, staps, qtaps = self._ensure_driven()
        out: Dict[str, Dict] = {}
        for svc_obj in pipe.services:
            name = svc_obj.cfg.name
            tap, qtap = staps[name], qtaps[name]
            fetched_ids = set(qtap.fetched.get(name, {}))
            covered_ids = set(tap.covered)
            buf_ids = set(map(id, svc_obj.buffer))
            drop_ids = set(map(id, qtap.drop_refs))
            evicted_unc = fetched_ids - buf_ids - covered_ids
            out[name] = {
                "queue": svc_obj.cfg.queue,
                "produced": len(qtap.pub_refs),
                "overflow": len(drop_ids - fetched_ids),
                "unread": len(set(map(id, svc_obj.q.buf)) - fetched_ids),
                "fetched": len(fetched_ids),
                "buffered": len(buf_ids - covered_ids),
                ("evicted_stored" if svc_obj.cfg.store is not None
                 else "evicted_lost"): len(evicted_unc),
            }
        self._ledger_static = out
        return out

    def _ledger(self, pipe: Pipeline, staps, qtaps
                ) -> Tuple[RecordLedger, Dict[str, Dict]]:
        ledger = RecordLedger()
        site_processed: Dict[str, int] = {s: 0
                                          for s in self.cfg.fleet.site_names}
        site_processed[SITE_DC] = 0
        skeleton = self._ledger_skeleton()
        for svc_obj in pipe.services:
            name = svc_obj.cfg.name
            sl = ServiceLedger(service=name, **skeleton[name])
            sl.duplicates = self._duplicates.get(name, 0)
            for f in self._fires[name]:
                if f.state == "done" and f.site != SITE_DC:
                    sl.processed_edge += f.n_new
                    site_processed[f.site] += f.n_new
                elif f.state == "done":
                    sl.processed_dc += f.n_new
                    site_processed[SITE_DC] += f.n_new
                elif f.dropped:
                    sl.dropped_dc += f.n_new
                else:
                    sl.inflight_dc += f.n_new
            ledger.services[name] = sl
        per_site = self._fleet.per_site_energy()
        for s, n in site_processed.items():
            per_site.setdefault(s, {})["records_processed"] = n
        return ledger, per_site
