"""Calibrate ``flops_per_record`` from Pallas kernel dry-runs.

Scenario profiles used to *declare* per-service operator cost; this
module *measures* it: the service's operator kernel (``window_agg``,
``ssd_scan`` or ``flash_attention``) is dry-run in interpret mode on a
canonical shape derived from the service's window, and XLA's compiled
cost analysis reports the FLOP count, normalized per ingested record.
That number feeds the same roofline cost cells
(:func:`repro.scenario.engine.analytics_cost_model`) the DC simulator
prices VDC steps with — closing the ROADMAP item "learn per-service
flops_per_record from measured kernel dry-runs".

When XLA cannot cost the program (backend without cost analysis), a
documented analytic fallback keeps calibration deterministic and
dependency-free.

Usage::

    cal = KernelCalibrator()
    engine = spec.compile(calibrator=cal)      # measured profiles
    print(cal.report())                        # what was measured

``benchmarks/run.py --calibrate`` threads a calibrator through the
placement benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

_INTENSITY = {          # analytic flops/record fallbacks, by operator
    # one VPU op per element in the segment phase + m-way combine
    "window_agg": lambda m: 1.0 + 1.0 / 64.0 * m,
    # per timestep: state update (2·N·P) + readout (2·N·P) + decay
    "ssd_scan": lambda m: 4.0 * 16 * 64 + 16,
    # per query row: QK^T + PV at S=256, d=64 → 4·S·d
    "flash_attention": lambda m: 4.0 * 256 * 64,
}


@dataclasses.dataclass(frozen=True)
class Calibration:
    """One measured operator cost."""
    operator: str
    agg: str
    m: int                      # window/stride ratio the shape encoded
    n_records: int              # records the dry-run ingested
    flops_total: float
    flops_per_record: float
    source: str                 # "xla-cost-analysis" | "analytic"


def _cost_flops(jitted, *args) -> Optional[float]:
    """FLOPs of a compiled program via XLA cost analysis (None when the
    backend does not expose one). Tracing/lowering errors propagate —
    a kernel that cannot lower for the requested shape/agg is a real
    calibration bug, not a missing-cost-analysis backend."""
    lowered = jitted.lower(*args)
    try:
        ca = lowered.compile().cost_analysis()
    except Exception:
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    if not ca:
        return None
    flops = ca.get("flops")
    return float(flops) if flops and flops > 0 else None


class KernelCalibrator:
    """Measures (and caches) flops_per_record per operator family.

    Callable with a :class:`~repro.scenario.spec.ServiceSpec` so it can
    be passed straight to ``ScenarioSpec.compile(calibrator=...)``.
    ``interpret=True`` runs the Pallas kernels in interpreter mode —
    fine for cost analysis, which reads the lowered program, not the
    wall clock."""

    def __init__(self, interpret: bool = True, stride: int = 64):
        self.interpret = interpret
        self.stride = stride
        self._cache: Dict[Tuple[str, str, int], Calibration] = {}
        self.log: List[Calibration] = []

    # ------------------------------------------------------------ frontends
    def __call__(self, svc) -> float:
        m = max(1, min(8, round(svc.width_s / max(svc.slide_s, 1e-9))))
        return self.measure(svc.operator, agg=svc.agg, m=m).flops_per_record

    def measure(self, operator: str, agg: str = "max",
                m: int = 2) -> Calibration:
        agg = {"count": "sum"}.get(agg, agg)
        if operator not in _INTENSITY:
            raise ValueError(f"unknown operator {operator!r} "
                             f"(known: {sorted(_INTENSITY)})")
        key = (operator, agg if operator == "window_agg" else "-", m)
        if key not in self._cache:
            cal = self._measure(operator, agg, m)
            self._cache[key] = cal
            self.log.append(cal)
        return self._cache[key]

    def report(self) -> List[Dict]:
        return [dataclasses.asdict(c) for c in self.log]

    # ------------------------------------------------------------ dry-runs
    def _measure(self, operator: str, agg: str, m: int) -> Calibration:
        fn = getattr(self, f"_dry_{operator}")
        flops, n_records = fn(agg, m)
        if flops is None:
            fpr = _INTENSITY[operator](m)
            return Calibration(operator, agg, m, n_records,
                               flops_total=fpr * n_records,
                               flops_per_record=fpr, source="analytic")
        return Calibration(operator, agg, m, n_records, flops_total=flops,
                           flops_per_record=flops / n_records,
                           source="xla-cost-analysis")

    def _dry_window_agg(self, agg: str, m: int):
        import jax
        import jax.numpy as jnp
        from repro.kernels.window_agg.ops import window_aggregate

        stride = self.stride
        window = m * stride
        T = 4 * window
        x = jnp.ones((T, 1), jnp.float32)
        f = jax.jit(lambda a: window_aggregate(
            a, agg=agg, window=window, stride=stride,
            interpret=self.interpret))
        return _cost_flops(f, x), T

    def _dry_ssd_scan(self, agg: str, m: int):
        import jax
        import jax.numpy as jnp
        from repro.kernels.ssd_scan.ops import ssd_scan

        B, L, H, P, G, N = 1, 128, 2, 64, 1, 16
        x = jnp.ones((B, L, H, P), jnp.float32)
        dt = jnp.ones((B, L, H), jnp.float32) * 0.1
        A = -jnp.ones((H,), jnp.float32)
        Bq = jnp.ones((B, L, G, N), jnp.float32)
        Cq = jnp.ones((B, L, G, N), jnp.float32)
        f = jax.jit(lambda *a: ssd_scan(*a, chunk=64,
                                        interpret=self.interpret))
        return _cost_flops(f, x, dt, A, Bq, Cq), B * L

    def _dry_flash_attention(self, agg: str, m: int):
        import jax
        import jax.numpy as jnp
        from repro.kernels.flash_attention.ops import flash_attention

        B, S, H, d = 1, 256, 2, 64
        q = jnp.ones((B, S, H, d), jnp.float32)
        k = jnp.ones((B, S, H, d), jnp.float32)
        f = jax.jit(lambda q, k, v: flash_attention(
            q, k, v, interpret=self.interpret))
        return _cost_flops(f, q, k, k), B * S


def calibrate_profiles(spec, calibrator: Optional[KernelCalibrator] = None):
    """Measured :class:`ServiceProfile`s for every service of ``spec``
    (declared flops are ignored; SLO/bytes kept). Returns
    ``(profiles, calibrator)`` so callers can read the report."""
    from repro.scenario.profiles import ServiceProfile

    cal = calibrator or KernelCalibrator()
    profiles = {
        s.name: ServiceProfile(slo=s.slo, flops_per_record=cal(s),
                               bytes_per_record=s.bytes_per_record,
                               operator=s.operator)
        for s in spec.services}
    return profiles, cal
