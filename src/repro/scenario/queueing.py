"""Queueing-inflation knee shared by every analytic ranking tier.

One curve, three callers: the online controller's scalar
``ForecastModel``, the tier-1 vectorized ``ScreeningModel`` (numpy), and
the batched fluid ensemble engine (``repro.fluid``, jax). The knee says:
a work-conserving server fed deterministic slide-aligned arrivals is
stable below saturation, inflates mildly approaching it, and cliffs at
it (``NEVER_S`` — the backlog diverges and fires effectively never
complete).

The three variants are pinned bit-equal by ``tests/test_queueing.py``;
edit the shape here, nowhere else.
"""
from __future__ import annotations

import numpy as np

NEVER_S = 1e9
Q_KNEE = 0.7
Q_CLIFF = 0.95


def q_factor(u):
    """Queueing inflation factor for utilization ``u``. Polymorphic:
    a float returns a float, a numpy array maps elementwise."""
    if isinstance(u, np.ndarray):
        return q_factor_np(u)
    if u >= Q_CLIFF:
        return NEVER_S
    if u <= Q_KNEE:
        return 1.0
    return 1.0 + (u - Q_KNEE) / (Q_CLIFF - u)


def q_factor_np(u: np.ndarray) -> np.ndarray:
    """Vectorized :func:`q_factor` over a numpy array."""
    out = np.ones_like(u)
    mid = (u > Q_KNEE) & (u < Q_CLIFF)
    out[mid] = 1.0 + (u[mid] - Q_KNEE) / (Q_CLIFF - u[mid])
    out[u >= Q_CLIFF] = NEVER_S
    return out


def q_factor_jnp(u):
    """jax.numpy twin of :func:`q_factor` (same knee/cliff/NEVER
    semantics, safe under jit — the mid-branch denominator is guarded
    because ``jnp.where`` evaluates both sides)."""
    import jax.numpy as jnp
    mid = 1.0 + (u - Q_KNEE) / jnp.maximum(Q_CLIFF - u, 1e-12)
    return jnp.where(u >= Q_CLIFF, NEVER_S,
                     jnp.where(u <= Q_KNEE, 1.0, mid))
