"""Per-service SLO + operator-cost profiles (single source of truth).

A :class:`ServiceProfile` is what every co-simulation layer reads to
cost one fire of a service: the Fig. 3 SLO value curves, the operator
work per window value (``flops_per_record``), and the working-set bytes.
Profiles can be *declared* (scenario authors pick the numbers) or
*calibrated* from dry-runs of the repo's Pallas kernels
(:mod:`repro.scenario.calibrate`) — ``operator`` names which kernel
family models the service's OperatorLogic.

These classes used to live in ``repro.placement.cosim``; that module
re-exports them for backward compatibility.
"""
from __future__ import annotations

import dataclasses

from repro.core.value import TaskValueSpec, ValueCurve


@dataclasses.dataclass(frozen=True)
class ServiceSLO:
    """Fig. 3 value curves for one service's fires: full value while the
    end-to-end latency (energy) stays under the soft threshold, decaying
    to zero at the hard threshold."""
    soft_latency_s: float
    hard_latency_s: float
    soft_energy_j: float = 50.0
    hard_energy_j: float = 500.0
    gamma: float = 1.0
    w_p: float = 0.7
    shape: str = "linear"

    def value_spec(self, shift_s: float = 0.0) -> TaskValueSpec:
        """SLO as Eq. 1 parameters; `shift_s` moves the latency curve
        left by the delay already accumulated before DC execution starts,
        so a DC task's (finish − arrival) is scored on the *end-to-end*
        deadline. The shifted soft threshold may go negative: a task
        whose upstream+transfer delay already exceeded the soft deadline
        starts *inside* the decay ramp (clamping it to ~0 would re-spread
        the whole decay over the remaining budget and over-credit slow
        offloads)."""
        soft = self.soft_latency_s - shift_s
        hard = max(self.hard_latency_s - shift_s, soft)
        return TaskValueSpec(
            gamma=self.gamma, w_p=self.w_p, w_e=1.0 - self.w_p,
            perf_curve=ValueCurve(1.0, 0.1, soft, hard, self.shape),
            energy_curve=ValueCurve(1.0, 0.1, self.soft_energy_j,
                                    self.hard_energy_j, self.shape))

    @property
    def max_value(self) -> float:
        return self.gamma * 1.0  # w_p·v_max + w_e·v_max with v_max = 1


@dataclasses.dataclass(frozen=True)
class ServiceProfile:
    """What one fire of this service costs, plus its SLO. ``operator``
    names the Pallas kernel family whose dry-run can calibrate
    ``flops_per_record`` (see :mod:`repro.scenario.calibrate`)."""
    slo: ServiceSLO
    flops_per_record: float = 1e3    # operator work per window value
    bytes_per_record: float = 8.0    # working-set bytes per window value
    operator: str = "window_agg"     # window_agg | ssd_scan | flash_attention
