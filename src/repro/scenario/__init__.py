"""Unified Scenario API: one declarative spec → one DES-bridged engine.

The public surface of the co-simulation stack:

  spec.py       ScenarioSpec / scenario() builder — pipeline DAG,
                per-service profiles, fleet topology, drift schedule,
                SLO/value specs; JSON round-trip; ``compile()``
  engine.py     ScenarioEngine — the one co-simulation engine: every
                DC-placed fire submits incrementally into one
                persistent JITA-4DS Simulator (event-feed DES bridge);
                ``run_plan`` for static placements, ``run(controller)``
                for epoch-based re-placement
  screen.py     ScreeningModel — tier-1 vectorized batch plan scorer
                over the placement-independent fire trace (the fast
                path of ``repro.placement.search``)
  profiles.py   ServiceSLO / ServiceProfile — the single source of
                truth for operator cost
  calibrate.py  KernelCalibrator — measure flops_per_record from Pallas
                kernel dry-runs instead of declaring it
  observe.py    shared observation protocol — BridgeInfo /
                EpochObservation / ObservationSource, so the DES engine
                and the live serving runtime (``repro.serve``) are
                interchangeable controller drivers
  feedback.py   CalibrationLoop — closed-loop forecast calibration:
                RLS-fitted per-service correction terms from realized
                engine residuals, injected into ForecastModel and
                ScreeningModel ranking
  ledger.py     exact record-conservation accounting shared by all runs

Older entry points (``repro.placement.cosim.CoSimulator``,
``repro.online.des_bridge.FleetCoSimulator``) are thin shims over this
package.
"""
from repro.scenario.profiles import ServiceProfile, ServiceSLO
from repro.scenario.ledger import RecordLedger, ServiceLedger, FireRec
from repro.scenario.observe import (BridgeInfo, EpochObservation,
                                    ObservationSource, ServiceInfo,
                                    epoch_bounds, epoch_of)
from repro.scenario.engine import (CoSimResult, EngineConfig, EngineResult,
                                   ScenarioEngine, analytics_cost_model,
                                   single_site_fleet)
from repro.scenario.spec import (FarmSpec, RateSpec, ScenarioBuilder,
                                 ScenarioSpec, ServiceSpec, StoreSpec,
                                 scenario)
from repro.scenario.calibrate import (Calibration, KernelCalibrator,
                                      calibrate_profiles)
from repro.scenario.feedback import (CalibrationLoop, ServiceCalibration,
                                     ServiceCorrection)
from repro.scenario.screen import ScreeningModel, ScreenResult
