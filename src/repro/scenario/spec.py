"""Declarative scenario specification: one spec → one engine.

A :class:`ScenarioSpec` captures everything a co-simulation needs as
plain data — the pipeline DAG (farms + services + who publishes where),
per-service :class:`~repro.scenario.profiles.ServiceProfile`s, the edge
fleet topology, the drift schedule, outage windows, and the DC engine
knobs. ``compile()`` turns it into the unified
:class:`~repro.scenario.engine.ScenarioEngine`; the JITA-4DS framing
("pipelines are dynamically assembled and re-assembled from composable
building blocks") becomes literal: a scenario is a ~20-line declarative
value, not a ~100-line builder script.

Specs round-trip losslessly through JSON (``to_json``/``from_json``), so
benchmark scenarios can be bundled, diffed and re-targeted. Drift is
declared (:class:`RateSpec`), not closed over — which is what makes the
round-trip possible.

Build one directly, or fluently::

    spec = (scenario("light")
            .horizon(600.0)
            .farm(n_things=8, rate=RateSpec.constant(2.0))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=60)
            .slo(soft_latency_s=2.0, hard_latency_s=10.0)
            .service("smooth", queue="agg_out", column="value",
                     agg="mean", width_s=300, slide_s=60)
            .fed_by("agg")
            .build())
    engine = spec.compile()
    result = engine.run_plan(PlacementPlan.all_edge(spec.service_names()))
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

from repro import hardware as hw
from repro.chaos.spec import ChaosSpec
from repro.online import drift as _drift
from repro.online.fleet import FleetSpec, SiteSpec
from repro.pipeline.composition import Pipeline
from repro.pipeline.operators import WindowSpec
from repro.pipeline.service import ServiceConfig, StreamService
from repro.pipeline.store import TimeSeriesStore
from repro.pipeline.streams import Broker
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.plan import SITE_DC, SITE_EDGE
from repro.region.hier import HierFleetSpec, RegionSpec
from repro.scenario.engine import EngineConfig, ScenarioEngine
from repro.scenario.profiles import ServiceProfile, ServiceSLO


# ---------------------------------------------------------------------------
# Drift, declaratively
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RateSpec:
    """A declarative rate curve (JSON-safe stand-in for the closures in
    :mod:`repro.online.drift`). ``horizon_s`` of the enclosing scenario
    parameterizes kinds that need it (poisson_bursts, and diurnal/
    piecewise knots given as fractions would be overkill — absolute
    seconds are used throughout)."""
    kind: str = "constant"   # constant|diurnal|step_bursts|piecewise_linear|poisson_bursts
    base_hz: float = 1.0
    amplitude: float = 0.5
    period_s: float = 3600.0
    phase_s: float = 0.0
    burst_hz: float = 0.0
    windows: Tuple[Tuple[float, float], ...] = ()
    knots: Tuple[Tuple[float, float], ...] = ()
    mean_gap_s: float = 60.0
    mean_len_s: float = 30.0
    seed: int = 0

    @classmethod
    def constant(cls, rate_hz: float) -> "RateSpec":
        return cls(kind="constant", base_hz=rate_hz)

    @classmethod
    def diurnal(cls, base_hz: float, amplitude: float = 0.5,
                period_s: float = 3600.0, phase_s: float = 0.0) -> "RateSpec":
        return cls(kind="diurnal", base_hz=base_hz, amplitude=amplitude,
                   period_s=period_s, phase_s=phase_s)

    @classmethod
    def bursts(cls, base_hz: float, burst_hz: float,
               windows) -> "RateSpec":
        return cls(kind="step_bursts", base_hz=base_hz, burst_hz=burst_hz,
                   windows=tuple(tuple(w) for w in windows))

    @classmethod
    def piecewise(cls, knots) -> "RateSpec":
        return cls(kind="piecewise_linear",
                   knots=tuple(tuple(k) for k in knots))

    @classmethod
    def poisson(cls, base_hz: float, burst_hz: float, mean_gap_s: float,
                mean_len_s: float, seed: int = 0) -> "RateSpec":
        return cls(kind="poisson_bursts", base_hz=base_hz, burst_hz=burst_hz,
                   mean_gap_s=mean_gap_s, mean_len_s=mean_len_s, seed=seed)

    def curve(self, horizon_s: float) -> _drift.RateCurve:
        if self.kind == "constant":
            return _drift.constant(self.base_hz)
        if self.kind == "diurnal":
            return _drift.diurnal(self.base_hz, amplitude=self.amplitude,
                                  period_s=self.period_s,
                                  phase_s=self.phase_s)
        if self.kind == "step_bursts":
            return _drift.step_bursts(self.base_hz, self.burst_hz,
                                      list(self.windows))
        if self.kind == "piecewise_linear":
            return _drift.piecewise_linear(list(self.knots))
        if self.kind == "poisson_bursts":
            return _drift.poisson_bursts(self.base_hz, self.burst_hz,
                                         horizon_s,
                                         mean_gap_s=self.mean_gap_s,
                                         mean_len_s=self.mean_len_s,
                                         seed=self.seed)
        raise ValueError(f"unknown rate kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class FarmSpec:
    """One IoT producer farm on one queue."""
    queue: str = "neubotspeed"
    n_things: int = 8
    seed: int = 0
    rate: RateSpec = dataclasses.field(
        default_factory=lambda: RateSpec.constant(1.0))


@dataclasses.dataclass(frozen=True)
class StoreSpec:
    """Post-mortem history store attached to a service."""
    chunk_seconds: float = 3600.0
    edge_budget_chunks: int = 48


@dataclasses.dataclass(frozen=True)
class ServiceSpec:
    """One stream service: window shape, operator profile, SLO, and the
    optional queue its results republish into (the DAG edges).
    ``flops_per_record=None`` means "calibrate me" — ``compile()`` will
    refuse unless given a calibrator (see ``repro.scenario.calibrate``)."""
    name: str
    queue: str
    column: str = "value"
    agg: str = "mean"
    window_kind: str = "sliding"     # sliding | landmark
    width_s: float = 120.0
    slide_s: float = 60.0
    buffer_budget: int = 4096
    publishes_to: Optional[str] = None
    store: Optional[StoreSpec] = None
    slo: ServiceSLO = dataclasses.field(default_factory=lambda: ServiceSLO(
        soft_latency_s=2.0, hard_latency_s=10.0))
    flops_per_record: Optional[float] = 1e3
    bytes_per_record: float = 8.0
    operator: str = "window_agg"

    def profile(self) -> ServiceProfile:
        if self.flops_per_record is None:
            raise ValueError(
                f"service {self.name!r}: flops_per_record is None "
                "(declared-cost path); compile with a calibrator or set it")
        return ServiceProfile(slo=self.slo,
                              flops_per_record=self.flops_per_record,
                              bytes_per_record=self.bytes_per_record,
                              operator=self.operator)


# ---------------------------------------------------------------------------
# The scenario itself
# ---------------------------------------------------------------------------
_DEFAULT_SITES = (SiteSpec(SITE_EDGE, EdgeSpec()),)


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """The whole co-simulation, as data. See the module docstring."""
    name: str
    services: Tuple[ServiceSpec, ...] = ()
    farms: Tuple[FarmSpec, ...] = ()
    sites: Tuple[SiteSpec, ...] = _DEFAULT_SITES
    user_site: str = ""
    regions: Tuple[RegionSpec, ...] = ()   # () → flat single-uplink fleet
    horizon_s: float = 600.0
    epoch_s: Optional[float] = None     # None -> one epoch (static co-sim)
    drive_step_s: Optional[float] = None
    outages: Tuple[Tuple[str, Tuple[Tuple[float, float], ...]], ...] = ()
    heuristic: str = "hinted"
    power_cap_w: Optional[float] = None
    records_per_step: int = 5_000
    dc_step_floor_s: float = 1e-3
    mxu_efficiency: float = 0.5
    grid_shape: Tuple[int, int] = (hw.POD_X, hw.POD_Y)
    migration_warmup_s: Optional[float] = None
    state_bytes_per_record: float = 16.0
    # unplanned faults (crashes / partitions / straggling links) plus
    # the migration + ledger semantics applied under them; None keeps
    # every chaos code path dormant (bit-identical runs)
    chaos: Optional[ChaosSpec] = None

    # ------------------------------------------------------------- queries
    def service_names(self) -> List[str]:
        return [s.name for s in self.services]

    def topology(self) -> Dict[str, List[str]]:
        """Service DAG from the declared publishes_to edges."""
        topo: Dict[str, List[str]] = {}
        for s in self.services:
            topo[s.name] = [u.name for u in self.services
                            if u.publishes_to == s.queue]
        return topo

    def profiles(self) -> Dict[str, ServiceProfile]:
        return {s.name: s.profile() for s in self.services}

    def outage_map(self) -> Dict[str, Tuple[Tuple[float, float], ...]]:
        return {site: tuple(tuple(w) for w in wins)
                for site, wins in self.outages}

    # ---------------------------------------------------------- validation
    def validate(self) -> None:
        names = self.service_names()
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate service names: {names}")
        if not self.services:
            raise ValueError("a scenario needs at least one service")
        self.fleet_spec()   # site + region partition checks
        site_names = {s.name for s in self.sites}
        for site, _wins in self.outages:
            if site not in site_names:
                raise ValueError(f"outage for unknown site {site!r}")
        farm_queues = {f.queue for f in self.farms}
        if len(farm_queues) != len(self.farms):
            raise ValueError("one FarmSpec per queue (merge the things)")
        produced = farm_queues | {s.publishes_to for s in self.services
                                  if s.publishes_to}
        for s in self.services:
            if s.queue not in produced:
                raise ValueError(
                    f"service {s.name!r} consumes {s.queue!r} which no "
                    "farm or service publishes")
        for s in self.services:
            if s.publishes_to in farm_queues:
                raise ValueError(
                    f"service {s.name!r} republishes into farm queue "
                    f"{s.publishes_to!r}")
        for f in self.farms:
            if f.n_things < 1:
                raise ValueError(f"farm {f.queue!r}: n_things < 1")
        if self.chaos is not None:
            self.chaos.validate(sorted(site_names))

    def fleet_spec(self) -> FleetSpec:
        """The fleet topology: a :class:`HierFleetSpec` when regions
        are declared, the classic flat :class:`FleetSpec` otherwise
        (existing specs stay bit-identical)."""
        if self.regions:
            return HierFleetSpec(sites=self.sites, user_site=self.user_site,
                                 regions=self.regions)
        return FleetSpec(sites=self.sites, user_site=self.user_site)

    # ------------------------------------------------------------ assembly
    def build_pipeline(self) -> Pipeline:
        """One fresh functional pipeline (broker, farms, services,
        connections) — the engine calls this on every construction."""
        b = Broker()
        pipe = Pipeline(b)
        for f in self.farms:
            pipe.add_farm(_drift.DriftingFarm(
                b, f.rate.curve(self.horizon_s), queue=f.queue,
                n_things=f.n_things, seed=f.seed))
        by_name: Dict[str, StreamService] = {}
        for s in self.services:
            store = (TimeSeriesStore(
                f"{self.name}:{s.name}", chunk_seconds=s.store.chunk_seconds,
                edge_budget_chunks=s.store.edge_budget_chunks)
                if s.store is not None else None)
            svc = StreamService(ServiceConfig(
                name=s.name, queue=s.queue, column=s.column, agg=s.agg,
                window=WindowSpec(s.window_kind, s.width_s, s.slide_s),
                buffer_budget=s.buffer_budget, store=store), b)
            pipe.add_service(svc)
            by_name[s.name] = svc
        for s in self.services:
            if s.publishes_to:
                pipe.connect(by_name[s.name], s.publishes_to)
        return pipe

    def engine_config(self) -> EngineConfig:
        kw: Dict[str, Any] = {}
        if self.migration_warmup_s is not None:
            kw["migration_warmup_s"] = self.migration_warmup_s
        if self.chaos is not None:
            kw["chaos"] = self.chaos
        return EngineConfig(
            fleet=self.fleet_spec(),
            horizon_s=self.horizon_s, epoch_s=self.epoch_s,
            drive_step_s=self.drive_step_s, heuristic=self.heuristic,
            power_cap_w=self.power_cap_w,
            records_per_step=self.records_per_step,
            dc_step_floor_s=self.dc_step_floor_s,
            mxu_efficiency=self.mxu_efficiency, grid_shape=self.grid_shape,
            state_bytes_per_record=self.state_bytes_per_record, **kw)

    def compile(self, calibrator: Optional[Callable[["ServiceSpec"], float]]
                = None) -> ScenarioEngine:
        """Spec → unified engine. ``calibrator`` (e.g.
        ``KernelCalibrator.flops_per_record``) replaces every declared
        ``flops_per_record`` with a measured one; it is *required* when
        any service declares ``flops_per_record=None``."""
        self.validate()
        if calibrator is not None:
            from repro.scenario.calibrate import calibrate_profiles
            profiles, _ = calibrate_profiles(self, calibrator)
        else:
            profiles = self.profiles()
        return ScenarioEngine(self.build_pipeline, profiles,
                              self.engine_config(),
                              outages=self.outage_map())

    # ------------------------------------------------------------------ JSON
    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        # dataclasses.asdict already recursed; normalize tuples to lists
        return json.loads(json.dumps(d))

    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Mapping) -> "ScenarioSpec":
        def _tt(seq):   # list-of-pairs -> tuple-of-tuples
            return tuple(tuple(x) for x in seq)

        services = tuple(
            ServiceSpec(
                **{**s,
                   "store": StoreSpec(**s["store"]) if s.get("store") else None,
                   "slo": ServiceSLO(**s["slo"])})
            for s in d.get("services", ()))
        farms = tuple(
            FarmSpec(**{**f, "rate": RateSpec(
                **{**f["rate"], "windows": _tt(f["rate"]["windows"]),
                   "knots": _tt(f["rate"]["knots"])})})
            for f in d.get("farms", ()))
        sites = tuple(
            SiteSpec(name=s["name"], edge=EdgeSpec(**s["edge"]),
                     link=LinkSpec(**s["link"]),
                     farm_queues=tuple(s["farm_queues"]))
            for s in d.get("sites", ()))
        regions = tuple(
            RegionSpec(name=r["name"], sites=tuple(r["sites"]),
                       rap=LinkSpec(**r["rap"]))
            for r in d.get("regions", ()))
        return cls(
            name=d["name"], services=services, farms=farms,
            sites=sites or _DEFAULT_SITES,
            user_site=d.get("user_site", ""),
            regions=regions,
            horizon_s=d.get("horizon_s", 600.0),
            epoch_s=d.get("epoch_s"),
            drive_step_s=d.get("drive_step_s"),
            outages=tuple((site, _tt(wins))
                          for site, wins in d.get("outages", ())),
            heuristic=d.get("heuristic", "hinted"),
            power_cap_w=d.get("power_cap_w"),
            records_per_step=d.get("records_per_step", 5_000),
            dc_step_floor_s=d.get("dc_step_floor_s", 1e-3),
            mxu_efficiency=d.get("mxu_efficiency", 0.5),
            grid_shape=tuple(d.get("grid_shape", (hw.POD_X, hw.POD_Y))),
            migration_warmup_s=d.get("migration_warmup_s"),
            state_bytes_per_record=d.get("state_bytes_per_record", 16.0),
            chaos=(ChaosSpec.from_dict(d["chaos"])
                   if d.get("chaos") else None))

    @classmethod
    def from_json(cls, s: str) -> "ScenarioSpec":
        return cls.from_dict(json.loads(s))


# ---------------------------------------------------------------------------
# Fluent builder
# ---------------------------------------------------------------------------
class ScenarioBuilder:
    """Fluent construction front for :class:`ScenarioSpec`. Service-
    scoped modifiers (``slo``/``profile``/``fed_by``/``with_store``)
    apply to the most recently declared service."""

    def __init__(self, name: str):
        self._name = name
        self._services: List[ServiceSpec] = []
        self._farms: List[FarmSpec] = []
        self._sites: Dict[str, Dict] = {}
        self._kw: Dict[str, Any] = {}
        self._outages: Dict[str, List[Tuple[float, float]]] = {}
        self._user_site = ""
        self._regions: List[RegionSpec] = []

    # --------------------------------------------------------------- global
    def horizon(self, seconds: float) -> "ScenarioBuilder":
        self._kw["horizon_s"] = float(seconds)
        return self

    def epochs(self, epoch_s: float) -> "ScenarioBuilder":
        self._kw["epoch_s"] = float(epoch_s)
        return self

    def drive_step(self, step_s: float) -> "ScenarioBuilder":
        self._kw["drive_step_s"] = float(step_s)
        return self

    def dc(self, **kw) -> "ScenarioBuilder":
        """DC engine knobs: records_per_step, dc_step_floor_s,
        mxu_efficiency, grid_shape, heuristic, power_cap_w."""
        allowed = {"records_per_step", "dc_step_floor_s", "mxu_efficiency",
                   "grid_shape", "heuristic", "power_cap_w",
                   "migration_warmup_s", "state_bytes_per_record"}
        bad = set(kw) - allowed
        if bad:
            raise TypeError(f"unknown dc() options: {sorted(bad)}")
        self._kw.update(kw)
        return self

    # ---------------------------------------------------------------- sites
    def site(self, name: str, edge: Optional[EdgeSpec] = None,
             link: Optional[LinkSpec] = None,
             user: bool = False) -> "ScenarioBuilder":
        if name == SITE_DC:
            raise ValueError(f"{SITE_DC!r} is reserved for the data center")
        self._sites[name] = {"edge": edge or EdgeSpec(name=name),
                             "link": link or LinkSpec(),
                             "farm_queues": self._sites.get(
                                 name, {}).get("farm_queues", [])}
        if user:
            self._user_site = name
        return self

    def outage(self, site: str, down_s: float, up_s: float
               ) -> "ScenarioBuilder":
        self._outages.setdefault(site, []).append((down_s, up_s))
        return self

    def chaos(self, spec: Optional[ChaosSpec] = None, **kw
              ) -> "ScenarioBuilder":
        """Attach unplanned faults: a prebuilt :class:`ChaosSpec`, or
        its fields as keywords (``crashes=``, ``partitions=``,
        ``straggles=``, ``migration=``, ``ledger_mode=``, ...)."""
        if spec is not None and kw:
            raise ValueError("pass a ChaosSpec or fields, not both")
        self._kw["chaos"] = spec if spec is not None else ChaosSpec(**kw)
        return self

    def region(self, name: str, *sites: str,
               rap: Optional[LinkSpec] = None) -> "ScenarioBuilder":
        """Group ``sites`` into a region behind one RAP trunk
        (declaring any site not yet declared). Regions must partition
        the fleet exactly — ``build()`` validates."""
        for s in sites:
            if s not in self._sites:
                self.site(s)
        from repro.region.hier import DEFAULT_RAP
        self._regions.append(RegionSpec(
            name=name, sites=tuple(sites), rap=rap or DEFAULT_RAP))
        return self

    # ---------------------------------------------------------------- farms
    def farm(self, queue: str = "neubotspeed", n_things: int = 8,
             seed: int = 0, rate: Optional[RateSpec] = None,
             rate_hz: Optional[float] = None,
             site: Optional[str] = None) -> "ScenarioBuilder":
        if rate is not None and rate_hz is not None:
            raise ValueError("pass rate= or rate_hz=, not both")
        r = rate if rate is not None else RateSpec.constant(rate_hz or 1.0)
        self._farms.append(FarmSpec(queue=queue, n_things=n_things,
                                    seed=seed, rate=r))
        if site is not None:
            if site not in self._sites:
                self.site(site)
            self._sites[site]["farm_queues"].append(queue)
        return self

    # ------------------------------------------------------------- services
    def service(self, name: str, queue: str, column: str = "value",
                agg: str = "mean", width_s: float = 120.0,
                slide_s: float = 60.0, buffer_budget: int = 4096,
                window_kind: str = "sliding") -> "ScenarioBuilder":
        self._services.append(ServiceSpec(
            name=name, queue=queue, column=column, agg=agg,
            window_kind=window_kind, width_s=width_s, slide_s=slide_s,
            buffer_budget=buffer_budget))
        return self

    def _amend(self, **kw) -> "ScenarioBuilder":
        if not self._services:
            raise ValueError("declare a service first")
        self._services[-1] = dataclasses.replace(self._services[-1], **kw)
        return self

    def slo(self, **kw) -> "ScenarioBuilder":
        """SLO of the last service (ServiceSLO fields)."""
        return self._amend(slo=ServiceSLO(**kw))

    def profile(self, flops_per_record: Optional[float] = None,
                bytes_per_record: float = 8.0,
                operator: str = "window_agg") -> "ScenarioBuilder":
        """Operator cost of the last service. ``flops_per_record=None``
        defers to kernel calibration at compile time."""
        return self._amend(flops_per_record=flops_per_record,
                           bytes_per_record=bytes_per_record,
                           operator=operator)

    def fed_by(self, *upstreams: str) -> "ScenarioBuilder":
        """Declare that the last service's input queue is published by
        ``upstreams`` (sets their ``publishes_to``)."""
        if not self._services:
            raise ValueError("declare a service first")
        q = self._services[-1].queue
        for i, s in enumerate(self._services[:-1]):
            if s.name in upstreams:
                self._services[i] = dataclasses.replace(s, publishes_to=q)
        known = {s.name for s in self._services[:-1]}
        missing = set(upstreams) - known
        if missing:
            raise ValueError(f"fed_by unknown services: {sorted(missing)}")
        return self

    def with_store(self, chunk_seconds: float = 3600.0,
                   edge_budget_chunks: int = 48) -> "ScenarioBuilder":
        return self._amend(store=StoreSpec(chunk_seconds=chunk_seconds,
                                           edge_budget_chunks=edge_budget_chunks))

    # ------------------------------------------------------------------ build
    def build(self) -> ScenarioSpec:
        sites = (tuple(SiteSpec(name=n, edge=d["edge"], link=d["link"],
                                farm_queues=tuple(d["farm_queues"]))
                       for n, d in self._sites.items())
                 or _DEFAULT_SITES)
        spec = ScenarioSpec(
            name=self._name, services=tuple(self._services),
            farms=tuple(self._farms), sites=sites,
            user_site=self._user_site,
            regions=tuple(self._regions),
            outages=tuple((s, tuple(w)) for s, w in self._outages.items()),
            **self._kw)
        spec.validate()
        return spec


def scenario(name: str) -> ScenarioBuilder:
    """Entry point: ``scenario("my-workload")...build()``."""
    return ScenarioBuilder(name)
