"""Shared observation protocol: the DES engine and the live serving
runtime as interchangeable observation sources.

A *source* executes one scenario under a controller-produced plan
schedule and, at every epoch boundary, hands the controller one
:class:`EpochObservation`. Controllers are source-agnostic: the same
``bind(BridgeInfo)`` / ``decide(EpochObservation)`` contract drives both
the simulated world (:class:`~repro.scenario.engine.ScenarioEngine`,
where ``realized_window`` carries *co-simulated* residuals) and the real
one (:class:`~repro.serve.runtime.ServeRuntime`, where the same fields
carry *measured* residuals). The calibration loop
(:mod:`repro.scenario.feedback`) trains on either feed unchanged —
that is the sim-to-real closure the JITA-4DS follow-up describes.

These classes lived in ``repro.scenario.engine``; that module (and
``repro.online``) re-export them for backward compatibility. The epoch
arithmetic and the per-epoch telemetry merge are shared here so both
sources produce byte-compatible epoch records.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

try:                                     # py3.8+: typing.Protocol
    from typing import Protocol, runtime_checkable
except ImportError:                      # pragma: no cover
    Protocol, runtime_checkable = object, (lambda c: c)

from repro.core.costmodel import CostModel
from repro.online.fleet import FleetSpec
from repro.scenario.profiles import ServiceProfile

_EPS = 1e-9

#: keys every per-service ``realized_window`` entry carries — the
#: measurement schema :meth:`repro.scenario.feedback.CalibrationLoop.observe`
#: trains on (both sources must emit exactly these).
REALIZED_KEYS = ("vos", "completed", "dropped", "inflight", "lat_mean_s")


@dataclasses.dataclass(frozen=True)
class ServiceInfo:
    """Static per-service facts a controller may plan with."""
    queue: str
    slide_s: float
    width_s: float
    buffer_budget: int


@dataclasses.dataclass(frozen=True)
class BridgeInfo:
    """Snapshot handed to controllers at run start (``controller.bind``)."""
    topology: Dict[str, List[str]]
    profiles: Dict[str, ServiceProfile]
    fleet: FleetSpec
    services: Dict[str, ServiceInfo]
    cost: CostModel
    grid_chips: int
    epoch_s: float
    records_per_step: int
    outages: Dict[str, Tuple[Tuple[float, float], ...]]


@dataclasses.dataclass
class EpochObservation:
    """What a controller sees at an epoch boundary. ``*_oracle`` fields
    are ground truth about the *coming* epoch — only the clairvoyant
    baseline may read them; honest controllers plan from the observed
    past (``rates_window``) and the instantaneous site health. (A live
    runtime has no clairvoyance: its oracle fields fall back to the
    trailing measurement and the declared outage schedule.)

    ``realized_window`` is the source's realized per-service residual
    per *completed* epoch (oldest first): VoS earned so far, completed /
    dropped / still-inflight fire counts and the mean realized fire
    latency — the measurement a forecast-calibration loop
    (:mod:`repro.scenario.feedback`) trains on. Like ``rates_window``
    it is strictly about the past, so honest controllers may read it.
    Each epoch's snapshot is *frozen* at the first boundary after the
    epoch completes: fires still in flight there stay counted
    ``inflight`` (their value is simply never attributed — a conscious
    under-measurement that keeps the feed one-pass and deterministic)."""
    epoch: int
    t0: float
    t1: float
    rates_window: List[Dict[str, float]]      # per completed epoch, oldest first
    down_now: Dict[str, bool]
    rates_oracle: Dict[str, float]
    down_oracle: Dict[str, bool]
    realized_window: List[Dict[str, Dict]] = dataclasses.field(
        default_factory=list)
    # realized chaos telemetry (strictly about the past / the instant):
    # which sites' links are partitioned right now (device up, link
    # dead — distinct from down_now), and per completed epoch the mean
    # uplink serialization seconds per transfer at each site (a
    # straggling link shows up here, and only here)
    partitioned_now: Dict[str, bool] = dataclasses.field(
        default_factory=dict)
    link_secs_window: List[Dict[str, float]] = dataclasses.field(
        default_factory=list)

    @property
    def rates_prev(self) -> Optional[Dict[str, float]]:
        return self.rates_window[-1] if self.rates_window else None


@runtime_checkable
class ObservationSource(Protocol):
    """What it takes to drive a controller: both
    :class:`~repro.scenario.engine.ScenarioEngine` and
    :class:`~repro.serve.runtime.ServeRuntime` satisfy this."""

    def info(self) -> BridgeInfo:
        """The static planning snapshot ``controller.bind`` receives."""

    def run(self, controller):
        """Execute the scenario under ``controller``'s plan schedule and
        return an :class:`~repro.scenario.engine.EngineResult`."""


# ---------------------------------------------------------------------------
# Epoch arithmetic (one definition, two sources)
# ---------------------------------------------------------------------------
def epoch_bounds(horizon_s: float, epoch_s: Optional[float]
                 ) -> List[Tuple[float, float]]:
    """Epoch boundaries over the horizon; the last epoch absorbs any
    sub-epoch remainder (``epoch_s=None`` → one epoch)."""
    step = epoch_s or horizon_s
    bounds: List[Tuple[float, float]] = []
    t = 0.0
    while t < horizon_s - _EPS:
        t1 = min(t + step, horizon_s)
        if horizon_s - t1 < step * 0.5:
            t1 = horizon_s
        bounds.append((t, t1))
        t = t1
    return bounds


def epoch_of(bounds: Sequence[Tuple[float, float]], ts: float) -> int:
    """Index of the epoch containing ``ts`` (a fire exactly on a
    boundary belongs to the *later* epoch; past-horizon times clamp to
    the last)."""
    for k, (t0, t1) in enumerate(bounds):
        if ts < t1 or k == len(bounds) - 1:
            return k
    return len(bounds) - 1


# ---------------------------------------------------------------------------
# Per-epoch telemetry (byte-compatible between sources)
# ---------------------------------------------------------------------------
def attach_forecast(controller, epoch: int, meta: Dict) -> None:
    """Copy the controller's regret-telemetry entry for ``epoch`` into
    the epoch record, if the controller exposes one (controllers that
    score plans against a forecast append one per ``decide``)."""
    tel = getattr(controller, "telemetry", None)
    if tel and tel[-1].get("epoch") == epoch:
        meta["forecast"] = dict(tel[-1])


def merge_realized_vos(epoch_meta: List[Dict],
                       ep_vos: Sequence[float]) -> None:
    """Merge each epoch's realized VoS into its record and derive the
    calibration gap against the forecast the controller played.
    ``cosim_vos`` is the realized per-epoch VoS of the *source* — the
    co-simulated value under the engine, the measured value under the
    serve runtime (one key, so downstream consumers parse one schema)."""
    for k, meta in enumerate(epoch_meta):
        meta["vos"] = round(ep_vos[k], 4)
        fc = meta.get("forecast")
        if fc is not None and fc.get("chosen_vos") is not None:
            # calibration gap: what the forecast promised for the
            # played plan minus what the source realized this epoch
            fc["cosim_vos"] = round(ep_vos[k], 4)
            fc["calibration_gap"] = round(fc["chosen_vos"] - ep_vos[k], 4)
            if fc.get("chosen_vos_raw") is not None:
                # calibrated controllers also report the *raw*
                # (uncorrected) forecast of the played plan, so one
                # run carries its own calibrated-vs-raw comparison
                fc["calibration_gap_raw"] = round(
                    fc["chosen_vos_raw"] - ep_vos[k], 4)
