"""TPU v5e hardware model: roofline constants, pod geometry, DVFS/power model.

These constants parameterize (a) the roofline analysis of compiled dry-run
artifacts and (b) the JITA-4DS cost model (core/costmodel.py) that the VoS
scheduler uses to predict execution time and energy per VDC configuration.

All values are per-chip unless noted. Sources: public TPU v5e specs.
"""
from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------------------
# Per-chip roofline constants (TPU v5e)
# ---------------------------------------------------------------------------
PEAK_FLOPS_BF16 = 197e12       # FLOP/s, bf16 MXU peak
PEAK_FLOPS_INT8 = 394e12       # FLOP/s, int8
HBM_BW = 819e9                 # bytes/s
HBM_BYTES = 16 * 2**30         # 16 GiB HBM per chip
ICI_LINK_BW = 50e9             # bytes/s per ICI link (one direction)
ICI_LINKS_PER_CHIP = 4         # 2D torus on v5e: 4 links/chip
DCN_BW_PER_HOST = 25e9         # bytes/s inter-pod (data-center network)
VMEM_BYTES = 128 * 2**20       # ~128 MiB VMEM per chip (v5e class)

# Power model (modeled; the container has no power registers — see DESIGN §8)
CHIP_TDP_W = 200.0             # watts, per-chip board power at f=1.0
CHIP_STATIC_W = 60.0           # static/leakage floor, independent of DVFS
HOST_POWER_W = 350.0           # per-host (CPU, NIC, fans), amortized

# Pod geometry
POD_X, POD_Y = 16, 16
CHIPS_PER_POD = POD_X * POD_Y
CHIPS_PER_HOST = 4             # v5e: 4 chips per host VM


@dataclasses.dataclass(frozen=True)
class DVFSState:
    """A modeled DVFS operating point.

    ``f`` scales MXU/VPU clock: compute time ∝ 1/f. Dynamic power scales
    cubically with frequency (classic DVFS model); HBM/ICI are unscaled.
    This replaces the paper's RAPL power capping (DESIGN §2, §8).
    """
    f: float  # frequency factor in (0, 1]

    @property
    def power_w(self) -> float:
        dynamic = (CHIP_TDP_W - CHIP_STATIC_W) * self.f ** 3
        return CHIP_STATIC_W + dynamic

    def compute_scale(self) -> float:
        return 1.0 / self.f


# Discrete DVFS ladder available to the scheduler (JSPC picks per job,
# CPC picks one for the whole pod).
DVFS_LADDER = tuple(DVFSState(f) for f in (1.0, 0.9, 0.8, 0.7, 0.6, 0.5))
DVFS_NOMINAL = DVFS_LADDER[0]


def pod_power_cap_w(fraction: float, chips: int = CHIPS_PER_POD) -> float:
    """System power cap as a fraction of the all-chips-nominal envelope."""
    hosts = chips // CHIPS_PER_HOST
    envelope = chips * CHIP_TDP_W + hosts * HOST_POWER_W
    return fraction * envelope


def bisection_bandwidth(chips: int) -> float:
    """Approx bisection bandwidth (bytes/s) of a 2D-torus slice of `chips`."""
    # square-ish slice: side = sqrt(chips); 2 * side wraparound links per cut
    side = max(1, int(chips ** 0.5))
    return 2 * side * ICI_LINK_BW
