"""Logical-axis sharding rules and PartitionSpec builders.

Every parameter/cache leaf carries a tuple of *logical* axis names (one per
dim, None = never sharded). Profiles map logical names to mesh axes:

  train:  FSDP over "data" (embed axis of weights), TP over "model"
          (vocab/heads/mlp/experts/ssm_inner), DP over "pod"+"data" (batch)
  serve:  TP-only weights (no FSDP — decode would all-gather per token),
          batch over pod+data, KV cache per decode rules

The builder is divisibility-aware: a logical axis whose dim does not divide
its mesh axis is dropped (replicated) — this is what lets every assigned
arch (9-head smollm, kv=8 GQA on a 16-way model axis, odd vocabs) compile
on every mesh (DESIGN §4).
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Set the framework-level mesh context (consumed by moe_fwd's shard_map
    and act_constraint); does not touch jax's global mesh state."""
    token = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(token)


# ---------------------------------------------------------------------------
# Rule profiles: logical axis -> preferred mesh axes (first that divides wins)
# ---------------------------------------------------------------------------
TRAIN_RULES: Dict[str, Tuple] = {
    "embed": ("data",),            # FSDP / ZeRO-3 shard of the non-TP weight axis
    "vocab": ("model",),
    # input-embedding rows: vocab over model ONLY (no FSDP on the embed dim —
    # a gather from a 2-axis-sharded table forces SPMD full rematerialization)
    "vocab_in": ("model",),
    "embed_in": (None,),
    "heads": ("model",),
    "kv_heads": ("model", None),
    "head_dim": (None,),
    "mlp": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "layers": (None,),
    "conv": (None,),
}

SERVE_RULES: Dict[str, Tuple] = dict(TRAIN_RULES, embed=(None,))

# batch=1 long-context decode: the data axis carries no batch work, so
# weights spread over it too (memory; the all-gather rides an idle axis)
SERVE_LONG_RULES: Dict[str, Tuple] = dict(TRAIN_RULES, embed=("data",))

PROFILES = {"train": TRAIN_RULES, "serve": SERVE_RULES,
            "serve_long": SERVE_LONG_RULES}


def batch_axes_for(mesh: Mesh, batch: int):
    """Largest prefix of data-like axes that divides `batch`."""
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    chosen = []
    size = 1
    for a in axes:
        if batch % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[a] for a in name]))
    return mesh.shape[name]


def spec_for_leaf(mesh: Mesh, logical_axes, shape, rules) -> P:
    """Map one leaf's logical axes to a PartitionSpec, dropping non-dividers."""
    entries = []
    used = set()
    for dim, lax_name in zip(shape, logical_axes):
        choice = None
        if lax_name is not None:
            for cand in rules.get(lax_name, (None,)):
                if cand is None:
                    continue
                if cand in used:
                    continue
                if dim % _axis_size(mesh, cand) == 0:
                    choice = cand
                    break
        if choice is not None:
            used.add(choice)
        entries.append(choice)
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def build_param_specs(mesh: Mesh, axes_tree, shape_tree, profile: str):
    """axes_tree: pytree of tuples-of-logical-names (tuple leaves);
    shape_tree: matching pytree of ShapeDtypeStructs/arrays."""
    rules = PROFILES[profile]
    flat_axes, treedef = jax.tree.flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple) and (
            len(x) == 0 or x[0] is None or isinstance(x[0], str)))
    flat_shapes = treedef.flatten_up_to(shape_tree)
    specs = [spec_for_leaf(mesh, a, s.shape, rules)
             for a, s in zip(flat_axes, flat_shapes)]
    return jax.tree.unflatten(treedef, specs)


def shardings_from_specs(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def stack_axes(axes, extra: str = "layers"):
    """Prepend the stacked-layers logical axis to every leaf tuple."""
    return jax.tree.map(
        lambda t: (extra,) + t, axes,
        is_leaf=lambda x: isinstance(x, tuple) and (
            len(x) == 0 or x[0] is None or isinstance(x[0], str)))


# ---------------------------------------------------------------------------
# Activation / input / cache specs
# ---------------------------------------------------------------------------
def token_spec(mesh: Mesh, batch: int) -> P:
    return P(batch_axes_for(mesh, batch), None)


def constrain_batch(x, extra=()):
    """Constrain a [B, ...] activation to batch sharding (identity w/o mesh).
    `extra` optionally assigns trailing dims, e.g. ("model",) for logits."""
    mesh = current_mesh()
    if mesh is None:
        return x
    b_ax = batch_axes_for(mesh, x.shape[0])
    rest = [None] * (x.ndim - 1 - len(extra)) + list(extra)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(b_ax, *rest)))


def act_constraint(x, spec: P):
    """with_sharding_constraint when a mesh is active, else identity."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def kv_cache_spec(mesh: Mesh, batch: int, kv_heads: int, head_dim: int,
                  long_context: bool = False) -> P:
    """Spec for [layers, B, S, KV, dh] caches (decode rules, DESIGN §4).

    kv_heads → model when divisible; otherwise the sequence dim takes the
    model axis (flash-decoding-style split-KV). batch=1 long-context decode
    additionally spreads the sequence over the data axes.
    """
    b_ax = batch_axes_for(mesh, batch)
    m = mesh.shape.get("model", 1)
    if kv_heads % m == 0 and kv_heads >= m:
        kv_ax, seq_ax = "model", None
    else:
        kv_ax, seq_ax = None, "model"
    if b_ax is None:  # batch=1: shard sequence over data too
        data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        seq_ax = data_axes + ("model",) if seq_ax == "model" else data_axes
        if isinstance(seq_ax, tuple) and len(seq_ax) == 1:
            seq_ax = seq_ax[0]
    return P(None, b_ax, seq_ax, kv_ax, None)


def ssm_cache_specs(mesh: Mesh, batch: int, n_heads: int) -> Dict[str, P]:
    """Specs for {"conv": [layers,B,K-1,C], "h": [layers,B,H,P,N]}."""
    b_ax = batch_axes_for(mesh, batch)
    m = mesh.shape.get("model", 1)
    h_ax = "model" if n_heads % m == 0 else None
    c_ax = "model" if h_ax is None else None  # conv channels follow d_inner
    return {"conv": P(None, b_ax, None, "model"),
            "h": P(None, b_ax, h_ax, None, None)}
