"""Serving steps: prefill (context ingest → cache) and decode (one token)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import model as M


def make_prefill_step(cfg: ArchConfig, cache_len: int,
                      compute_dtype=jnp.bfloat16, q_chunk: int = 512):
    def prefill_step(params, batch: Dict[str, jax.Array]):
        return M.prefill(cfg, params, batch, cache_len,
                         compute_dtype=compute_dtype, q_chunk=q_chunk)
    return prefill_step


def make_decode_step(cfg: ArchConfig, compute_dtype=jnp.bfloat16):
    def decode_step(params, cache: Any, token: jax.Array, pos):
        return M.decode_step(cfg, params, cache, token, pos,
                             compute_dtype=compute_dtype)
    return decode_step


def greedy_generate(cfg: ArchConfig, params, batch, *, steps: int,
                    cache_len: int, compute_dtype=jnp.bfloat16):
    """Simple greedy loop used by examples/tests (jit-compatible)."""
    logits, cache = M.prefill(cfg, params, batch, cache_len,
                              compute_dtype=compute_dtype)
    B = logits.shape[0]
    tok0 = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    start = batch["tokens"].shape[1]

    def body(carry, i):
        tok, cache = carry
        logits, cache = M.decode_step(cfg, params, cache, tok, start + i,
                                      compute_dtype=compute_dtype)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, cache), nxt[:, 0]

    (_, cache), toks = jax.lax.scan(body, (tok0, cache), jnp.arange(steps))
    return jnp.concatenate([tok0, toks.T[:, :-1]], axis=1), cache
