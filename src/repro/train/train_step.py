"""The train step: bf16 compute, fp32 masters, remat, microbatch grad
accumulation (compute/comm overlap: each microbatch's gradient contribution
is produced while the next microbatch's forward is scheduled — XLA overlaps
the FSDP all-gathers/reduce-scatters with compute across scan iterations).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import model as M
from repro.optim import adamw_update, clip_by_global_norm, cosine_schedule
from repro.train.state import TrainState


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    grad_accum: int = 1          # microbatches per step
    remat: str = "full"          # none | dots | full
    q_chunk: int = 512
    compute_dtype: Any = jnp.bfloat16
    unroll: bool = False         # python loops instead of lax.scan (dry-run
                                 # cost variants: exact trip-count accounting)
    # gather FSDP-sharded weights ONCE per step (bf16, model-only sharding)
    # instead of per-layer per-microbatch: trades +weight-resident memory
    # for grad_accum× fewer all-gathers (the §Perf internvl hillclimb)
    gather_once: bool = False


def make_train_step(cfg: ArchConfig, hp: TrainHParams):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss(params, mb):
        if hp.gather_once:
            from repro import sharding as shd
            from repro.models.model import param_axes
            mesh = shd.current_mesh()
            if mesh is not None:
                specs = shd.build_param_specs(
                    mesh, param_axes(cfg), params, "serve")
                params = jax.tree.map(
                    lambda p, s: jax.lax.with_sharding_constraint(
                        p.astype(hp.compute_dtype),
                        jax.sharding.NamedSharding(mesh, s)),
                    params, specs,
                    is_leaf=lambda x: hasattr(x, "shape"))
        return M.loss_fn(cfg, params, mb, compute_dtype=hp.compute_dtype,
                         remat=hp.remat, q_chunk=hp.q_chunk,
                         unroll=hp.unroll)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if hp.grad_accum <= 1:
            (l, metrics), grads = grad_fn(state.params, batch)
        else:
            # split the global batch into microbatches along batch dim
            def reshape(x):
                b = x.shape[0]
                return x.reshape((hp.grad_accum, b // hp.grad_accum)
                                 + x.shape[1:])
            mbs = jax.tree.map(reshape, batch)

            def accum(carry, mb):
                (l, metrics), g = grad_fn(state.params, mb)
                gsum = jax.tree.map(jnp.add, carry, g)
                return gsum, (l, metrics)

            # seed the accumulator with microbatch 0's gradients so the scan
            # carry inherits the FSDP param sharding (a fresh jnp.zeros carry
            # has no sharding and XLA keeps it replicated)
            (l0, m0), g0 = grad_fn(state.params,
                                   jax.tree.map(lambda x: x[0], mbs))
            if hp.unroll:
                gsum, l, metrics = g0, l0, m0
                for i in range(1, hp.grad_accum):
                    mb = jax.tree.map(lambda x: x[i], mbs)
                    (li, mi), gi = grad_fn(state.params, mb)
                    gsum = jax.tree.map(jnp.add, gsum, gi)
                    l = l + li
                    metrics = jax.tree.map(jnp.add, metrics, mi)
                l = l / hp.grad_accum
                metrics = jax.tree.map(lambda x: x / hp.grad_accum, metrics)
            else:
                rest = jax.tree.map(lambda x: x[1:], mbs)
                gsum, (ls, ms) = jax.lax.scan(accum, g0, rest)
                l = (jnp.sum(ls) + l0) / hp.grad_accum
                metrics = jax.tree.map(lambda a, b: (jnp.sum(a) + b)
                                       / hp.grad_accum, ms, m0)
            grads = jax.tree.map(lambda g: g / hp.grad_accum, gsum)

        grads, gnorm = clip_by_global_norm(grads, hp.clip_norm)
        lr = cosine_schedule(state.step, hp.warmup_steps, hp.total_steps,
                             hp.peak_lr)
        params, opt = adamw_update(grads, state.opt, state.params, lr=lr,
                                   weight_decay=hp.weight_decay)
        new_state = TrainState(params=params, opt=opt, step=state.step + 1)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr, loss_total=l)
        return new_state, metrics

    return train_step
