from repro.train.state import TrainState, init_train_state
from repro.train.train_step import make_train_step, TrainHParams
from repro.train.serve_step import make_prefill_step, make_decode_step
