"""Train state pytree: fp32 master params + AdamW state + step counter."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim import AdamWState, adamw_init


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    step: jax.Array


def init_train_state(params) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))
