"""DEPRECATED location — the fleet co-simulator *is* the unified
scenario engine now (``repro.scenario.engine``).

The incremental event-feed DES bridge that debuted here (one persistent
JITA-4DS Simulator, ``inject``-as-produced, migration stalls via the
elastic cost model, per-service and per-site conservation ledgers) was
generalized to cover the single-site case as well and moved to
:mod:`repro.scenario.engine`; this module keeps the historical names
importable:

  ``FleetCoSimulator``  → :class:`repro.scenario.engine.ScenarioEngine`
  ``OnlineConfig``      → :class:`repro.scenario.engine.EngineConfig`
  ``OnlineResult``      → :class:`repro.scenario.engine.EngineResult`

The observation-protocol types (``BridgeInfo``, ``EpochObservation``,
``ServiceInfo``) are *not* deprecated — they moved to
:mod:`repro.scenario.observe` and stay importable from ``repro.online``
without touching this shim.

New code should build engines from a declarative
:class:`~repro.scenario.spec.ScenarioSpec` via ``spec.compile()`` (or
its live twin, :func:`repro.serve.serve_scenario`). Importing this
module emits a :class:`DeprecationWarning`; it will be removed in v0.9
(2026-12-01) — see README, Migration table.
"""
import warnings

from repro.scenario.engine import (EngineConfig, EngineResult,  # noqa: F401
                                   ScenarioEngine)
from repro.scenario.observe import (BridgeInfo, EpochObservation,  # noqa: F401
                                    ServiceInfo)

warnings.warn(
    "repro.online.des_bridge is deprecated and will be removed in v0.9 "
    "(2026-12-01): FleetCoSimulator/OnlineConfig/OnlineResult are "
    "repro.scenario's ScenarioEngine/EngineConfig/EngineResult; the "
    "observation types live in repro.scenario.observe (see README, "
    "Migration table)", DeprecationWarning, stacklevel=2)

FleetCoSimulator = ScenarioEngine
OnlineConfig = EngineConfig
OnlineResult = EngineResult
