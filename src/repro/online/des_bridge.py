"""DEPRECATED location — the fleet co-simulator *is* the unified
scenario engine now (``repro.scenario.engine``).

The incremental event-feed DES bridge that debuted here (one persistent
JITA-4DS Simulator, ``inject``-as-produced, migration stalls via the
elastic cost model, per-service and per-site conservation ledgers) was
generalized to cover the single-site case as well and moved to
:mod:`repro.scenario.engine`; this module keeps the historical names
importable:

  ``FleetCoSimulator``  → :class:`repro.scenario.engine.ScenarioEngine`
  ``OnlineConfig``      → :class:`repro.scenario.engine.EngineConfig`
  ``OnlineResult``      → :class:`repro.scenario.engine.EngineResult`

New code should build engines from a declarative
:class:`~repro.scenario.spec.ScenarioSpec` via ``spec.compile()``.
"""
from repro.scenario.engine import (BridgeInfo, EngineConfig,  # noqa: F401
                                   EngineResult, EpochObservation,
                                   ScenarioEngine, ServiceInfo)

FleetCoSimulator = ScenarioEngine
OnlineConfig = EngineConfig
OnlineResult = EngineResult
