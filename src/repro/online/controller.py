"""Epoch-based re-placement controllers.

At every epoch boundary the bridge asks a controller for the coming
epoch's placement plan. The online controller re-runs the *same*
``placement.search`` machinery the static engine uses — over a cheap
deterministic forecast model parameterized by a sliding estimate of the
observed record rates — then applies a switch margin so marginal wins
don't churn migrations. The oracle variant plans from ground-truth
next-epoch rates with free migrations: the per-epoch upper bound the
acceptance criteria compare against.

The forecast model is intentionally analytic (M/M/1-style queueing
inflation on saturated devices and the shared uplink, roofline DC
latency via the same cost cells the DES uses): it only needs to *rank*
plans; fidelity comes from the fleet co-simulation that replays the
chosen schedule.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.value import task_value
from repro.placement.edge import EdgeNode
from repro.placement.plan import SITE_DC, PlacementPlan
from repro.placement.search import Evaluator, search_placement
from repro.region.hier import regions_view
from repro.scenario.observe import BridgeInfo, EpochObservation
from repro.scenario.feedback import CalibrationLoop, ServiceCorrection
from repro.scenario.queueing import q_factor

# latency penalty standing in for "this transfer cannot complete": a
# partitioned link makes any plan that needs it rank to ~zero value
# while staying feasible (local-only work on the partitioned site is
# still worth doing — partition is not outage)
_LINK_DEAD_S = 1e7


@dataclasses.dataclass
class ForecastResult:
    """Duck-typed stand-in for CoSimResult: exactly what the search
    scorer reads."""
    vos: float
    feasible: bool
    plan_label: str = ""
    infeasible_reason: str = ""


class ForecastModel:
    """Analytic plan scorer over a rate estimate; plugs into
    ``placement.search`` (it quacks like a CoSimulator: ``.topology`` +
    ``.run(plan)``).

    ``corrections`` installs per-service calibration terms
    (:class:`~repro.scenario.feedback.ServiceCorrection`): the raw
    analytic latency is mapped through ``q_mult·lat + lat_bias_s`` and
    the resulting value scaled by ``1 − drop_offset`` before ranking —
    the closed half of the forecast-calibration loop. With no
    corrections the model is bit-identical to the uncalibrated one."""

    def __init__(self, info: BridgeInfo, rates: Mapping[str, float],
                 down: Optional[Mapping[str, bool]] = None,
                 corrections: Optional[Mapping[str, ServiceCorrection]]
                 = None,
                 link_slowdown: Optional[Mapping[str, float]] = None,
                 link_dead: Optional[Mapping[str, bool]] = None):
        self.info = info
        self.topology = info.topology
        self.rates = dict(rates)
        self.down = dict(down or {})
        self.corrections = dict(corrections or {})
        # chaos-telemetry steering (ChaosController): per-site uplink
        # serialization inflation and realized link partitions. Empty
        # maps are bit-identical to the un-steered model (×1.0, no
        # penalties).
        self.link_slowdown = dict(link_slowdown or {})
        self.link_dead = dict(link_dead or {})
        self._nodes = {s.name: EdgeNode(s.edge) for s in info.fleet.sites}
        # hierarchy: per-region edge tiers + RAP trunks (flat fleets are
        # one transparent region — every trunk term is zero and the
        # forecast stays bit-identical to the single-uplink model)
        regs = regions_view(info.fleet)
        self._region_of = {s: i for i, r in enumerate(regs)
                           for s in r.sites}
        self._rap = [None if r.transparent else r.rap for r in regs]
        self._hier = any(r is not None for r in self._rap)

    def _crosses(self, src: str, dst: str) -> bool:
        """True when a src→dst transfer transits the DC core (mirrors
        ``Fleet._crosses_core``)."""
        if src == SITE_DC or dst == SITE_DC:
            return True
        return self._region_of[src] != self._region_of[dst]

    def _slow(self, site: str) -> float:
        return self.link_slowdown.get(site, 1.0)

    def _dead(self, site: str) -> bool:
        return site != SITE_DC and bool(self.link_dead.get(site))

    # ------------------------------------------------------------- helpers
    def _n_window(self, svc: str) -> float:
        i = self.info.services[svc]
        return min(self.rates.get(svc, 0.0) * i.width_s,
                   float(i.buffer_budget))

    def _n_new(self, svc: str) -> float:
        i = self.info.services[svc]
        return self.rates.get(svc, 0.0) * i.slide_s

    def _dc_steps(self, svc: str) -> int:
        return max(1, int(self._n_window(svc)
                          // self.info.records_per_step) + 1)

    # ----------------------------------------------------------------- run
    def run(self, plan: PlacementPlan) -> ForecastResult:
        return self._eval(plan)[0]

    def predict(self, plan: PlacementPlan
                ) -> Tuple[ForecastResult, Dict[str, Dict]]:
        """Score plus per-service detail: the *raw* analytic latency
        (``lat_s`` — what a calibration loop regresses realized
        latencies against), the calibrated latency actually ranked with
        (``lat_cal_s``), and the per-epoch VoS contribution under both
        (``vos`` / ``vos_raw``)."""
        return self._eval(plan, want_detail=True)

    def _eval(self, plan: PlacementPlan, want_detail: bool = False
              ) -> Tuple[ForecastResult, Dict[str, Dict]]:
        info = self.info
        order = list(self.topology)
        sites = info.fleet.site_names
        try:
            plan.validate(self.topology, grid_chips=info.grid_chips,
                          sites=tuple(sites) + (SITE_DC,))
        except ValueError as e:
            return ForecastResult(float("-inf"), False, plan.label,
                                  str(e)), {}

        # group placements by site once — per-site passes below stay
        # O(services), not O(sites × services) (a 500-site fleet used to
        # pay the product on every plan evaluation)
        placed_by_site: Dict[str, List[str]] = {}
        for s in order:
            placed_by_site.setdefault(plan.site(s), []).append(s)

        # hard feasibility: down sites host nothing; RAM fits
        for name in sites:
            placed = placed_by_site.get(name)
            if not placed:
                continue
            if self.down.get(name):
                return ForecastResult(float("-inf"), False, plan.label,
                                      f"site {name} is down"), {}
            spec = info.fleet.site(name).edge
            budget = sum(info.services[s].buffer_budget for s in placed)
            if spec.ram_required(budget) > spec.ram_bytes:
                return ForecastResult(float("-inf"), False, plan.label,
                                      f"site {name}: RAM"), {}

        # device utilization per hosting site; per-region edge-tier and
        # RAP-trunk serialization load
        util: Dict[str, float] = {}
        for name, placed in placed_by_site.items():
            if name == SITE_DC:
                continue
            node = self._nodes[name]
            u = 0.0
            for s in placed:
                i = info.services[s]
                u += node.fire_time(int(self._n_window(s)),
                                    info.profiles[s].flops_per_record) \
                    / i.slide_s
            util[name] = u
        up_load = [0.0] * len(self._rap)
        rap_load = [0.0] * len(self._rap)
        for s in order:
            i = info.services[s]
            src = self._origin_site(s, plan)
            dst = plan.site(s)
            if src == dst or src == SITE_DC:
                continue
            net = info.fleet.site(src).link
            wire = self._n_new(s) * net.record_bytes * net.compression
            rj = self._region_of[src]
            up_load[rj] += wire / net.uplink_bps / i.slide_s \
                * self._slow(src)
            rap = self._rap[rj]
            if rap is not None and self._crosses(src, dst):
                rap_load[rj] += wire / rap.uplink_bps / i.slide_s
        q_up = [q_factor(x) for x in up_load]
        q_rap = [q_factor(x) for x in rap_load]

        # q_factor (repro.scenario.screen, shared with the vectorized
        # plan screen): deterministic slide-aligned arrivals — a work-
        # conserving server is stable below saturation, then the
        # backlog diverges; mild inflation approaching the cliff.

        # DC composition pressure: duty-cycle chip demand vs the grid
        demand = 0.0
        for s in order:
            p = plan.placement(s)
            if p.is_edge:
                continue
            t_step = info.cost.time_per_step(f"svc:{s}", "window",
                                             p.chips, p.dvfs_f)
            demand += p.chips * (self._dc_steps(s) * t_step
                                 / info.services[s].slide_s)
        dc_over = max(1.0, demand / info.grid_chips)

        # Serial-device rank blocking: services co-located on one site
        # fire at aligned slide boundaries and execute in topo-rank
        # order, so a light service queued behind a long fire eats the
        # long fire's latency — deterministically, not stochastically.
        rank = {s: i for i, s in enumerate(order)}
        fire_s: Dict[str, float] = {}
        for s in order:
            p = plan.placement(s)
            if p.is_edge:
                fire_s[s] = self._nodes[p.site].fire_time(
                    int(self._n_window(s)),
                    self.info.profiles[s].flops_per_record)

        def rank_wait(s: str) -> float:
            p = plan.placement(s)
            slide = info.services[s].slide_s
            w = 0.0
            for o in order:
                if o == s or plan.site(o) != p.site or rank[o] > rank[s]:
                    continue
                # collision probability of o's fires with s's boundaries
                align = min(1.0, slide / info.services[o].slide_s)
                w += align * fire_s[o]
            return w

        vos = 0.0
        user = info.fleet.result_site
        detail: Dict[str, Dict] = {}
        for s in order:
            i = info.services[s]
            prof = info.profiles[s]
            p = plan.placement(s)
            n_win, n_new = self._n_window(s), self._n_new(s)
            hop = self._upstream_hop_s(s, plan)
            if p.is_edge:
                node = self._nodes[p.site]
                base = fire_s[s]
                lat = (base + rank_wait(s)) * q_factor(util[p.site]) + hop
                lat += self._haul_s(s, plan, n_new, q_up, q_rap)
                # mirror EdgeNode.execute_fire: the ingest term covers
                # the whole window, not just the newly covered records
                energy = (n_win * node.spec.energy_per_record_j
                          + base * node.spec.active_power_w)
            else:
                src = self._origin_site(s, plan)
                xfer = 0.0
                if src != SITE_DC:
                    net = info.fleet.site(src).link
                    rj = self._region_of[src]
                    wire = n_new * net.record_bytes * net.compression
                    xfer = (net.rtt_s / 2
                            + wire / net.uplink_bps * q_up[rj]
                            * self._slow(src))
                    rap = self._rap[rj]
                    if rap is not None:   # edge→DC always transits the core
                        xfer += (rap.rtt_s / 2
                                 + wire / rap.uplink_bps * q_rap[rj])
                    if self._dead(src):   # records cannot leave the site
                        xfer += _LINK_DEAD_S
                t_step = info.cost.time_per_step(f"svc:{s}", "window",
                                                 p.chips, p.dvfs_f)
                dl = info.fleet.site(user).link.rtt_s / 2
                rap_u = self._rap[self._region_of[user]]
                if rap_u is not None:   # DC results ride the user trunk down
                    dl += (rap_u.rtt_s / 2
                           + info.fleet.site(user).link.result_bytes
                           / rap_u.downlink_bps)
                if self._dead(user):    # results cannot reach the user
                    dl += _LINK_DEAD_S
                lat = (hop + xfer + self._dc_steps(s) * t_step * dc_over
                       + dl)
                energy = self._dc_steps(s) * info.cost.energy_per_step(
                    f"svc:{s}", "window", p.chips, p.dvfs_f)
            corr = self.corrections.get(s)
            if corr is not None:
                corr = corr.tier(p.is_edge)
            lat_cal = corr.latency(lat) if corr is not None else lat
            vspec = prof.slo.value_spec()
            v = task_value(vspec, lat_cal, energy)
            if corr is not None:
                v *= corr.keep_prob
            fires = info.epoch_s / i.slide_s
            vos += v * fires
            if want_detail:
                v_raw = (v if corr is None
                         else task_value(vspec, lat, energy))
                detail[s] = {"lat_s": lat, "lat_cal_s": lat_cal,
                             "tier": "edge" if p.is_edge else "dc",
                             "vos": v * fires, "vos_raw": v_raw * fires}
        return ForecastResult(vos, True, plan.label), detail

    def _origin_site(self, svc: str, plan: PlacementPlan) -> str:
        """Dominant input-record origin: upstream's site if any upstream
        exists, else the farm site of the input queue."""
        ups = self.topology[svc]
        if ups:
            return plan.site(ups[0])
        return self.info.fleet.farm_site(self.info.services[svc].queue)

    def _upstream_hop_s(self, svc: str, plan: PlacementPlan) -> float:
        """Result-handoff latency from upstream cuts (cross-region cuts
        additionally ride the src RAP up and the dst RAP down)."""
        t = 0.0
        my = plan.site(svc)
        for u in self.topology[svc]:
            us = plan.site(u)
            if us == my or my == SITE_DC:
                continue
            if us == SITE_DC:
                h = self.info.fleet.site(my).link.rtt_s / 2
            else:
                h = (self.info.fleet.site(us).link.rtt_s / 2
                     + self.info.fleet.site(my).link.rtt_s / 2)
            if self._hier and self._crosses(us, my):
                if us != SITE_DC:
                    rap = self._rap[self._region_of[us]]
                    if rap is not None:
                        h += (rap.rtt_s / 2
                              + self.info.fleet.site(us).link.result_bytes
                              / rap.uplink_bps)
                rapd = self._rap[self._region_of[my]]
                if rapd is not None:
                    h += (rapd.rtt_s / 2
                          + self.info.fleet.site(my).link.result_bytes
                          / rapd.downlink_bps)
            if self._dead(us) or self._dead(my):
                h += _LINK_DEAD_S
            t = max(t, h)
        return t

    def _haul_s(self, svc: str, plan: PlacementPlan, n_new: float,
                q_up: Sequence[float], q_rap: Sequence[float]) -> float:
        """Cross-site raw-record haul onto an edge placement
        (cross-region: plus the src RAP trunk up, contended, and the dst
        RAP trunk down)."""
        src, dst = self._origin_site(svc, plan), plan.site(svc)
        if src == dst or src == SITE_DC:
            return 0.0
        if self._dead(src) or self._dead(dst):
            return _LINK_DEAD_S
        snet = self.info.fleet.site(src).link
        dnet = self.info.fleet.site(dst).link
        rj = self._region_of[src]
        wire = n_new * snet.record_bytes * snet.compression
        base = (snet.rtt_s / 2
                + wire / snet.uplink_bps * q_up[rj] * self._slow(src)
                + dnet.rtt_s / 2
                + n_new * dnet.record_bytes / dnet.downlink_bps)
        if not self._hier or not self._crosses(src, dst):
            return base
        extra = 0.0
        rap = self._rap[rj]
        if rap is not None:
            extra += rap.rtt_s / 2 + wire / rap.uplink_bps * q_rap[rj]
        rapd = self._rap[self._region_of[dst]]
        if rapd is not None:
            extra += (rapd.rtt_s / 2
                      + n_new * dnet.record_bytes / rapd.downlink_bps)
        return base + extra


# ---------------------------------------------------------------------------
# Controllers
# ---------------------------------------------------------------------------
class StaticController:
    """Plays one fixed plan for the whole horizon (the PR-1 world)."""
    charge_migrations = True

    def __init__(self, plan: PlacementPlan, label: str = "static"):
        self.plan = plan
        self.label = label

    def bind(self, info: BridgeInfo) -> None:
        self.info = info

    def decide(self, obs: EpochObservation) -> PlacementPlan:
        return self.plan


class OnlineController:
    """Sliding-estimate re-placement: search the plan space against the
    forecast model each epoch; switch (and pay migrations) only when the
    forecast win clears ``switch_margin``, or the live plan went
    infeasible (site failure / RAM).

    Every ``decide`` appends one regret-telemetry entry: the forecast
    VoS of the search's best plan, of the plan actually played
    (hysteresis may keep the incumbent), and their *signed* gap
    (``search_regret`` — negative when tie-breaking kept an incumbent
    the fresh search scored below). The engine merges the realized
    per-epoch co-sim VoS into the same record (``cosim_vos`` /
    ``calibration_gap``).

    ``calibrate=True`` closes the forecast-calibration loop: a
    :class:`~repro.scenario.feedback.CalibrationLoop` fits per-service
    correction terms (queueing-inflation multiplier, network-latency
    bias, drop-probability offset) by recursive least squares over the
    engine's realized residuals (``EpochObservation.realized_window``)
    paired with the raw forecasts this controller stored for the plans
    it played, and every subsequent epoch's plan search ranks with the
    corrected model. Telemetry then additionally records the raw
    (uncorrected) forecast of the played plan (``chosen_vos_raw`` — the
    engine derives ``calibration_gap_raw`` from it) and the corrections
    in force.

    ``risk`` switches plan *selection* from the single sliding-estimate
    forecast to a distributionally robust pick: each epoch the rate
    estimate is perturbed into a small lognormal ensemble of forecast
    models (deterministic per ``(seed, epoch)``), a candidate shortlist
    (the nominal search winner, the incumbent, and the anchor plans) is
    scored under every realization, and the plan with the best risk
    score (:class:`repro.fluid.robust.RiskSpec` — e.g. ``"cvar"``) is
    handed to the usual hysteresis gate. ``risk=None`` (default) is
    bit-identical to the single-trace controller. When calibration is
    also on, the ensemble's per-service VoS spread for the chosen plan
    is fed to ``CalibrationLoop.set_variance_prior`` so volatile
    services keep larger RLS gains."""
    charge_migrations = True
    label = "online"

    def __init__(self, chips_options: Sequence[int] = (4, 8),
                 dvfs_options: Sequence[float] = (1.0,),
                 window: int = 3, switch_margin: float = 0.05,
                 seed: int = 0,
                 prior_rates: Optional[Mapping[str, float]] = None,
                 calibrate: bool = False,
                 calibration: Optional[CalibrationLoop] = None,
                 risk=None, risk_ensemble: int = 16,
                 risk_rate_scale: float = 0.25):
        self.chips_options = tuple(chips_options)
        self.dvfs_options = tuple(dvfs_options)
        self.window = window
        self.switch_margin = switch_margin
        self.seed = seed
        self.prior_rates = dict(prior_rates) if prior_rates else None
        self.calibrate = calibrate or calibration is not None
        self.calibration = calibration
        self.risk = risk
        self.risk_ensemble = int(risk_ensemble)
        self.risk_rate_scale = float(risk_rate_scale)
        if self.calibrate:
            self.label = "online-cal"
        if self.risk is not None:
            self.label = self.label + "-risk"
        self.current: Optional[PlacementPlan] = None
        self.telemetry: List[Dict] = []

    def bind(self, info: BridgeInfo) -> None:
        self.info = info
        self.telemetry = []   # bind() marks a run start: drop stale entries
        self.current = None
        self._pred: Dict[int, Dict[str, Dict]] = {}
        self._observed_upto = 0
        # cross-epoch exact-score memo: one dict for the whole run,
        # namespaced per epoch by the forecast model's fingerprint (the
        # model changes whenever the rate estimate / outage set /
        # corrections move — a plan-only key would serve stale scores).
        # Steady epochs re-derive the same fingerprint and the search's
        # warm-start / anchor / finalist evaluations hit instead of
        # re-scoring.
        self._xcache: Dict = {}
        self._cum_hits = 0
        self._cum_misses = 0
        self._fp_seen: set = set()
        if self.calibrate:
            if self.calibration is None:
                self.calibration = CalibrationLoop(list(info.topology))
            else:
                self.calibration.reset()

    # ------------------------------------------------------------ estimate
    def _estimate(self, obs: EpochObservation) -> Dict[str, float]:
        win = obs.rates_window[-self.window:]
        if not win:
            if self.prior_rates is not None:
                return dict(self.prior_rates)
            return {s: 1.0 for s in self.info.topology}
        out: Dict[str, float] = {}
        for s in self.info.topology:
            out[s] = sum(w.get(s, 0.0) for w in win) / len(win)
        return out

    def _rates(self, obs: EpochObservation) -> Dict[str, float]:
        return self._estimate(obs)

    def _down(self, obs: EpochObservation) -> Dict[str, bool]:
        return obs.down_now

    def _make_model(self, rates: Mapping[str, float],
                    down: Mapping[str, bool], corr) -> ForecastModel:
        """Model-construction hook: chaos-aware subclasses inject
        telemetry-derived link state here. Subclasses that do MUST also
        extend ``_model_fingerprint`` with the same state, or the
        cross-epoch score memo serves stale scores."""
        return ForecastModel(self.info, rates, down, corrections=corr)

    def _model_fingerprint(self, rates: Mapping[str, float],
                           down: Mapping[str, bool],
                           corr) -> Tuple:
        """Hashable identity of this epoch's forecast model — the cache
        namespace for cross-epoch score reuse. Built from the *exact*
        parameter values (not the telemetry's rounded ``to_dict`` forms,
        which could alias two different models onto one namespace and
        serve a stale score)."""
        corr_fp: Tuple = ()
        if corr:
            corr_fp = tuple(sorted(
                (s, dataclasses.astuple(c) if dataclasses.is_dataclass(c)
                 else tuple(sorted(c.to_dict().items())))
                for s, c in corr.items()))
        return (tuple(sorted(rates.items())),
                # ForecastModel only reads truthiness of down entries
                tuple(sorted(k for k, v in down.items() if v)),
                corr_fp)

    # ---------------------------------------------------------- calibration
    def _absorb_residuals(self, obs: EpochObservation) -> None:
        """Feed each newly completed epoch's realized residuals (paired
        with the raw forecast stored when that epoch's plan was chosen)
        into the calibration loop — each epoch is observed exactly once,
        at the first boundary after it completes."""
        for e in range(self._observed_upto, len(obs.realized_window)):
            pred = self._pred.pop(e, None)
            if pred is not None:
                self.calibration.observe(e, pred, obs.realized_window[e])
        self._observed_upto = max(self._observed_upto,
                                  len(obs.realized_window))

    # -------------------------------------------------------------- robust
    def _risk_candidates(self, sr, edge_sites) -> List[PlacementPlan]:
        """Shortlist the ensemble re-scores: the nominal search winner
        first (stable-tie favorite), then the incumbent and the anchor
        plans."""
        names = list(self.info.topology)
        cands = [sr.plan]
        if self.current is not None:
            cands.append(self.current)
        for site in edge_sites:
            cands.append(PlacementPlan.all_edge(names, site=site))
        for c in self.chips_options:
            cands.append(PlacementPlan.all_dc(names, chips=c,
                                              dvfs_f=self.dvfs_options[0]))
        out: List[PlacementPlan] = []
        seen = set()
        for p in cands:
            k = p.key()
            if k not in seen:
                seen.add(k)
                out.append(p)
        return out

    def _robust_pick(self, rates, down, corr, sr, edge_sites,
                     epoch: int) -> Tuple[PlacementPlan, Dict]:
        """Risk-ranked plan selection over a lognormal rate-forecast
        ensemble (realization 0 is the nominal estimate); deterministic
        per ``(seed, epoch)``."""
        from repro.fluid.robust import RiskSpec, risk_score

        risk = RiskSpec.of(self.risk)
        rng = random.Random((self.seed + 1) * 1_000_003 + epoch * 7919)
        models = [ForecastModel(self.info, rates, down, corrections=corr)]
        for _ in range(self.risk_ensemble):
            pr = {s: r * math.exp(rng.gauss(0.0, self.risk_rate_scale))
                  for s, r in sorted(rates.items())}
            models.append(ForecastModel(self.info, pr, down,
                                        corrections=corr))
        cands = self._risk_candidates(sr, edge_sites)
        vos = [[m.run(p).vos for p in cands] for m in models]
        scores = risk_score(vos, risk)
        best_i = int(scores.argmax())   # first max: sr.plan wins ties
        best = cands[best_i]

        if self.calibration is not None:
            # ensemble spread of the chosen plan's per-service forecast
            # VoS -> RLS variance prior (volatile services keep learning)
            per: Dict[str, List[float]] = {}
            for m in models:
                _, det = m.predict(best)
                for s, d in det.items():
                    per.setdefault(s, []).append(d["vos"])
            prior: Dict[str, Dict[str, float]] = {}
            for s, vals in per.items():
                scale = max(1e-9, max(abs(v) for v in vals))
                mean = sum(vals) / len(vals)
                rel = (sum((v - mean) ** 2 for v in vals)
                       / len(vals)) ** 0.5 / scale
                is_edge = best.placement(s).is_edge
                prior[s] = {"edge": rel if is_edge else 0.0,
                            "dc": 0.0 if is_edge else rel}
            self.calibration.set_variance_prior(prior)

        info = {
            "metric": risk.label,
            "ensemble": len(models),
            "candidates": len(cands),
            "chosen": best.label,
            "nominal_best": sr.plan.label,
            "diverged": best.key() != sr.plan.key(),
            "scores": {p.label: (round(float(scores[i]), 4)
                                 if math.isfinite(float(scores[i]))
                                 else None)
                       for i, p in enumerate(cands)},
        }
        return best, info

    # -------------------------------------------------------------- decide
    def decide(self, obs: EpochObservation) -> PlacementPlan:
        rates, down = self._rates(obs), self._down(obs)
        corr = None
        if self.calibration is not None:
            self._absorb_residuals(obs)
            corr = self.calibration.corrections()
        model = self._make_model(rates, down, corr)
        up_sites = tuple(s for s in self.info.fleet.site_names
                         if not down.get(s))
        edge_sites = up_sites or self.info.fleet.site_names
        # on hierarchical fleets the front door routes to the decomposed
        # per-region search; the incumbent plan warm-starts it so steady
        # epochs cost a handful of model calls (ignored on flat fleets —
        # the joint search stays bit-identical)
        fp = self._model_fingerprint(rates, down, corr)
        model_reused = fp in self._fp_seen
        self._fp_seen.add(fp)
        if len(self._xcache) > 200_000:   # bound the run-long memo
            self._xcache.clear()
            self._fp_seen = {fp}
        ev = Evaluator(model, cache=self._xcache, key_prefix=fp)
        sr = search_placement(model, self.chips_options, self.dvfs_options,
                              seed=self.seed, edge_sites=edge_sites,
                              warm_start=self.current, evaluator=ev)
        self._cum_hits += sr.cache_hits
        self._cum_misses += sr.cache_misses
        best = sr.plan
        risk_entry = None
        if self.risk is not None:
            best, risk_entry = self._robust_pick(rates, down, corr, sr,
                                                 edge_sites, obs.epoch)
        new, new_detail = model.predict(best)
        switched = True
        if self.current is None:
            self.current, chosen, detail = best, new, new_detail
        else:
            cur, cur_detail = model.predict(self.current)
            must_switch = not cur.feasible
            margin_ok = (new.feasible and cur.feasible
                         and new.vos > cur.vos * (1.0 + self.switch_margin)
                         + 1e-9)
            if must_switch or margin_ok:
                self.current, chosen, detail = best, new, new_detail
            else:
                chosen, detail, switched = cur, cur_detail, False
        entry = {
            "epoch": obs.epoch,
            "best_vos": round(new.vos, 4) if new.feasible else None,
            "chosen_vos": round(chosen.vos, 4) if chosen.feasible else None,
            # signed: hysteresis/tie-break can keep an incumbent the
            # fresh search scores *below* (negative regret), which a
            # max(0, .) here used to silently discard
            "search_regret": round(new.vos - chosen.vos, 4)
            if new.feasible and chosen.feasible else None,
            "switched": switched,
            "search": {"method": sr.method, "evaluations": sr.evaluations,
                       "cache_hits": sr.cache_hits,
                       "cache_misses": sr.cache_misses,
                       # cross-epoch reuse: cumulative over the run's
                       # shared memo plus whether this epoch's model
                       # fingerprint repeated an earlier epoch's
                       "cum_cache_hits": self._cum_hits,
                       "cum_cache_misses": self._cum_misses,
                       "cache_plans": len(self._xcache),
                       "model_reused": model_reused},
        }
        if risk_entry is not None:
            entry["risk"] = risk_entry
        if self.calibration is not None:
            if chosen.feasible:
                # raw forecast detail of the played plan (reused from
                # the hysteresis evaluation): the pairing target for
                # this epoch's realized residuals, and the raw-arm
                # prediction the engine turns into calibration_gap_raw
                self._pred[obs.epoch] = detail
                entry["chosen_vos_raw"] = round(
                    sum(d["vos_raw"] for d in detail.values()), 4)
            entry["corrections"] = {
                s: c.to_dict() for s, c in corr.items()}
        self.telemetry.append(entry)
        return self.current


class OracleController(OnlineController):
    """Clairvoyant per-epoch baseline: plans from ground-truth coming-
    epoch rates and outage windows, switches freely, pays no migration —
    the upper bound the online controller is measured against."""
    charge_migrations = False
    label = "oracle"

    def __init__(self, chips_options: Sequence[int] = (4, 8),
                 dvfs_options: Sequence[float] = (1.0,), seed: int = 0):
        super().__init__(chips_options=chips_options,
                         dvfs_options=dvfs_options, window=1,
                         switch_margin=0.0, seed=seed)

    def _rates(self, obs: EpochObservation) -> Dict[str, float]:
        return dict(obs.rates_oracle)

    def _down(self, obs: EpochObservation) -> Dict[str, bool]:
        return dict(obs.down_oracle)


def plan_on_average_rates(info: BridgeInfo,
                          avg_rates: Mapping[str, float],
                          chips_options: Sequence[int] = (4, 8),
                          dvfs_options: Sequence[float] = (1.0,),
                          seed: int = 0) -> PlacementPlan:
    """The best *static* plan the forecast model can find for the
    whole-horizon average rates — the strongest honest static baseline."""
    model = ForecastModel(info, avg_rates, down=None)
    sr = search_placement(model, chips_options, dvfs_options, seed=seed,
                          edge_sites=info.fleet.site_names)
    return sr.plan
