"""Online fleet controller: multi-edge-site topologies with drift-driven
re-placement co-simulated through the DES loop.

The static placement engine (``repro.placement``) scores one plan for
one gateway. This subsystem makes re-assembly *online*, the way the
JITA4DS framing describes it:

  fleet.py       SiteSpec/FleetSpec/Fleet — several heterogeneous
                 gateways, per-site links, one FIFO-contended shared
                 uplink, site→site record routing
  drift.py       deterministic workload drift — diurnal tides, Poisson
                 bursts, site failure/recovery windows
  controller.py  epoch-based re-placement (reuses placement.search over
                 an analytic forecast), oracle + static baselines,
                 migration hysteresis
  des_bridge.py  FleetCoSimulator — incremental DC task submission into
                 one persistent JITA-4DS Simulator (no optimistic
                 handoff estimates), migration state shipped via the
                 elastic cost model, per-service *and* per-site record
                 conservation
"""
from repro.online.fleet import (ContendedUplink, EdgeSite, Fleet, FleetSpec,
                                SiteSpec)
from repro.online.drift import (DriftScenario, DriftingFarm,
                                DriftingProducer, constant, diurnal,
                                piecewise_linear, poisson_bursts,
                                step_bursts)
from repro.online.des_bridge import (BridgeInfo, EpochObservation,
                                     FleetCoSimulator, OnlineConfig,
                                     OnlineResult, ServiceInfo)
from repro.online.controller import (ForecastModel, ForecastResult,
                                     OnlineController, OracleController,
                                     StaticController,
                                     plan_on_average_rates)
