"""Online fleet controller: multi-edge-site topologies with drift-driven
re-placement co-simulated through the DES loop.

The static placement engine (``repro.placement``) scores one plan for
one gateway. This subsystem makes re-assembly *online*, the way the
JITA4DS framing describes it:

  fleet.py       SiteSpec/FleetSpec/Fleet — several heterogeneous
                 gateways, per-site links, one FIFO-contended shared
                 uplink, site→site record routing
  drift.py       deterministic workload drift — diurnal tides, Poisson
                 bursts, site failure/recovery windows
  controller.py  epoch-based re-placement (reuses placement.search over
                 an analytic forecast), oracle + static baselines,
                 migration hysteresis, per-epoch regret telemetry
  des_bridge.py  DEPRECATED shim — the incremental DES bridge is the
                 unified engine now (``repro.scenario.engine``);
                 ``FleetCoSimulator`` aliases ``ScenarioEngine`` and
                 importing the shim warns (removal: v0.9, 2026-12-01)

The bridge/controller names resolve lazily so the shim's import of
``repro.scenario`` cannot cycle back through this package's eager
imports. The observation-protocol types (``BridgeInfo``,
``EpochObservation``, ``ServiceInfo``) resolve straight from their new
home, :mod:`repro.scenario.observe`, so importing them here stays
warning-free; only the legacy engine aliases route through the shim.
"""
from repro.online.fleet import (ContendedUplink, EdgeSite, Fleet, FleetSpec,
                                SiteSpec)
from repro.online.drift import (DriftScenario, DriftingFarm,
                                DriftingProducer, constant, diurnal,
                                piecewise_linear, poisson_bursts,
                                step_bursts)

_OBSERVE_NAMES = ("BridgeInfo", "EpochObservation", "ServiceInfo")
_BRIDGE_NAMES = ("FleetCoSimulator", "OnlineConfig", "OnlineResult")
_CONTROLLER_NAMES = ("ForecastModel", "ForecastResult", "OnlineController",
                     "OracleController", "StaticController",
                     "plan_on_average_rates")

__all__ = ["ContendedUplink", "EdgeSite", "Fleet", "FleetSpec", "SiteSpec",
           "DriftScenario", "DriftingFarm", "DriftingProducer", "constant",
           "diurnal", "piecewise_linear", "poisson_bursts", "step_bursts",
           *_OBSERVE_NAMES, *_BRIDGE_NAMES, *_CONTROLLER_NAMES]


def __getattr__(name):
    if name in _OBSERVE_NAMES:
        from repro.scenario import observe
        return getattr(observe, name)
    if name in _BRIDGE_NAMES:
        from repro.online import des_bridge
        return getattr(des_bridge, name)
    if name in _CONTROLLER_NAMES:
        from repro.online import controller
        return getattr(controller, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
