"""Deterministic workload-drift generators.

The online controller exists because record rates *move*: diurnal tides,
flash-crowd bursts, and sites dropping out. Everything here is a pure
function of simulated time and a seed — two runs of the same scenario
produce bit-identical record streams, which the determinism acceptance
criterion (and the oracle baseline, which replays the same drive)
depends on.

Rate curves are callables ``t -> rate_hz`` composed per farm queue; the
:class:`DriftingFarm` advances producers whose inter-record gap tracks
the instantaneous curve. Site outages are plain ``(down, up)`` windows
consumed by :class:`~repro.online.fleet.EdgeSite`.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Sequence, Tuple

from repro.pipeline.streams import Broker, StreamProducer

RateCurve = Callable[[float], float]

_MIN_RATE_HZ = 1e-6


def _tag(curve: RateCurve, kind: str, **params) -> RateCurve:
    """Attach the declarative recipe to a curve closure so ensemble
    sampling (:meth:`DriftScenario.sample`) can perturb it structurally
    (re-seed a poisson process, shift a diurnal phase) instead of just
    scaling the opaque callable."""
    curve.drift_kind = kind          # type: ignore[attr-defined]
    curve.drift_params = params      # type: ignore[attr-defined]
    return curve


def constant(rate_hz: float) -> RateCurve:
    return _tag(lambda t: rate_hz, "constant", rate_hz=rate_hz)


def diurnal(base_hz: float, amplitude: float = 0.5,
            period_s: float = 3600.0, phase_s: float = 0.0) -> RateCurve:
    """Sinusoidal tide around ``base_hz``: rate(t) = base·(1 + a·sin).
    ``amplitude`` in [0, 1) keeps the rate strictly positive."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError("amplitude must be in [0, 1)")

    def curve(t: float) -> float:
        return base_hz * (1.0 + amplitude
                          * math.sin(2 * math.pi * (t - phase_s) / period_s))
    return _tag(curve, "diurnal", base_hz=base_hz, amplitude=amplitude,
                period_s=period_s, phase_s=phase_s)


def step_bursts(base_hz: float, burst_hz: float,
                windows: Sequence[Tuple[float, float]]) -> RateCurve:
    """Explicit burst windows: ``burst_hz`` inside, ``base_hz`` outside."""
    wins = sorted(windows)

    def curve(t: float) -> float:
        for t0, t1 in wins:
            if t0 <= t < t1:
                return burst_hz
        return base_hz
    return _tag(curve, "step_bursts", base_hz=base_hz, burst_hz=burst_hz,
                windows=tuple(wins))


def piecewise_linear(points: Sequence[Tuple[float, float]]) -> RateCurve:
    """Linear interpolation through (t, rate) knots — ramps, trapezoid
    bursts, any hand-drawn drift shape. Clamps outside the knot range."""
    pts = sorted(points)
    if len(pts) < 2:
        raise ValueError("need at least two (t, rate) points")

    def curve(t: float) -> float:
        if t <= pts[0][0]:
            return pts[0][1]
        for (t0, r0), (t1, r1) in zip(pts, pts[1:]):
            if t <= t1:
                frac = (t - t0) / max(t1 - t0, 1e-12)
                return r0 + frac * (r1 - r0)
        return pts[-1][1]
    return _tag(curve, "piecewise_linear", points=tuple(pts))


def poisson_bursts(base_hz: float, burst_hz: float, horizon_s: float,
                   mean_gap_s: float, mean_len_s: float,
                   seed: int = 0) -> RateCurve:
    """Bursts whose starts form a (seeded, hence deterministic) Poisson
    process with mean gap ``mean_gap_s`` and exponential lengths."""
    rng = random.Random(seed * 6271 + 17)
    wins: List[Tuple[float, float]] = []
    t = rng.expovariate(1.0 / mean_gap_s)
    while t < horizon_s:
        length = rng.expovariate(1.0 / mean_len_s)
        wins.append((t, min(t + length, horizon_s)))
        t += length + rng.expovariate(1.0 / mean_gap_s)
    return _tag(step_bursts(base_hz, burst_hz, wins), "poisson_bursts",
                base_hz=base_hz, burst_hz=burst_hz, horizon_s=horizon_s,
                mean_gap_s=mean_gap_s, mean_len_s=mean_len_s, seed=seed)


def _lognorm(rng: random.Random, sigma: float) -> float:
    return math.exp(rng.gauss(0.0, sigma))


def perturb_curve(curve: RateCurve, rng: random.Random,
                  rate_scale: float = 0.15) -> RateCurve:
    """One perturbed realization of a rate curve: structural jitter for
    tagged curves (the factories above), a plain lognormal amplitude
    scale for opaque callables. Deterministic in ``rng``'s state."""
    kind = getattr(curve, "drift_kind", None)
    p = dict(getattr(curve, "drift_params", {}) or {})
    if kind == "constant":
        return constant(p["rate_hz"] * _lognorm(rng, rate_scale))
    if kind == "diurnal":
        return diurnal(
            p["base_hz"] * _lognorm(rng, rate_scale),
            amplitude=min(0.95, p["amplitude"] * _lognorm(rng, rate_scale)),
            period_s=p["period_s"],
            phase_s=p["phase_s"] + rng.gauss(0.0, p["period_s"] / 12.0))
    if kind == "step_bursts":
        wins = []
        for t0, t1 in p["windows"]:
            length = max(1e-9, (t1 - t0) * _lognorm(rng, rate_scale))
            start = max(0.0, t0 + rng.gauss(0.0, 0.1 * (t1 - t0)))
            wins.append((start, start + length))
        return step_bursts(p["base_hz"] * _lognorm(rng, rate_scale),
                           p["burst_hz"] * _lognorm(rng, rate_scale), wins)
    if kind == "piecewise_linear":
        return piecewise_linear(
            [(t, r * _lognorm(rng, rate_scale)) for t, r in p["points"]])
    if kind == "poisson_bursts":
        return poisson_bursts(
            p["base_hz"] * _lognorm(rng, rate_scale),
            p["burst_hz"] * _lognorm(rng, rate_scale),
            p["horizon_s"], p["mean_gap_s"], p["mean_len_s"],
            seed=rng.randrange(2 ** 31))   # resampled arrival process
    factor = _lognorm(rng, rate_scale)
    return _tag(lambda t: factor * curve(t), "scaled", factor=factor)


def perturb_outages(outages, rng: random.Random,
                    onset_scale: float = 0.1):
    """Jitter each outage window's onset (duration preserved, onsets
    clamped at 0) — the outage-noise half of ensemble sampling."""
    out = {}
    for site, wins in outages.items():
        jittered = []
        for d, u in wins:
            length = u - d
            start = max(0.0, d + rng.gauss(0.0, onset_scale * max(length,
                                                                  1e-9)))
            jittered.append((start, start + length))
        out[site] = tuple(sorted(jittered))
    return out


class DriftingProducer(StreamProducer):
    """One 'thing' whose inter-record gap tracks a rate curve. Record
    payloads reuse the Neubot-shaped schema of the base producer."""

    def __init__(self, broker: Broker, queue: str, thing_id: int,
                 curve: RateCurve, seed: int = 0):
        super().__init__(broker, queue, thing_id, rate_hz=1.0, seed=seed)
        self.curve = curve

    def advance_to(self, ts: float) -> int:
        n = 0
        while self._next_t <= ts:
            self.q.publish(self._record(self._next_t))
            rate = max(self.curve(self._next_t), _MIN_RATE_HZ)
            self._next_t += 1.0 / rate
            n += 1
        return n


class DriftingFarm:
    """An IoT farm of drift-modulated producers on one queue (the
    per-thing curve is the farm curve: the *aggregate* queue rate is
    ``n_things × curve(t)``)."""

    def __init__(self, broker: Broker, curve: RateCurve,
                 queue: str = "neubotspeed", n_things: int = 8,
                 seed: int = 0):
        self.producers = [DriftingProducer(broker, queue, i, curve, seed)
                          for i in range(n_things)]

    def advance_to(self, ts: float) -> int:
        return sum(p.advance_to(ts) for p in self.producers)


@dataclasses.dataclass(frozen=True)
class DriftScenario:
    """A named drift shape: per-queue rate curves plus site outage
    windows, applied on top of a fleet/pipeline scenario."""
    name: str
    curves: Dict[str, RateCurve] = dataclasses.field(default_factory=dict)
    outages: Dict[str, Tuple[Tuple[float, float], ...]] = \
        dataclasses.field(default_factory=dict)

    def curve(self, queue: str, default_hz: float = 1.0) -> RateCurve:
        return self.curves.get(queue, constant(default_hz))

    def sample(self, rng, n: int,
               rate_scale: float = 0.15,
               onset_scale: float = 0.1) -> Tuple["DriftScenario", ...]:
        """``n`` perturbed realizations of this drift shape — the
        ensemble source for the fluid engine. ``rng`` is a seed int or a
        ``random.Random``; the same seed yields bit-identical
        realizations (curves and outages alike). Jitter is structural
        where the curve recipe is known: diurnal phase/amplitude,
        burst-window onsets/lengths, re-seeded poisson arrival
        processes, per-knot piecewise rates."""
        if not isinstance(rng, random.Random):
            rng = random.Random(rng)
        reals = []
        for k in range(n):
            curves = {q: perturb_curve(c, rng, rate_scale)
                      for q, c in sorted(self.curves.items())}
            outages = perturb_outages(self.outages, rng, onset_scale)
            reals.append(dataclasses.replace(
                self, name=f"{self.name}#{k}", curves=curves,
                outages=outages))
        return tuple(reals)
