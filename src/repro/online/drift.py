"""Deterministic workload-drift generators.

The online controller exists because record rates *move*: diurnal tides,
flash-crowd bursts, and sites dropping out. Everything here is a pure
function of simulated time and a seed — two runs of the same scenario
produce bit-identical record streams, which the determinism acceptance
criterion (and the oracle baseline, which replays the same drive)
depends on.

Rate curves are callables ``t -> rate_hz`` composed per farm queue; the
:class:`DriftingFarm` advances producers whose inter-record gap tracks
the instantaneous curve. Site outages are plain ``(down, up)`` windows
consumed by :class:`~repro.online.fleet.EdgeSite`.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Sequence, Tuple

from repro.pipeline.streams import Broker, StreamProducer

RateCurve = Callable[[float], float]

_MIN_RATE_HZ = 1e-6


def constant(rate_hz: float) -> RateCurve:
    return lambda t: rate_hz


def diurnal(base_hz: float, amplitude: float = 0.5,
            period_s: float = 3600.0, phase_s: float = 0.0) -> RateCurve:
    """Sinusoidal tide around ``base_hz``: rate(t) = base·(1 + a·sin).
    ``amplitude`` in [0, 1) keeps the rate strictly positive."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError("amplitude must be in [0, 1)")

    def curve(t: float) -> float:
        return base_hz * (1.0 + amplitude
                          * math.sin(2 * math.pi * (t - phase_s) / period_s))
    return curve


def step_bursts(base_hz: float, burst_hz: float,
                windows: Sequence[Tuple[float, float]]) -> RateCurve:
    """Explicit burst windows: ``burst_hz`` inside, ``base_hz`` outside."""
    wins = sorted(windows)

    def curve(t: float) -> float:
        for t0, t1 in wins:
            if t0 <= t < t1:
                return burst_hz
        return base_hz
    return curve


def piecewise_linear(points: Sequence[Tuple[float, float]]) -> RateCurve:
    """Linear interpolation through (t, rate) knots — ramps, trapezoid
    bursts, any hand-drawn drift shape. Clamps outside the knot range."""
    pts = sorted(points)
    if len(pts) < 2:
        raise ValueError("need at least two (t, rate) points")

    def curve(t: float) -> float:
        if t <= pts[0][0]:
            return pts[0][1]
        for (t0, r0), (t1, r1) in zip(pts, pts[1:]):
            if t <= t1:
                frac = (t - t0) / max(t1 - t0, 1e-12)
                return r0 + frac * (r1 - r0)
        return pts[-1][1]
    return curve


def poisson_bursts(base_hz: float, burst_hz: float, horizon_s: float,
                   mean_gap_s: float, mean_len_s: float,
                   seed: int = 0) -> RateCurve:
    """Bursts whose starts form a (seeded, hence deterministic) Poisson
    process with mean gap ``mean_gap_s`` and exponential lengths."""
    rng = random.Random(seed * 6271 + 17)
    wins: List[Tuple[float, float]] = []
    t = rng.expovariate(1.0 / mean_gap_s)
    while t < horizon_s:
        length = rng.expovariate(1.0 / mean_len_s)
        wins.append((t, min(t + length, horizon_s)))
        t += length + rng.expovariate(1.0 / mean_gap_s)
    return step_bursts(base_hz, burst_hz, wins)


class DriftingProducer(StreamProducer):
    """One 'thing' whose inter-record gap tracks a rate curve. Record
    payloads reuse the Neubot-shaped schema of the base producer."""

    def __init__(self, broker: Broker, queue: str, thing_id: int,
                 curve: RateCurve, seed: int = 0):
        super().__init__(broker, queue, thing_id, rate_hz=1.0, seed=seed)
        self.curve = curve

    def advance_to(self, ts: float) -> int:
        n = 0
        while self._next_t <= ts:
            self.q.publish(self._record(self._next_t))
            rate = max(self.curve(self._next_t), _MIN_RATE_HZ)
            self._next_t += 1.0 / rate
            n += 1
        return n


class DriftingFarm:
    """An IoT farm of drift-modulated producers on one queue (the
    per-thing curve is the farm curve: the *aggregate* queue rate is
    ``n_things × curve(t)``)."""

    def __init__(self, broker: Broker, curve: RateCurve,
                 queue: str = "neubotspeed", n_things: int = 8,
                 seed: int = 0):
        self.producers = [DriftingProducer(broker, queue, i, curve, seed)
                          for i in range(n_things)]

    def advance_to(self, ts: float) -> int:
        return sum(p.advance_to(ts) for p in self.producers)


@dataclasses.dataclass(frozen=True)
class DriftScenario:
    """A named drift shape: per-queue rate curves plus site outage
    windows, applied on top of a fleet/pipeline scenario."""
    name: str
    curves: Dict[str, RateCurve] = dataclasses.field(default_factory=dict)
    outages: Dict[str, Tuple[Tuple[float, float], ...]] = \
        dataclasses.field(default_factory=dict)

    def curve(self, queue: str, default_hz: float = 1.0) -> RateCurve:
        return self.curves.get(queue, constant(default_hz))
