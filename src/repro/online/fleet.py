"""Multi-edge-site fleet topology (online controller subsystem).

The paper's deployment has *one* gateway next to the IoT farm; a fleet
has several — heterogeneous gateway-class boxes, each with its own
last-mile :class:`~repro.placement.network.LinkSpec` toward the DC, all
sharing one contended WAN uplink: concurrent uplink transfers (record
hauls, DC offloads, migration state) serialize FIFO through the shared
pipe, so one site's burst delays every site's offloads.

Routing between placement sites:

  edge→DC    src site's uplink through the shared FIFO, half-RTT after
             serialization completes.
  DC→edge    dst site's downlink (uncontended direction).
  edge→edge  relayed through the backhaul: src uplink (FIFO) then the
             dst site's downlink — a pipeline cut spanning two gateways
             pays both legs.

Sites can fail and recover (drift scenarios): while a site is down its
device executes nothing — fires queue until recovery (the outage windows
push the device's busy horizon), and the controller is expected to move
services off the site at the next epoch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

from repro.placement.edge import EdgeNode, EdgeSpec, FireExec
from repro.placement.network import LinkSpec, NetworkModel
from repro.placement.plan import SITE_DC


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One edge gateway site: device + last-mile link + the producer
    queues whose farms are physically attached to it."""
    name: str
    edge: EdgeSpec
    link: LinkSpec = dataclasses.field(default_factory=LinkSpec)
    farm_queues: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """The static fleet topology. ``user_site`` is where DC results
    surface for the user (one downlink per completed DC fire, as in the
    single-site co-sim); defaults to the first site."""
    sites: Tuple[SiteSpec, ...]
    user_site: str = ""

    def __post_init__(self):
        names = [s.name for s in self.sites]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate site names: {names}")
        if SITE_DC in names:
            raise ValueError(f"{SITE_DC!r} is reserved for the data center")
        if not self.sites:
            raise ValueError("a fleet needs at least one edge site")
        queues: Dict[str, str] = {}
        for s in self.sites:
            for q in s.farm_queues:
                if q in queues:
                    raise ValueError(
                        f"farm queue {q!r} pinned to both {queues[q]!r} "
                        f"and {s.name!r}")
                queues[q] = s.name
        if self.user_site and self.user_site not in names:
            raise ValueError(f"user_site {self.user_site!r} not in {names}")

    @property
    def site_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.sites)

    def site(self, name: str) -> SiteSpec:
        for s in self.sites:
            if s.name == name:
                return s
        raise KeyError(name)

    def farm_site(self, queue: str) -> str:
        """Site whose farm publishes into ``queue``; unpinned queues
        default to the first site (the classic single-gateway reading)."""
        for s in self.sites:
            if queue in s.farm_queues:
                return s.name
        return self.sites[0].name

    @property
    def result_site(self) -> str:
        return self.user_site or self.sites[0].name


class ContendedUplink:
    """FIFO serialization of the shared WAN uplink: a transfer occupies
    the pipe for its serialization time; concurrent transfers queue in
    admission order. Propagation (half-RTT) overlaps and does not hold
    the pipe."""

    def __init__(self):
        self.busy_until = 0.0
        self.queue_wait_s = 0.0     # total time transfers sat in the FIFO
        self.transfers = 0

    def admit(self, ready_ts: float, serialization_s: float) -> float:
        """Returns the time the transfer starts serializing."""
        start = max(ready_ts, self.busy_until)
        self.queue_wait_s += start - ready_ts
        self.busy_until = start + serialization_s
        self.transfers += 1
        return start


class EdgeSite:
    """Live state of one gateway: serial device + link accounting +
    failure windows."""

    def __init__(self, spec: SiteSpec,
                 outages: Sequence[Tuple[float, float]] = ()):
        self.spec = spec
        self.node = EdgeNode(spec.edge)
        self.net = NetworkModel(spec.link)
        self.outages = sorted(outages)

    def available_at(self, t: float) -> float:
        """Earliest time >= t at which the device is not in an outage."""
        for down, up in self.outages:
            if down <= t < up:
                return up
        return t

    def failed_at(self, t: float) -> bool:
        return any(down <= t < up for down, up in self.outages)

    def execute_fire(self, ready_ts: float, n_records: int,
                     flops_per_record: float = 0.0) -> FireExec:
        """Serial execution with outage deferral: a down site executes
        nothing, so any fire whose execution would *overlap* an outage
        window (including one that would start just before the site
        fails) is deferred to recovery."""
        dur = self.node.fire_time(n_records, flops_per_record)
        start = max(ready_ts, self.node.busy_until)
        moved = True
        while moved:
            moved = False
            for down, up in self.outages:
                if start < up and start + dur > down:
                    start = max(up, self.node.busy_until)
                    moved = True
        if start > self.node.busy_until:
            self.node.busy_until = start
        return self.node.execute_fire(ready_ts, n_records, flops_per_record)


class Fleet:
    """Live multi-site topology: per-site devices and links plus the one
    contended uplink every site's WAN transfers serialize through."""

    def __init__(self, spec: FleetSpec,
                 outages: Optional[Mapping[str, Sequence[Tuple[float, float]]]]
                 = None):
        self.spec = spec
        outages = outages or {}
        unknown = set(outages) - set(spec.site_names)
        if unknown:
            raise ValueError(f"outages for unknown sites: {sorted(unknown)}")
        self.sites: Dict[str, EdgeSite] = {
            s.name: EdgeSite(s, outages.get(s.name, ())) for s in spec.sites}
        self.uplink = ContendedUplink()

    def site(self, name: str) -> EdgeSite:
        return self.sites[name]

    # ------------------------------------------------------------- routing
    def ship_records(self, src: str, dst: str, n_records: int,
                     ready_ts: float) -> float:
        """Route ``n_records`` raw records src→dst; returns their arrival
        time. Same-site moves are free; any uplink leg contends FIFO."""
        if n_records <= 0 or src == dst:
            return ready_ts
        t = ready_ts
        if src != SITE_DC:
            site = self.sites[src]
            ser = site.net.uplink_serialization_s(n_records)
            start = self.uplink.admit(t, ser)
            site.net.uplink(n_records)          # bytes + NIC energy
            t = start + ser + site.net.spec.rtt_s / 2
        if dst != SITE_DC:
            t += self.sites[dst].net.downlink_records(n_records)
        return t

    def ship_result(self, src: str, dst: str, ready_ts: float) -> float:
        """Route one aggregate result src→dst (service handoff across a
        cut). Results are single records: the uplink leg still pays FIFO
        admission, the downlink leg is propagation-dominated."""
        if src == dst:
            return ready_ts
        t = ready_ts
        if src != SITE_DC:
            site = self.sites[src]
            ser = site.net.spec.result_bytes / site.net.spec.uplink_bps
            start = self.uplink.admit(t, ser)
            site.net.bytes_up += site.net.spec.result_bytes
            site.net.energy_j += (site.net.spec.result_bytes
                                  * site.net.spec.energy_per_byte_j)
            t = start + ser + site.net.spec.rtt_s / 2
        if dst != SITE_DC:
            t += self.sites[dst].net.downlink(1)
        return t

    def ship_state(self, src: str, dst: str, state_bytes: float,
                   ready_ts: float) -> float:
        """Migration state transfer (operator buffer shipped under a new
        placement plan). Occupies the shared uplink like any transfer —
        a migration storm visibly delays record offloads."""
        if state_bytes <= 0 or src == dst:
            return ready_ts
        t = ready_ts
        if src != SITE_DC:
            site = self.sites[src]
            ser = state_bytes / site.net.spec.uplink_bps
            start = self.uplink.admit(t, ser)
            site.net.bytes_up += state_bytes
            site.net.energy_j += state_bytes * site.net.spec.energy_per_byte_j
            t = start + ser + site.net.spec.rtt_s / 2
        if dst != SITE_DC:
            site = self.sites[dst]
            t += (site.net.spec.rtt_s / 2
                  + state_bytes / site.net.spec.downlink_bps)
            site.net.bytes_down += state_bytes
            site.net.energy_j += state_bytes * site.net.spec.energy_per_byte_j
        return t

    def downlink_time(self, dst: str) -> float:
        """Propagation+wire time of one result onto ``dst``'s downlink
        (no accounting — used for SLO shifts)."""
        return self.sites[dst].net.downlink_time(1)

    # ---------------------------------------------------------- accounting
    @property
    def edge_energy_j(self) -> float:
        return sum(s.node.energy_j for s in self.sites.values())

    @property
    def network_energy_j(self) -> float:
        return sum(s.net.energy_j for s in self.sites.values())

    @property
    def bytes_up(self) -> float:
        return sum(s.net.bytes_up for s in self.sites.values())

    @property
    def bytes_down(self) -> float:
        return sum(s.net.bytes_down for s in self.sites.values())

    def per_site_energy(self) -> Dict[str, Dict[str, float]]:
        return {name: {"edge_j": round(site.node.energy_j, 3),
                       "network_j": round(site.net.energy_j, 3),
                       "bytes_up": int(site.net.bytes_up),
                       "bytes_down": int(site.net.bytes_down)}
                for name, site in self.sites.items()}
