"""Multi-edge-site fleet topology (online controller subsystem).

The paper's deployment has *one* gateway next to the IoT farm; a fleet
has several — heterogeneous gateway-class boxes, each with its own
last-mile :class:`~repro.placement.network.LinkSpec` toward the DC, all
sharing one contended WAN uplink: concurrent uplink transfers (record
hauls, DC offloads, migration state) serialize FIFO through the shared
pipe, so one site's burst delays every site's offloads.

A fleet can also be *hierarchical* (``repro.region.HierFleetSpec``):
sites are partitioned into regions, each with its own shared edge-tier
pipe (the per-region twin of the flat fleet's single uplink) and a
regional aggregation point (RAP) whose trunk link to the DC core is a
second FIFO tier. :class:`Fleet` duck-types the hierarchy off the
spec's ``regions`` attribute, so the flat ``FleetSpec`` remains a
degenerate one-region hierarchy with a *transparent* RAP (infinite
trunk bandwidth, zero RTT — contributes nothing, bit-identically).

Routing between placement sites (flat; [RAP] legs apply only to
non-transparent hierarchies):

  edge→DC    src site's uplink through its region's edge-tier FIFO,
             half-RTT after serialization completes [then the RAP trunk
             FIFO + half trunk RTT].
  DC→edge    [RAP trunk downlink, uncontended] then the dst site's
             downlink (uncontended direction).
  edge→edge  relayed through the backhaul: src uplink (FIFO) then the
             dst site's downlink — a pipeline cut spanning two gateways
             pays both legs [cross-region cuts additionally pay the src
             RAP trunk up and the dst RAP trunk down; same-region cuts
             turn around at the RAP and never touch the trunk].

Sites can fail and recover (drift scenarios): while a site is down its
device executes nothing — fires queue until recovery (the outage windows
push the device's busy horizon), and the controller is expected to move
services off the site at the next epoch.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.placement.edge import EdgeNode, EdgeSpec, FireExec
from repro.placement.network import LinkSpec, NetworkModel
from repro.placement.plan import SITE_DC


def transparent_link(link: LinkSpec) -> bool:
    """True when ``link`` is a transparent (no-op) pipe — the degenerate
    RAP that makes a flat fleet and a one-region hierarchy bit-identical
    (infinite bandwidth, zero RTT, zero per-byte energy)."""
    return (math.isinf(link.uplink_bps) and math.isinf(link.downlink_bps)
            and link.rtt_s == 0.0 and link.energy_per_byte_j == 0.0)


@dataclasses.dataclass(frozen=True)
class SiteSpec:
    """One edge gateway site: device + last-mile link + the producer
    queues whose farms are physically attached to it."""
    name: str
    edge: EdgeSpec
    link: LinkSpec = dataclasses.field(default_factory=LinkSpec)
    farm_queues: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """The static fleet topology. ``user_site`` is where DC results
    surface for the user (one downlink per completed DC fire, as in the
    single-site co-sim); defaults to the first site."""
    sites: Tuple[SiteSpec, ...]
    user_site: str = ""

    def __post_init__(self):
        names = [s.name for s in self.sites]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate site names: {names}")
        if SITE_DC in names:
            raise ValueError(f"{SITE_DC!r} is reserved for the data center")
        if not self.sites:
            raise ValueError("a fleet needs at least one edge site")
        queues: Dict[str, str] = {}
        for s in self.sites:
            for q in s.farm_queues:
                if q in queues:
                    raise ValueError(
                        f"farm queue {q!r} pinned to both {queues[q]!r} "
                        f"and {s.name!r}")
                queues[q] = s.name
        if self.user_site and self.user_site not in names:
            raise ValueError(f"user_site {self.user_site!r} not in {names}")
        # O(1) lookup caches (a 500-site fleet is queried per service per
        # plan evaluation; the linear scans used to dominate)
        object.__setattr__(self, "_site_by_name",
                           {s.name: s for s in self.sites})
        object.__setattr__(self, "_site_of_queue", dict(queues))

    @property
    def site_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.sites)

    def site(self, name: str) -> SiteSpec:
        try:
            return self._site_by_name[name]
        except KeyError:
            raise KeyError(name) from None

    def farm_site(self, queue: str) -> str:
        """Site whose farm publishes into ``queue``; unpinned queues
        default to the first site (the classic single-gateway reading)."""
        return self._site_of_queue.get(queue, self.sites[0].name)

    @property
    def result_site(self) -> str:
        return self.user_site or self.sites[0].name


class LinkQueue:
    """FIFO serialization of one shared pipe: a transfer occupies the
    pipe for its serialization time; concurrent transfers queue in
    admission order. Propagation (half-RTT) overlaps and does not hold
    the pipe. One instance per contended tier — the flat fleet's shared
    WAN uplink, a region's edge-tier pipe, or a RAP's trunk to the DC
    core."""

    def __init__(self):
        self.busy_until = 0.0
        self.queue_wait_s = 0.0     # total time transfers sat in the FIFO
        self.transfers = 0
        # admission log [ready_ts, serialization_s, active] — lets an
        # admitted-but-unserviced transfer be withdrawn (its source site
        # died before the pipe got to it) with exact FIFO restoration
        self._log: List[List] = []

    def admit(self, ready_ts: float, serialization_s: float) -> float:
        """Returns the time the transfer starts serializing."""
        start = max(ready_ts, self.busy_until)
        self.queue_wait_s += start - ready_ts
        self.busy_until = start + serialization_s
        self.transfers += 1
        self._log.append([ready_ts, serialization_s, True])
        return start

    @property
    def last_token(self) -> int:
        """Token of the most recent admission (pass to ``withdraw``)."""
        return len(self._log) - 1

    def withdraw(self, token: int) -> bool:
        """Withdraw admission ``token`` and restore ``busy_until`` /
        ``queue_wait_s`` / ``transfers`` exactly as if it had never been
        admitted (the remaining admissions replay in order). Returns
        False when the token was already withdrawn."""
        if token < 0 or token >= len(self._log) or not self._log[token][2]:
            return False
        self._log[token][2] = False
        self.busy_until = 0.0
        self.queue_wait_s = 0.0
        self.transfers = 0
        for ready_ts, ser, active in self._log:
            if not active:
                continue
            start = max(ready_ts, self.busy_until)
            self.queue_wait_s += start - ready_ts
            self.busy_until = start + ser
            self.transfers += 1
        return True

    def withdraw_last(self) -> bool:
        """Withdraw the most recent still-active admission."""
        for i in range(len(self._log) - 1, -1, -1):
            if self._log[i][2]:
                return self.withdraw(i)
        return False


class ContendedUplink(LinkQueue):
    """The flat fleet's single shared WAN uplink — now just a
    :class:`LinkQueue` under its historical name (kept because it is
    part of the public ``repro.online`` surface)."""


class EdgeSite:
    """Live state of one gateway: serial device + link accounting +
    failure windows. ``outages`` are the *scheduled* maintenance windows
    (the oracle may read them); ``crashes`` / ``partitions`` /
    ``straggles`` are realized chaos windows kept separate so planning
    stays blind to them — a crash downs device *and* link, a partition
    downs only the link, a straggle multiplies link serialization."""

    def __init__(self, spec: SiteSpec,
                 outages: Sequence[Tuple[float, float]] = (),
                 crashes: Sequence[Tuple[float, float]] = (),
                 partitions: Sequence[Tuple[float, float]] = (),
                 straggles: Sequence[Tuple[float, float, float]] = ()):
        self.spec = spec
        self.node = EdgeNode(spec.edge)
        self.net = NetworkModel(spec.link)
        self.outages = sorted(outages)
        self.crashes = sorted(crashes)
        self.partitions = sorted(partitions)
        self.straggles = sorted(straggles)
        # device-down = scheduled outage OR unplanned crash;
        # link-dead = crash OR partition
        self._device_down = sorted(self.outages + self.crashes)
        self._link_dead = sorted(self.crashes + self.partitions)
        # realized uplink occupancy (chaos telemetry feed): seconds the
        # site's transfers held a shared pipe, and how many transfers
        self.link_busy_s = 0.0
        self.link_transfers = 0

    def available_at(self, t: float) -> float:
        """Earliest time >= t at which the device is not down."""
        for down, up in self._device_down:
            if down <= t < up:
                return up
        return t

    def failed_at(self, t: float) -> bool:
        return any(down <= t < up for down, up in self._device_down)

    def crashed_at(self, t: float) -> bool:
        return any(lo <= t < hi for lo, hi in self.crashes)

    def partitioned_at(self, t: float) -> bool:
        return any(lo <= t < hi for lo, hi in self.partitions)

    def link_blocked_until(self, t: float) -> Optional[float]:
        """End of the link-dead (crash ∪ partition) window covering
        ``t``, or None when the link is up."""
        out = None
        for lo, hi in self._link_dead:
            if lo <= t < hi:
                out = hi if out is None else max(out, hi)
        return out

    def straggle_factor(self, t: float) -> float:
        f = 1.0
        for lo, hi, fac in self.straggles:
            if lo <= t < hi:
                f = max(f, fac)
        return f

    def execute_fire(self, ready_ts: float, n_records: int,
                     flops_per_record: float = 0.0) -> FireExec:
        """Serial execution with down-window deferral: a down site
        (scheduled outage or unplanned crash) executes nothing, so any
        fire whose execution would *overlap* a down window (including
        one that would start just before the site fails) is deferred to
        recovery."""
        dur = self.node.fire_time(n_records, flops_per_record)
        start = max(ready_ts, self.node.busy_until)
        moved = True
        while moved:
            moved = False
            for down, up in self._device_down:
                if start < up and start + dur > down:
                    start = max(up, self.node.busy_until)
                    moved = True
        if start > self.node.busy_until:
            self.node.busy_until = start
        return self.node.execute_fire(ready_ts, n_records, flops_per_record)


class Fleet:
    """Live multi-site topology: per-site devices and links plus the
    contended shared pipes every WAN transfer serializes through — one
    uplink for a flat fleet, a per-region edge tier + per-region RAP
    trunk for a hierarchical one (``spec.regions``, duck-typed)."""

    def __init__(self, spec: FleetSpec,
                 outages: Optional[Mapping[str, Sequence[Tuple[float, float]]]]
                 = None, chaos=None):
        self.spec = spec
        outages = outages or {}
        unknown = set(outages) - set(spec.site_names)
        if unknown:
            raise ValueError(f"outages for unknown sites: {sorted(unknown)}")
        # chaos: an optional compiled ChaosTimeline — per-site realized
        # crash/partition/straggle windows injected physically (and kept
        # apart from the forecastable `outages`). None → every chaos
        # path below is dormant and routing is bit-identical.
        self.chaos = chaos
        self.sites: Dict[str, EdgeSite] = {
            s.name: EdgeSite(
                s, outages.get(s.name, ()),
                crashes=chaos.crash_windows(s.name) if chaos else (),
                partitions=chaos.partition_windows(s.name) if chaos else (),
                straggles=chaos.straggle_windows(s.name) if chaos else ())
            for s in spec.sites}

        regions = tuple(getattr(spec, "regions", ()) or ())
        if regions:
            self.region_names: Tuple[str, ...] = tuple(r.name for r in regions)
            self._region_of: Dict[str, int] = {
                site: i for i, r in enumerate(regions) for site in r.sites}
            self._edge_q: List[LinkQueue] = [LinkQueue() for _ in regions]
            self._rap_q: List[LinkQueue] = [LinkQueue() for _ in regions]
            # transparent RAPs short-circuit (None): the degenerate
            # one-region hierarchy routes bit-identically to a flat fleet
            self._rap: List[Optional[NetworkModel]] = [
                None if transparent_link(r.rap) else NetworkModel(r.rap)
                for r in regions]
        else:
            self.region_names = ("fleet",)
            self._region_of = {name: 0 for name in spec.site_names}
            self._edge_q = [LinkQueue()]
            self._rap_q = [LinkQueue()]
            self._rap = [None]
        # historical name: the (first) edge-tier shared pipe
        self.uplink: LinkQueue = self._edge_q[0]

    def site(self, name: str) -> EdgeSite:
        return self.sites[name]

    def region_of(self, site: str) -> int:
        return self._region_of[site]

    # ---------------------------------------------------------- RAP legs
    def _rap_up(self, region: int, wire_bytes: float, t: float) -> float:
        """Trunk leg RAP→DC-core: FIFO-contended serialization plus half
        the trunk RTT; accounts trunk bytes/energy. No-op when the RAP
        is transparent."""
        net = self._rap[region]
        if net is None:
            return t
        ser = wire_bytes / net.spec.uplink_bps
        start = self._rap_q[region].admit(t, ser)
        net.bytes_up += wire_bytes
        net.energy_j += wire_bytes * net.spec.energy_per_byte_j
        return start + ser + net.spec.rtt_s / 2

    def _rap_down(self, region: int, wire_bytes: float, t: float) -> float:
        """Trunk leg DC-core→RAP (uncontended direction, like a site
        downlink); accounts trunk bytes/energy."""
        net = self._rap[region]
        if net is None:
            return t
        net.bytes_down += wire_bytes
        net.energy_j += wire_bytes * net.spec.energy_per_byte_j
        return t + net.spec.rtt_s / 2 + wire_bytes / net.spec.downlink_bps

    def _crosses_core(self, src: str, dst: str) -> bool:
        """True when a src→dst transfer transits the DC core (leaves the
        src region / enters the dst region) rather than turning around
        inside one region."""
        if src == SITE_DC or dst == SITE_DC:
            return True
        return self._region_of[src] != self._region_of[dst]

    # ------------------------------------------------------------- routing
    def _admit_src(self, site: EdgeSite, region: int, ser0: float,
                   ready_ts: float) -> Tuple[float, float]:
        """Admit one uplink serialization for ``site``, chaos-aware:
        a straggling link inflates the serialization, and a transfer
        admitted into a dead-link window (the source crashed or
        partitioned before the pipe got to it) is *withdrawn* and
        re-admitted at heal. Without chaos windows this is exactly one
        ``admit`` at ×1.0. Returns ``(start, serialization_s)``."""
        q = self._edge_q[region]
        ser = ser0 * site.straggle_factor(ready_ts)
        start = q.admit(ready_ts, ser)
        while True:
            blocked = site.link_blocked_until(start)
            if blocked is None:
                break
            q.withdraw_last()
            ser = ser0 * site.straggle_factor(blocked)
            start = q.admit(blocked, ser)
        site.link_busy_s += ser
        site.link_transfers += 1
        return start, ser

    def ship_records(self, src: str, dst: str, n_records: int,
                     ready_ts: float) -> float:
        """Route ``n_records`` raw records src→dst; returns their arrival
        time. Same-site moves are free; any uplink leg contends FIFO."""
        if n_records <= 0 or src == dst:
            return ready_ts
        t = ready_ts
        cross = self._crosses_core(src, dst)
        if src != SITE_DC:
            site = self.sites[src]
            ser0 = site.net.uplink_serialization_s(n_records)
            start, ser = self._admit_src(site, self._region_of[src], ser0, t)
            site.net.uplink(n_records)          # bytes + NIC energy
            t = start + ser + site.net.spec.rtt_s / 2
            if cross:
                t = self._rap_up(self._region_of[src],
                                 site.net.uplink_wire_bytes(n_records), t)
        if dst != SITE_DC:
            dsite = self.sites[dst]
            blocked = dsite.link_blocked_until(t)
            if blocked is not None:   # dst link dead: delivery waits for heal
                t = blocked
            if cross:
                t = self._rap_down(self._region_of[dst],
                                   n_records * dsite.net.spec.record_bytes, t)
            t += dsite.net.downlink_records(n_records)
        return t

    def ship_result(self, src: str, dst: str, ready_ts: float) -> float:
        """Route one aggregate result src→dst (service handoff across a
        cut). Results are single records: the uplink leg still pays FIFO
        admission, the downlink leg is propagation-dominated."""
        if src == dst:
            return ready_ts
        t = ready_ts
        cross = self._crosses_core(src, dst)
        if src != SITE_DC:
            site = self.sites[src]
            ser0 = site.net.spec.result_bytes / site.net.spec.uplink_bps
            start, ser = self._admit_src(site, self._region_of[src], ser0, t)
            site.net.bytes_up += site.net.spec.result_bytes
            site.net.energy_j += (site.net.spec.result_bytes
                                  * site.net.spec.energy_per_byte_j)
            t = start + ser + site.net.spec.rtt_s / 2
            if cross:
                t = self._rap_up(self._region_of[src],
                                 site.net.spec.result_bytes, t)
        if dst != SITE_DC:
            dsite = self.sites[dst]
            blocked = dsite.link_blocked_until(t)
            if blocked is not None:
                t = blocked
            if cross:
                t = self._rap_down(self._region_of[dst],
                                   dsite.net.spec.result_bytes, t)
            t += dsite.net.downlink(1)
        return t

    def ship_state(self, src: str, dst: str, state_bytes: float,
                   ready_ts: float) -> float:
        """Migration state transfer (operator buffer shipped under a new
        placement plan). Occupies the shared pipes like any transfer —
        a migration storm visibly delays record offloads."""
        if state_bytes <= 0 or src == dst:
            return ready_ts
        t = ready_ts
        cross = self._crosses_core(src, dst)
        if src != SITE_DC:
            site = self.sites[src]
            ser0 = state_bytes / site.net.spec.uplink_bps
            start, ser = self._admit_src(site, self._region_of[src], ser0, t)
            site.net.bytes_up += state_bytes
            site.net.energy_j += state_bytes * site.net.spec.energy_per_byte_j
            t = start + ser + site.net.spec.rtt_s / 2
            if cross:
                t = self._rap_up(self._region_of[src], state_bytes, t)
        if dst != SITE_DC:
            site = self.sites[dst]
            blocked = site.link_blocked_until(t)
            if blocked is not None:
                t = blocked
            if cross:
                t = self._rap_down(self._region_of[dst], state_bytes, t)
            t += (site.net.spec.rtt_s / 2
                  + state_bytes / site.net.spec.downlink_bps)
            site.net.bytes_down += state_bytes
            site.net.energy_j += state_bytes * site.net.spec.energy_per_byte_j
        return t

    def downlink_time(self, dst: str) -> float:
        """Propagation+wire time of one result onto ``dst``'s downlink
        (no accounting — used for SLO shifts). Results surfacing from
        the DC core additionally ride the dst region's RAP trunk down
        in a hierarchy."""
        t = self.sites[dst].net.downlink_time(1)
        net = self._rap[self._region_of[dst]]
        if net is not None:
            t += (net.spec.rtt_s / 2
                  + self.sites[dst].net.spec.result_bytes
                  / net.spec.downlink_bps)
        return t

    # ---------------------------------------------------------- accounting
    @property
    def uplink_wait_s(self) -> float:
        """Total FIFO queue wait across every contended tier (edge-tier
        pipes + RAP trunks). Flat fleets: exactly the single uplink's."""
        return (sum(q.queue_wait_s for q in self._edge_q)
                + sum(q.queue_wait_s for q in self._rap_q))

    @property
    def uplink_transfers(self) -> int:
        return (sum(q.transfers for q in self._edge_q)
                + sum(q.transfers for q in self._rap_q))

    @property
    def edge_energy_j(self) -> float:
        return sum(s.node.energy_j for s in self.sites.values())

    @property
    def network_energy_j(self) -> float:
        return (sum(s.net.energy_j for s in self.sites.values())
                + sum(n.energy_j for n in self._rap if n is not None))

    @property
    def bytes_up(self) -> float:
        return sum(s.net.bytes_up for s in self.sites.values())

    @property
    def bytes_down(self) -> float:
        return sum(s.net.bytes_down for s in self.sites.values())

    def per_site_energy(self) -> Dict[str, Dict[str, float]]:
        return {name: {"edge_j": round(site.node.energy_j, 3),
                       "network_j": round(site.net.energy_j, 3),
                       "bytes_up": int(site.net.bytes_up),
                       "bytes_down": int(site.net.bytes_down)}
                for name, site in self.sites.items()}

    def per_region_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-region tier accounting: edge-tier FIFO wait/transfers and
        RAP trunk wait/transfers/bytes (zeros for transparent RAPs)."""
        out: Dict[str, Dict[str, float]] = {}
        for i, name in enumerate(self.region_names):
            rap = self._rap[i]
            out[name] = {
                "edge_fifo_wait_s": round(self._edge_q[i].queue_wait_s, 3),
                "edge_transfers": self._edge_q[i].transfers,
                "rap_fifo_wait_s": round(self._rap_q[i].queue_wait_s, 3),
                "rap_transfers": self._rap_q[i].transfers,
                "rap_bytes_up": int(rap.bytes_up) if rap else 0,
                "rap_bytes_down": int(rap.bytes_down) if rap else 0,
            }
        return out
