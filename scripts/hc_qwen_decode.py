"""Hillclimb 3: qwen3-1.7b × decode_32k — collective-bound decode
(t_coll 1.13s vs t_mem 0.19s).

H0 baseline: (16,16) mesh; kv=8 < model=16 → cache sequence-sharded over
"model" → per-layer score all-gathers for the softmax.
H1 (paper-faithful: VDC re-composition): same 256 chips recomposed as
   (32, 8) — kv=8 divides model=8, cache kv-head-sharded, no score
   gathers; batch 128/32 ✓.
H2: half-size VDC (16, 8) = 128 chips — VPTR prefers it if value/TaR wins.
H3: (64, 4) — TP=4, even fewer gathers but fatter per-chip cache.
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.hillclimb import run_variant  # noqa: E402

out = {}
for label, kw in [
    ("H1_32x8", dict(mesh_spec="32x8")),
    ("H2_16x8", dict(mesh_spec="16x8")),
    ("H3_64x4", dict(mesh_spec="64x4")),
]:
    rep = run_variant("qwen3-1.7b", "decode_32k", label=label, **kw)
    out[label] = rep.to_dict()
with open("results/hc_qwen_decode.json", "w") as f:
    json.dump(out, f, indent=1)
