"""Hillclimb 2: internvl2-76b × train_4k — most collective-bound cell
(t_coll 72.3s; 25.6k all-gathers: ZeRO-3 re-gathers weights 3× per
microbatch × 16 microbatches) and memory-OVER.

H1 (beyond-paper): gather_once — hoist the FSDP weight gather out of the
   microbatch loop (bf16, model-only sharding); per-microbatch cost drops
   to the grad reduce-scatter alone. Predicted: t_coll 72 → ~20s.
H2: H1 + 2-pod mesh (2x16x16): DP over pods halves per-device batch work.
H3: geometry (32,8) single pod: TP=8 halves TP all-reduce sizes, kv=8
   divides; FSDP width 32.
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.hillclimb import run_variant  # noqa: E402

out = {}
for label, kw in [
    ("H1_gather_once", dict(gather_once=True)),
    ("H2_gather_once_2pod", dict(gather_once=True, mesh_spec="2x16x16")),
    ("H3_32x8", dict(mesh_spec="32x8")),
]:
    rep = run_variant("internvl2-76b", "train_4k", label=label, **kw)
    out[label] = rep.to_dict()
with open("results/hc_internvl.json", "w") as f:
    json.dump(out, f, indent=1)
