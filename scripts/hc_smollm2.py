"""smollm hillclimb round 2: push the full-pod geometry further.
H5: 128x2; H6: 256x1 pure DP; H7: 64x4 + q_chunk 1024."""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.hillclimb import run_variant  # noqa: E402

out = json.load(open("results/hc_smollm.json"))
for label, kw in [
    ("H5_pod_128x2", dict(mesh_spec="128x2")),
    ("H6_pod_256x1", dict(mesh_spec="256x1")),
    ("H7_pod_64x4_qc1024", dict(mesh_spec="64x4", q_chunk=1024)),
]:
    rep = run_variant("smollm-135m", "train_4k", label=label, **kw)
    out[label] = rep.to_dict()
with open("results/hc_smollm.json", "w") as f:
    json.dump(out, f, indent=1)
