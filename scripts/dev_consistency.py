"""Dev check: prefill(t[0:S]) then decode(t[S]) must equal forward(t[0:S+1])
next-token logits for every arch family."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.models import model as M

S, B = 24, 2
F32 = jnp.float32


def run(name):
    cfg = get_arch(name).reduced()
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    extras = {}
    if cfg.frontend == "patch_stub":
        extras["patches"] = jax.random.normal(
            key, (B, cfg.n_prefix_tokens, cfg.d_model)) * 0.1
    if cfg.enc_dec is not None:
        extras["frames"] = jax.random.normal(
            key, (B, cfg.enc_dec.enc_seq, cfg.d_model)) * 0.1

    # reference: full forward over S+1 tokens, logits at position S-? We
    # compare the logits for predicting token S+1: forward position index S.
    full = {"tokens": tokens, **extras}
    logits_full, _ = M.forward(cfg, params, full, compute_dtype=F32)
    ref = np.asarray(logits_full[:, S])

    # prefill first S tokens, then decode token S at pos S
    pre = {"tokens": tokens[:, :S], **extras}
    logits0, cache = M.prefill(cfg, params, pre, cache_len=S + 8,
                               compute_dtype=F32)
    ref_prefill = np.asarray(logits_full[:, S - 1])
    err0 = np.max(np.abs(np.asarray(logits0) - ref_prefill))

    tok = tokens[:, S:S + 1]
    logits1, _ = M.decode_step(cfg, params, cache, tok, S, compute_dtype=F32)
    err1 = np.max(np.abs(np.asarray(logits1) - ref))
    status = "OK " if (err0 < 2e-3 and err1 < 2e-3) else "FAIL"
    print(f"{status} {name:24s} prefill_err={err0:.2e} decode_err={err1:.2e}")
    return err0 < 2e-3 and err1 < 2e-3


if __name__ == "__main__":
    names = sys.argv[1:] or list_archs()
    ok = all([run(n) for n in names])
    sys.exit(0 if ok else 1)
