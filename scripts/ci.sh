#!/usr/bin/env bash
# CI entry point: install the package with its test extra, then run the
# tier-1 suite (see ROADMAP.md). Falls back to a PYTHONPATH run when the
# environment is offline / externally managed.
set -euo pipefail
cd "$(dirname "$0")/.."

PIP_LOG="${TMPDIR:-/tmp}/ci-pip-install.log"
if ! python -m pip install -q -e ".[test]" 2>"$PIP_LOG"; then
    echo "ci.sh: pip install failed (see $PIP_LOG); running from src/ directly" >&2
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
