#!/usr/bin/env bash
# CI entry point: install the package with its test extra, run the
# tier-1 suite (see ROADMAP.md), then a fast benchmark smoke (1 scenario
# per stream bench at reduced trace length) so the benches can't rot
# silently. Falls back to a PYTHONPATH run when the environment is
# offline / externally managed. Set CI_SKIP_BENCH_SMOKE=1 to run tests
# only.
set -euo pipefail
cd "$(dirname "$0")/.."

PIP_LOG="${TMPDIR:-/tmp}/ci-pip-install.log"
if ! python -m pip install -q -e ".[test]" 2>"$PIP_LOG"; then
    echo "ci.sh: pip install failed (see $PIP_LOG); running from src/ directly" >&2
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

if [[ "${CI_SKIP_API_SURFACE:-0}" != "1" ]]; then
    echo "== API surface (scripts/ci.sh; CI_SKIP_API_SURFACE=1 to skip) =="
    # public exports import-check + ScenarioSpec JSON round-trip on the
    # bundled benchmark scenarios, then both edge examples end-to-end
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/api_surface.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/edge_offload_demo.py --smoke >/dev/null
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/edge_pipeline.py --smoke >/dev/null
    echo "examples (--smoke): OK"
fi

if [[ "${CI_SKIP_BENCH_SMOKE:-0}" != "1" ]]; then
    echo "== benchmark smoke (scripts/ci.sh; CI_SKIP_BENCH_SMOKE=1 to skip) =="
    # includes bench_search_perf --smoke, which *asserts* that the
    # two-tier screened search returns the same best-plan VoS as the
    # exact-only search (screen-vs-exact agreement gate)
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/run.py --smoke
fi
