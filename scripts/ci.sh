#!/usr/bin/env bash
# CI entry point: install the package with its test extra, run the
# tier-1 suite (see ROADMAP.md), then a fast benchmark smoke (1 scenario
# per stream bench at reduced trace length) so the benches can't rot
# silently. Falls back to a PYTHONPATH run when the environment is
# offline / externally managed. Set CI_SKIP_BENCH_SMOKE=1 to run tests
# only.
set -euo pipefail
cd "$(dirname "$0")/.."

PIP_LOG="${TMPDIR:-/tmp}/ci-pip-install.log"
if ! python -m pip install -q -e ".[test]" 2>"$PIP_LOG"; then
    echo "ci.sh: pip install failed (see $PIP_LOG); running from src/ directly" >&2
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

if [[ "${CI_SKIP_API_SURFACE:-0}" != "1" ]]; then
    echo "== API surface (scripts/ci.sh; CI_SKIP_API_SURFACE=1 to skip) =="
    # public exports import-check + ScenarioSpec JSON round-trip on the
    # bundled benchmark scenarios, then both edge examples end-to-end
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/api_surface.py
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/edge_offload_demo.py --smoke >/dev/null
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/edge_pipeline.py --smoke >/dev/null
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/serve_pipeline_demo.py --smoke >/dev/null
    echo "examples (--smoke): OK"
fi

if [[ "${CI_SKIP_COVERAGE:-0}" != "1" ]]; then
    if python -c "import pytest_cov" >/dev/null 2>&1; then
        echo "== coverage floor: repro.scenario + repro.online (CI_SKIP_COVERAGE=1 to skip) =="
        # Floor measured post-PR-5 at ~92% statement coverage over these
        # suites (settrace-based measurement); 85 leaves margin for
        # tool/version differences. Tighten via CI_COV_FLOOR as the
        # packages' suites grow. This re-runs suites the tier-1 pass
        # above already executed on purpose: that pass uses -x and may
        # stop at a known-flaky module, which would leave coverage
        # unmeasured if the two were merged into one invocation.
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q \
            tests/test_scenario.py tests/test_online.py \
            tests/test_feedback.py tests/test_placement.py \
            tests/test_elastic.py tests/test_screen_properties.py \
            tests/test_ledger_properties.py tests/test_parallel.py \
            --cov=repro.scenario --cov=repro.online \
            --cov-report=term --cov-fail-under="${CI_COV_FLOOR:-85}"
    else
        echo "coverage floor: pytest-cov not installed; skipping (pip install pytest-cov)"
    fi
fi

if [[ "${CI_SKIP_BENCH_SMOKE:-0}" != "1" ]]; then
    echo "== benchmark smoke (scripts/ci.sh; CI_SKIP_BENCH_SMOKE=1 to skip) =="
    # includes bench_search_perf --smoke, which *asserts* that the
    # two-tier screened search returns the same best-plan VoS as the
    # exact-only search (screen-vs-exact agreement gate), and
    # bench_online --smoke, which *asserts* the calibrated controller's
    # mean |calibration_gap| and oracle regret do not regress vs the
    # uncalibrated arm on the smoke scenario (calibration smoke gate),
    # and bench_serve --smoke, which *asserts* the live serving runtime
    # tracks the DES engine within the recorded sim-to-real gap
    # threshold, replays deterministically, conserves records, and
    # feeds the calibration loop from measured residuals (serving gate),
    # and bench_robust --smoke, which *asserts* the fluid ensemble
    # engine agrees with the exact DES within 5%, sustains >= 50x the
    # sequential-DES scenario-evals/sec, and that the CVaR objective
    # strictly improves worst-quantile VoS with DES tail confirmation
    # (robust-planning gate),
    # and bench_fleet --smoke, which *asserts* the 500-site hierarchical
    # fleet is generated, searched (delta-aware per-region screening +
    # batched exact-DES finalists) and co-simulated under the wall-clock
    # gate, with the decomposed search beating both flat anchors, the
    # warm-started online controller beating the best static plan, the
    # search phase holding >= 3x its recorded pre-optimization wall, and
    # a 2-worker ParallelEvaluator re-search reproducing the serial
    # winner bit-identically (planet-scale fleet + parallel gate),
    # and bench_chaos --smoke, which *asserts* the chaos-aware
    # controller beats every static plan through an unplanned mid-epoch
    # fault with at least one emergency re-plan, record ledgers stay
    # conserved (exactly-once: no duplicates key; at-least-once:
    # duplicates == declared migration replays), same-seed runs are
    # bit-identical, and a recorded chaos-free benchmark scenario
    # replays bit-identically (chaos & migration gate)
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python benchmarks/run.py --smoke
fi
