"""Dev scratch: forward/loss/prefill/decode on every reduced arch (CPU)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, list_archs
from repro.models import model as M

S, B = 32, 2


def run(name):
    cfg = get_arch(name).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "patch_stub":
        batch["patches"] = jnp.ones((B, cfg.n_prefix_tokens, cfg.d_model))
    if cfg.enc_dec is not None:
        batch["frames"] = jnp.ones((B, cfg.enc_dec.enc_seq, cfg.d_model))
    loss, metrics = jax.jit(
        lambda p, b: M.loss_fn(cfg, p, b, remat="full"))(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss NaN"
    # grads
    g = jax.jit(jax.grad(lambda p, b: M.loss_fn(cfg, p, b)[0]))(params, batch)
    gnorm = sum(float(jnp.sum(jnp.square(x))) for x in jax.tree.leaves(g)) ** 0.5
    assert np.isfinite(gnorm), f"{name}: grad NaN"
    # prefill + decode
    logits0, cache = jax.jit(
        lambda p, b: M.prefill(cfg, p, b, cache_len=S + 4))(params, batch)
    assert np.all(np.isfinite(np.asarray(logits0))), f"{name}: prefill NaN"
    tok = jnp.argmax(logits0, -1)[:, None].astype(jnp.int32)
    logits1, cache = jax.jit(
        lambda p, c, t: M.decode_step(cfg, p, c, t, S))(params, cache, tok)
    assert logits1.shape == (B, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits1))), f"{name}: decode NaN"
    print(f"OK {name:24s} params={n_params:>9,} loss={float(loss):.3f} "
          f"gnorm={gnorm:.3f}")


if __name__ == "__main__":
    names = sys.argv[1:] or list_archs()
    for n in names:
        run(n)
