#!/usr/bin/env python
"""Profile one decomposed region_search call on the benchmark fleet.

Separates the placement-independent functional drive (prewarmed, timed
apart) from the search itself, then prints the cProfile top-N of the
search by cumulative time — the first place to look when the planning
hot path regresses. Options:

  --sites N / --regions N / --seed N   fleet shape (default 100x4, a
                                       faster stand-in for the 500x8
                                       benchmark scenario; pass
                                       --sites 500 --regions 8 to
                                       profile the bench itself)
  --sweeps N                           block-coordinate sweeps (default 1)
  --top N                              rows to print (default 25)
  --sort cumulative|tottime            cProfile sort key
  --workers N                          profile through a ParallelEvaluator
                                       pool instead of the serial
                                       evaluator (worker CPU time is NOT
                                       attributed by cProfile — use this
                                       to see the dispatch overhead, not
                                       the kernels)

Usage: PYTHONPATH=src python scripts/profile_search.py [--top 25]
"""
from __future__ import annotations

import argparse
import cProfile
import pstats
import sys
import time

from repro.region import FleetGenSpec, generate_fleet, region_search


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sites", type=int, default=100)
    ap.add_argument("--regions", type=int, default=4)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--sweeps", type=int, default=1)
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--sort", default="cumulative",
                    choices=("cumulative", "tottime"))
    ap.add_argument("--workers", type=int, default=0)
    args = ap.parse_args(argv)

    gen = FleetGenSpec(n_sites=args.sites, n_regions=args.regions,
                       seed=args.seed, epoch_s=300.0, drift="bursts")
    t0 = time.perf_counter()
    spec = generate_fleet(gen)
    eng = spec.compile()
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng.screening_model()          # functional drive + screen lowering
    t_drive = time.perf_counter() - t0
    print(f"fleet {args.sites}x{args.regions}: compile {t_compile:.2f}s, "
          f"drive+screen prewarm {t_drive:.2f}s (excluded from profile)")

    evaluator = None
    if args.workers > 1:
        from repro.placement.parallel import ParallelEvaluator
        evaluator = ParallelEvaluator(eng, workers=args.workers, spec=spec)

    prof = cProfile.Profile()
    t0 = time.perf_counter()
    prof.enable()
    sr = region_search(eng, chips_options=(4, 8), seed=0,
                       sweeps=args.sweeps, evaluator=evaluator)
    prof.disable()
    wall = time.perf_counter() - t0
    if evaluator is not None:
        evaluator.close()

    delta = sr.screen.get("delta") or {}
    print(f"search wall {wall:.2f}s: vos={sr.result.vos:.1f} "
          f"screened={sr.screen['screened']} exact-evals={sr.evaluations} "
          f"delta-calls={delta.get('delta_calls')} "
          f"dense-fallbacks={delta.get('dense_fallbacks')}")
    stats = pstats.Stats(prof, stream=sys.stdout)
    stats.sort_stats(args.sort).print_stats(args.top)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
