"""internvl hillclimb round 3: the collective floor is TP activation
all-reduces (gather_once only bought ~11%), so reduce TP width.
H7: (32,8) accum=8 single pod — TP-AR bytes/device halve; FSDP gather
    traffic doubles (weights per model shard 2×). Net unclear — measure.
H8: (2,32,8) accum=4 — TP=8 across 2 pods, widest batch spread.
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.hillclimb import run_variant  # noqa: E402

out = json.load(open("results/hc_internvl.json"))
for label, kw in [
    ("H7_32x8_a8", dict(mesh_spec="32x8", accum=8)),
    ("H8_2x32x8_a4", dict(mesh_spec="2x32x8", accum=4)),
]:
    rep = run_variant("internvl2-76b", "train_4k", label=label, **kw)
    out[label] = rep.to_dict()
with open("results/hc_internvl.json", "w") as f:
    json.dump(out, f, indent=1)
