"""API-surface check (scripts/ci.sh): the public exports of the
scenario / placement / online packages must import and resolve, and
every bundled benchmark ScenarioSpec must round-trip losslessly through
JSON (spec == from_json(to_json(spec))) — the property that makes
scenarios re-targetable data rather than code.

  PYTHONPATH=src python scripts/api_surface.py
"""
import importlib
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

SURFACE = {
    "repro.scenario": (
        "ScenarioSpec", "ScenarioBuilder", "scenario", "ServiceSpec",
        "FarmSpec", "RateSpec", "StoreSpec", "ScenarioEngine",
        "EngineConfig", "EngineResult", "CoSimResult", "ServiceProfile",
        "ServiceSLO", "KernelCalibrator", "calibrate_profiles",
        "RecordLedger", "ServiceLedger", "BridgeInfo", "EpochObservation",
        "analytics_cost_model", "single_site_fleet", "ScreeningModel",
        "ScreenResult", "CalibrationLoop", "ServiceCalibration",
        "ServiceCorrection"),
    "repro.placement": (
        "EdgeNode", "EdgeSpec", "LinkSpec", "NetworkModel", "PlacementPlan",
        "ServicePlacement", "CoSimConfig", "CoSimResult", "CoSimulator",
        "ServiceProfile", "ServiceSLO", "Evaluator", "search_placement",
        "exhaustive_search", "greedy_search", "robust_search",
        "screened_search", "enumerate_plans"),
    "repro.fluid": (
        "FluidEngine", "FluidResult", "ScenarioEnsemble", "sample_specs",
        "RiskSpec", "risk_score", "rank_plans", "ensemble_spread",
        "calibration_prior"),
    "repro.online": (
        "Fleet", "FleetSpec", "SiteSpec", "ContendedUplink", "DriftingFarm",
        "FleetCoSimulator", "OnlineConfig", "OnlineResult", "BridgeInfo",
        "EpochObservation", "OnlineController", "OracleController",
        "StaticController", "ForecastModel", "plan_on_average_rates",
        "diurnal", "piecewise_linear", "poisson_bursts", "step_bursts"),
    "repro.region": (
        "RegionSpec", "HierFleetSpec", "TRANSPARENT_RAP", "DEFAULT_RAP",
        "regions_view", "FleetGenSpec", "generate_fleet", "hier_fleet_spec",
        "RegionPartition", "partition_services", "region_search",
        "region_search_exact"),
    "repro.chaos": (
        "ChaosSpec", "SiteCrash", "Partition", "LinkStraggle",
        "ChaosTimeline", "FaultObservation", "ChaosMigration",
        "plan_chaos_migrations", "ChaosController"),
    "repro.serve": (
        "ServeRuntime", "ServeConfig", "serve_scenario", "VirtualClock",
        "ServeTelemetry", "StageFire", "ServiceStage", "FarmDriver",
        "PlacementRouter", "DCPool", "UplinkShaper"),
}


def check_exports() -> int:
    bad = 0
    for module, names in SURFACE.items():
        mod = importlib.import_module(module)
        for name in names:
            if getattr(mod, name, None) is None:
                print(f"MISSING: {module}.{name}")
                bad += 1
    print(f"exports: {sum(len(v) for v in SURFACE.values())} names across "
          f"{len(SURFACE)} packages, {bad} missing")
    return bad


def check_roundtrips() -> int:
    from benchmarks import bench_chaos, bench_online, bench_placement
    from repro.scenario import ScenarioSpec

    specs = [make().spec for make in bench_placement.SCENARIOS]
    for make in bench_online.SCENARIOS:
        specs.append(make(smoke=True).spec)
        specs.append(make(smoke=False).spec)
    # chaos specs ride the same ScenarioSpec JSON (ChaosSpec is a field)
    for make in bench_chaos.SCENARIOS:
        specs.append(make(smoke=True).spec)
        specs.append(make(smoke=False).spec)
    # a generated hierarchical fleet (regions + RAP trunks, including
    # infinite-bandwidth transparent links) must survive JSON too
    from repro.region import FleetGenSpec, generate_fleet
    specs.append(generate_fleet(FleetGenSpec(
        n_sites=12, n_regions=3, seed=1, horizon_s=600.0)))
    bad = 0
    for spec in specs:
        back = ScenarioSpec.from_json(spec.to_json())
        if back != spec:
            print(f"ROUND-TRIP MISMATCH: {spec.name}")
            bad += 1
        else:
            # a round-tripped spec must also still compile
            back.validate()
    print(f"json round-trip: {len(specs)} bundled benchmark specs, "
          f"{bad} mismatched")
    return bad


def main() -> None:
    bad = check_exports() + check_roundtrips()
    if bad:
        sys.exit(f"api_surface: {bad} failures")
    print("api_surface: OK")


if __name__ == "__main__":
    main()
