"""internvl hillclimb round 2: fix the microbatch/data-width divisibility
(accum must satisfy global_batch/accum % data_width == 0).

H4: 2-pod + accum=8 (microbatch 32 ÷ 32-way data ✓) + gather_once
H5: 2-pod + accum=8, per-layer gathers (isolate gather_once's effect)
H6: single-pod + accum=16 + gather_once + remat='dots' (trade recompute
    memory-traffic for saved activations)
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.hillclimb import run_variant  # noqa: E402

out = json.load(open("results/hc_internvl.json"))
for label, kw in [
    ("H4_2pod_a8_gather_once", dict(mesh_spec="2x16x16", accum=8,
                                    gather_once=True)),
    ("H5_2pod_a8", dict(mesh_spec="2x16x16", accum=8)),
    ("H6_gather_once_dots", dict(gather_once=True, remat="dots")),
]:
    rep = run_variant("internvl2-76b", "train_4k", label=label, **kw)
    out[label] = rep.to_dict()
with open("results/hc_internvl.json", "w") as f:
    json.dump(out, f, indent=1)
