"""Hillclimb 1: smollm-135m × train_4k — worst roofline fraction (0.1%).

H0 baseline: 256 chips, TP=16 — 9 heads unshardable → attention replicated
16× across the model axis; a 135M model drowns on a full pod.
H1 (paper-faithful: VDC right-sizing, the paper's own mechanism): compose a
   16-chip VDC, pure DP (16x1) — zero TP replication.
H2: 16-chip VDC, 4x4 — replication only 4×.
H3 (beyond-paper): keep 256 chips but as 64x4 geometry — DP-heavy, TP=4.
H4: q_chunk 1024 on the best geometry.
"""
import json
import sys

sys.path.insert(0, "src")
from repro.launch.hillclimb import run_variant  # noqa: E402

out = {}
for label, kw in [
    ("H1_vdc16_dp", dict(mesh_spec="16x1")),
    ("H2_vdc16_4x4", dict(mesh_spec="4x4")),
    ("H3_pod_64x4", dict(mesh_spec="64x4")),
    ("H4_vdc16_dp_qc1024", dict(mesh_spec="16x1", q_chunk=1024)),
]:
    rep = run_variant("smollm-135m", "train_4k", label=label, **kw)
    out[label] = rep.to_dict()
with open("results/hc_smollm.json", "w") as f:
    json.dump(out, f, indent=1)
