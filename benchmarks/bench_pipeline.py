"""Paper §3 use case: the two Neubot queries over an IoT farm — latency of
combining massive post-mortem histories with live streams ("results at
reasonable response times (order of seconds)")."""
from __future__ import annotations

import time

import numpy as np

from repro.pipeline import (Broker, HybridExecutor, NeubotFarm, Pipeline,
                            TimeSeriesStore, neubot_query_1)


def main(csv_rows):
    print("\n== §3 use case: Neubot windowed queries ==")
    broker = Broker()
    store = TimeSeriesStore("speedtests", chunk_seconds=3600)
    farm = NeubotFarm(broker, n_things=8, rate_hz=1.0, seed=0)
    q1 = neubot_query_1(broker, store)
    pipe = Pipeline(broker).add_farm(farm).add_service(q1)

    t0 = time.perf_counter()
    res = pipe.advance_to(3600.0)["q1_max_speed"]  # 1 simulated hour
    dt = time.perf_counter() - t0
    per_fire = dt / max(1, len(res)) * 1e6
    print(f"Q1 (EVERY 60s MAX over last 3min, 8 things): {len(res)} fires, "
          f"{per_fire:.0f} us/fire, wall {dt:.2f}s")
    csv_rows.append(("q1_per_fire", per_fire, f"{len(res)}fires"))

    # Q2-scale history: 120-day mean = 10.4M records/thing at 1Hz; we build
    # a scaled history and compare edge vs VDC(JIT-offload kernel) paths.
    hx = HybridExecutor(edge_budget=100_000)
    for n in (10_000, 1_000_000, 10_368_000):
        vals = np.random.default_rng(0).standard_normal(n).astype(np.float32)
        t0 = time.perf_counter()
        v = hx.run_window(vals, "mean")
        dt = (time.perf_counter() - t0) * 1e6
        path = "VDC(offload)" if n > 100_000 else "edge"
        ok = abs(v - vals.mean()) < 1e-2
        print(f"Q2 window n={n:>10,}: {path:13s} {dt/1e6:7.3f}s "
              f"({'order-of-seconds OK' if dt < 30e6 and ok else 'SLOW/BAD'})")
        csv_rows.append((f"q2_window_{n}", dt, path))
    print(f"offload decisions: edge={hx.edge_runs} vdc={hx.offloads}")


if __name__ == "__main__":
    main([])
