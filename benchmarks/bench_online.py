"""Online fleet controller benchmark: static plans vs oracle-per-epoch
vs the online controller across drift scenarios → BENCH_online.json.

Each drift scenario is a declarative ScenarioSpec (fleet topology +
drift schedule + outages + epoching); ``spec.compile()`` yields the same
unified DES-bridged engine the static placement bench runs through.

Scenarios (2 edge gateways + the DC, shared FIFO-contended uplink):

  diurnal_tide   — a ~9× diurnal swing on the farm rate. At the peak the
                   medium analytics service saturates the gateway *and*
                   its raw-record offload saturates the shared uplink,
                   so the optimal home for it flips over the day; the
                   trough favors the DC (VDC floor energy beats a
                   seconds-long edge fire).
  flash_crowd    — trapezoid flash crowds (quiet base, multi-epoch
                   bursts). Static plans either waste the quiet epochs
                   or die in the bursts.
  site_failover  — farms on both gateways, primary gateway fails
                   mid-run and recovers. Pinning to the primary dies
                   during the outage; pinning to the backup pays the
                   cross-site record haul forever; the controller
                   evacuates and returns.
  correlated_bursts — synchronized multi-epoch bursts on BOTH gateways'
                   farms (adversarial for the forecast: correlated
                   offload demand saturates the shared FIFO uplink and
                   the DC at once, so the analytic model's optimistic
                   DC terms mis-rank plans burst after burst).
  ramp_outage    — slow rate ramp + a primary-gateway outage mid-ramp.
                   The sliding-window rate estimate lags the ramp every
                   epoch in the same direction: a persistent,
                   learnable forecast bias.

Every scenario runs TWO online arms: the raw controller and one with
``calibrate=True`` — a ``repro.scenario.feedback.CalibrationLoop``
feeding the measured calibration gap back into the forecast's ranking
terms. Acceptance (ISSUE 5, on top of ISSUE 2's): on every scenario
the calibrated arm's mean |calibration_gap| and its online-vs-oracle
regret are <= the uncalibrated arm's.

Base acceptance (ISSUE 2): online beats the best static plan on >= 2/3
scenarios, is within 10% of the oracle-per-epoch upper bound on all,
the per-service and per-site record-conservation ledgers are exact, and
controller runs are deterministic for a fixed seed. The online
controller's per-epoch regret telemetry (forecast-ranked vs co-simulated
VoS, *signed* search regret) lands in each epoch record of the report.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Sequence, Tuple

from repro.online import (OnlineController, OracleController,
                          StaticController, plan_on_average_rates)
from repro.placement import PlacementPlan, ServicePlacement
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import RateSpec, ScenarioBuilder, ScenarioSpec, scenario


def _out_path(smoke: bool) -> str:
    default = "BENCH_online_smoke.json" if smoke else "BENCH_online.json"
    return os.environ.get("BENCH_ONLINE_OUT", default)


@dataclasses.dataclass
class OnlineScenario:
    name: str
    spec: ScenarioSpec
    prior_rates: Dict[str, float]
    static_plans: Dict[str, PlacementPlan]
    chips_options: Sequence[int] = (4, 8)


# ---------------------------------------------------------------------------
# Shared fabric helpers
# ---------------------------------------------------------------------------
def _two_site_builder(name: str, uplink_a_bps: float, uplink_b_bps: float,
                      compression: float = 0.25,
                      record_bytes: float = 1024.0) -> ScenarioBuilder:
    """Two gateways, farm-heavy primary, leaner backup."""
    return (scenario(name)
            .site("gw-a", edge=EdgeSpec(name="gw-a", active_power_w=8.0),
                  link=LinkSpec(uplink_bps=uplink_a_bps, downlink_bps=20e6,
                                rtt_s=0.040, record_bytes=record_bytes,
                                compression=compression))
            .site("gw-b", edge=EdgeSpec(name="gw-b", flops_per_s=15e9,
                                        active_power_w=8.0),
                  link=LinkSpec(uplink_bps=uplink_b_bps, downlink_bps=20e6,
                                rtt_s=0.060, record_bytes=record_bytes,
                                compression=compression)))


def _tide_builder(name: str) -> ScenarioBuilder:
    """Ingest-bound gateways (slow record pump, frugal active power) on
    thin last-mile links with compact delta-coded records."""
    return (scenario(name)
            .site("gw-a", edge=EdgeSpec(name="gw-a", throughput_rps=2000.0,
                                        active_power_w=1.0,
                                        energy_per_record_j=50e-6),
                  link=LinkSpec(uplink_bps=15e3, downlink_bps=2e6,
                                rtt_s=0.040, record_bytes=64.0,
                                compression=0.25))
            .site("gw-b", edge=EdgeSpec(name="gw-b", throughput_rps=1500.0,
                                        flops_per_s=15e9, active_power_w=1.2,
                                        energy_per_record_j=60e-6),
                  link=LinkSpec(uplink_bps=12e3, downlink_bps=2e6,
                                rtt_s=0.060, record_bytes=64.0,
                                compression=0.25)))


# The tide services live on a tight per-fire energy budget spanning the
# VDC's floor energy (~2.3 J for a composed 4-chip tile at the kernel-
# launch floor): at low rates an ingest-bound edge fire costs well under
# a joule and the edge wins outright; the edge cost grows linearly with
# the record rate while the DC's stays flat, so the optimum flips as the
# tide comes in — and at the peak the edge fire blows the hard energy
# threshold entirely.
def _three_services(b: ScenarioBuilder) -> ScenarioBuilder:
    (b.service("agg", queue="neubotspeed", column="download_speed",
               agg="max", width_s=120, slide_s=30, buffer_budget=8192)
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=0.3, hard_energy_j=3.0)
     .profile(flops_per_record=2e3)
     .service("pctl", queue="neubotspeed", column="latency_ms",
              agg="mean", width_s=120, slide_s=30, buffer_budget=16384)
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=0.3, hard_energy_j=3.0, gamma=2.0)
     .profile(flops_per_record=2e3)
     .service("trend", queue="agg_out", column="value", agg="mean",
              width_s=300, slide_s=60, buffer_budget=8192)
     .fed_by("agg")
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=1.0, hard_energy_j=60.0)
     .profile(flops_per_record=2e3))
    return b


_NAMES_3 = ("agg", "pctl", "trend")


def _static_plans_3() -> Dict[str, PlacementPlan]:
    return {
        "all-edge-a": PlacementPlan.all_edge(list(_NAMES_3), site="gw-a"),
        "all-dc": PlacementPlan.all_dc(list(_NAMES_3), chips=4),
        "hybrid-tide-dc": PlacementPlan({
            "agg": ServicePlacement("dc", chips=4),
            "pctl": ServicePlacement("dc", chips=4),
            "trend": ServicePlacement("gw-a")}),
    }


_TIDE_PRIORS = {"agg": 8.0, "pctl": 8.0, "trend": 0.02}


def scenario_diurnal_tide(smoke: bool = False) -> OnlineScenario:
    horizon = 1800.0 if smoke else 3600.0
    rate = RateSpec.diurnal(5.0, amplitude=0.8, period_s=horizon,
                            phase_s=horizon / 4)   # trough first, peak mid
    b = (_three_services(_tide_builder("diurnal_tide"))
         .horizon(horizon).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .farm(n_things=8, seed=11, rate=rate, site="gw-a"))
    return OnlineScenario("diurnal_tide", b.build(),
                          prior_rates=dict(_TIDE_PRIORS),
                          static_plans=_static_plans_3())


def scenario_flash_crowd(smoke: bool = False) -> OnlineScenario:
    horizon = 1800.0 if smoke else 3600.0
    if smoke:
        knots = [(0.0, 1.0), (600.0, 1.0), (750.0, 9.0), (1050.0, 9.0),
                 (1200.0, 1.0), (horizon, 1.0)]
    else:
        knots = [(0.0, 1.0), (1200.0, 1.0), (1500.0, 9.0), (2100.0, 9.0),
                 (2400.0, 1.0), (horizon, 1.0)]
    b = (_three_services(_tide_builder("flash_crowd"))
         .horizon(horizon).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .farm(n_things=8, seed=23, rate=RateSpec.piecewise(knots),
               site="gw-a"))
    return OnlineScenario("flash_crowd", b.build(),
                          prior_rates=dict(_TIDE_PRIORS),
                          static_plans=_static_plans_3())


def scenario_site_failover(smoke: bool = False) -> OnlineScenario:
    horizon = 1800.0 if smoke else 3600.0
    out_lo, out_hi = (600.0, 1200.0) if smoke else (1200.0, 2400.0)
    b = (_two_site_builder("site_failover", uplink_a_bps=30e3,
                           uplink_b_bps=30e3)
         .horizon(horizon).epochs(300.0 if smoke else 600.0)
         .outage("gw-a", out_lo, out_hi)
         .farm(queue="neubotspeed", n_things=6, seed=37, site="gw-a",
               rate=RateSpec.diurnal(3.0, amplitude=0.3, period_s=horizon,
                                     phase_s=0.0))
         .farm(queue="auxspeed", n_things=6, seed=41, site="gw-b",
               rate=RateSpec.diurnal(3.0, amplitude=0.3, period_s=horizon,
                                     phase_s=horizon / 2)))
    for name, queue in (("agg_a", "neubotspeed"), ("agg_b", "auxspeed")):
        (b.service(name, queue=queue, column="download_speed", agg="max",
                   width_s=120, slide_s=30, buffer_budget=8192)
         .slo(soft_latency_s=2.0, hard_latency_s=10.0,
              soft_energy_j=1.0, hard_energy_j=60.0)
         .profile(flops_per_record=2e3))
    (b.service("fuse", queue="agg_out", column="value", agg="mean",
               width_s=300, slide_s=60, buffer_budget=8192)
     .fed_by("agg_a", "agg_b")
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=1.0, hard_energy_j=60.0)
     .profile(flops_per_record=2e3))
    names = ("agg_a", "agg_b", "fuse")
    statics = {
        "pin-gw-a": PlacementPlan.all_edge(list(names), site="gw-a"),
        "pin-gw-b": PlacementPlan.all_edge(list(names), site="gw-b"),
        "all-dc": PlacementPlan.all_dc(list(names), chips=4),
        "split-home": PlacementPlan({
            "agg_a": ServicePlacement("gw-a"),
            "agg_b": ServicePlacement("gw-b"),
            "fuse": ServicePlacement("gw-a")}),
    }
    return OnlineScenario(
        "site_failover", b.build(),
        prior_rates={"agg_a": 18.0, "agg_b": 18.0, "fuse": 0.05},
        static_plans=statics)


def scenario_correlated_bursts(smoke: bool = False) -> OnlineScenario:
    """Correlated multi-site bursts: both farms burst in the same
    multi-epoch windows, so offload demand hits the shared uplink and
    the DC grid at once — the regime where the analytic forecast's
    independent per-site terms mis-rank hardest."""
    horizon = 1800.0 if smoke else 3600.0
    if smoke:
        wins = [(450.0, 900.0), (1350.0, 1800.0)]
    else:
        wins = [(900.0, 1800.0), (2700.0, 3600.0)]
    b = _tide_builder("correlated_bursts")
    (b.horizon(horizon).epochs(300.0).dc(dc_step_floor_s=2e-3)
     .farm(queue="neubotspeed", n_things=8, seed=11, site="gw-a",
           rate=RateSpec.bursts(2.0, 11.0, wins))
     .farm(queue="auxspeed", n_things=8, seed=13, site="gw-b",
           rate=RateSpec.bursts(2.0, 11.0, wins)))
    for name, q in (("agg_a", "neubotspeed"), ("agg_b", "auxspeed")):
        (b.service(name, queue=q, column="download_speed", agg="max",
                   width_s=120, slide_s=30, buffer_budget=8192)
         .slo(soft_latency_s=2.0, hard_latency_s=10.0,
              soft_energy_j=0.3, hard_energy_j=3.0)
         .profile(flops_per_record=2e3))
    (b.service("fuse", queue="agg_out", column="value", agg="mean",
               width_s=300, slide_s=60, buffer_budget=8192)
     .fed_by("agg_a", "agg_b")
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=1.0, hard_energy_j=60.0)
     .profile(flops_per_record=2e3))
    names = ("agg_a", "agg_b", "fuse")
    statics = {
        "all-edge-a": PlacementPlan.all_edge(list(names), site="gw-a"),
        "split-home": PlacementPlan({
            "agg_a": ServicePlacement("gw-a"),
            "agg_b": ServicePlacement("gw-b"),
            "fuse": ServicePlacement("gw-a")}),
        "all-dc": PlacementPlan.all_dc(list(names), chips=4),
    }
    return OnlineScenario(
        "correlated_bursts", b.build(),
        prior_rates={"agg_a": 16.0, "agg_b": 16.0, "fuse": 0.05},
        static_plans=statics)


def scenario_ramp_outage(smoke: bool = False) -> OnlineScenario:
    """Slow ramp + mid-ramp uplink-site outage: the sliding rate
    estimate under-forecasts every epoch of the ramp (same sign), the
    persistent bias the calibration loop is built to learn."""
    horizon = 1800.0 if smoke else 3600.0
    out_lo, out_hi = (750.0, 1050.0) if smoke else (1500.0, 2100.0)
    ramp_top = horizon * 5.0 / 6.0
    b = (_three_services(_tide_builder("ramp_outage"))
         .horizon(horizon).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .outage("gw-a", out_lo, out_hi)
         .farm(n_things=8, seed=17, site="gw-a",
               rate=RateSpec.piecewise([(0.0, 1.0), (ramp_top, 13.0),
                                        (horizon, 13.0)])))
    return OnlineScenario("ramp_outage", b.build(),
                          prior_rates=dict(_TIDE_PRIORS),
                          static_plans=_static_plans_3())


SCENARIOS = (scenario_diurnal_tide, scenario_flash_crowd,
             scenario_site_failover, scenario_correlated_bursts,
             scenario_ramp_outage)


# ---------------------------------------------------------------------------
def _regret_block(summary: Dict) -> Dict:
    """Per-arm forecast-regret digest from an engine summary. The mean
    search regret is over *signed* per-epoch values (negative: the
    hysteresis kept an incumbent the fresh search scored below)."""
    regret = [e.get("forecast", {}) for e in summary["epochs"]]
    return {
        "epochs_with_telemetry": sum(1 for r in regret if r),
        "mean_search_regret": round(
            sum(r.get("search_regret") or 0.0 for r in regret)
            / max(1, len(regret)), 4),
        "mean_calibration_gap": round(
            sum(abs(r.get("calibration_gap") or 0.0) for r in regret)
            / max(1, len(regret)), 4),
    }


def run_scenario(sc: OnlineScenario, seed: int = 0) -> Dict:
    t0 = time.perf_counter()
    cs = sc.spec.compile()
    true_rates = cs.true_epoch_rates()
    avg_rates = {s: sum(r[s] for r in true_rates) / len(true_rates)
                 for s in cs.order}

    statics: Dict[str, Dict] = {}
    candidates = dict(sc.static_plans)
    searched = plan_on_average_rates(cs.info(), avg_rates,
                                     chips_options=sc.chips_options,
                                     seed=seed)
    candidates.setdefault("searched-avg", searched)
    best_static = None
    for label, plan in candidates.items():
        r = cs.run(StaticController(plan, label=f"static:{label}"))
        statics[label] = r.summary()
        if best_static is None or r.vos > best_static[1].vos:
            best_static = (label, r)
    assert best_static is not None

    online_ctrl = lambda cal=False: OnlineController(     # noqa: E731
        chips_options=sc.chips_options, window=1, switch_margin=0.02,
        seed=seed, prior_rates=sc.prior_rates, calibrate=cal)
    r_online = cs.run(online_ctrl())
    r_cal = cs.run(online_ctrl(cal=True))
    r_oracle = cs.run(OracleController(chips_options=sc.chips_options,
                                       seed=seed))
    r_repeat = cs.run(online_ctrl())            # determinism probes
    r_cal_repeat = cs.run(online_ctrl(cal=True))

    # ---- acceptance checks ----------------------------------------------
    conserved = (r_online.ledger.conserved() and r_cal.ledger.conserved()
                 and r_oracle.ledger.conserved())

    def _site_exact(r) -> bool:
        tot = r.ledger.totals()
        site_sum = sum(d.get("records_processed", 0)
                       for d in r.per_site.values())
        return site_sum == tot["processed_edge"] + tot["processed_dc"]

    per_site_exact = _site_exact(r_online) and _site_exact(r_cal)
    deterministic = (r_online.vos == r_repeat.vos
                     and r_online.ledger.totals() == r_repeat.ledger.totals()
                     and r_cal.vos == r_cal_repeat.vos
                     and r_cal.ledger.totals()
                     == r_cal_repeat.ledger.totals())
    beats_static = r_online.vos > best_static[1].vos
    within_oracle = (r_oracle.vos <= 0.0
                     or r_online.vos >= 0.9 * r_oracle.vos)
    regret = [e.get("forecast", {}) for e in r_online.summary()["epochs"]]
    searches = [r.get("search") for r in regret if r.get("search")]
    fr_raw = _regret_block(r_online.summary())
    fr_cal = _regret_block(r_cal.summary())
    regret_raw = r_oracle.vos - r_online.vos
    regret_cal = r_oracle.vos - r_cal.vos
    calibration = {
        "mean_abs_gap_raw": fr_raw["mean_calibration_gap"],
        "mean_abs_gap_calibrated": fr_cal["mean_calibration_gap"],
        "oracle_regret_raw": round(regret_raw, 4),
        "oracle_regret_calibrated": round(regret_cal, 4),
        "gap_shrinks": bool(fr_cal["mean_calibration_gap"]
                            <= fr_raw["mean_calibration_gap"] + 1e-9),
        "regret_shrinks": bool(regret_cal <= regret_raw + 1e-9),
    }
    return {
        "spec": sc.spec.to_dict(),
        "statics": statics,
        "best_static": {"label": best_static[0],
                        "vos": round(best_static[1].vos, 4)},
        "online": r_online.summary(),
        "online_calibrated": r_cal.summary(),
        "oracle": r_oracle.summary(),
        "avg_rates": {k: round(v, 3) for k, v in avg_rates.items()},
        "search_stats": {   # forecast-model plan searches across epochs
            "epochs": len(searches),
            "evaluations": sum(s["evaluations"] for s in searches),
            "cache_hits": sum(s["cache_hits"] for s in searches),
            "cache_misses": sum(s["cache_misses"] for s in searches),
        },
        "forecast_regret": fr_raw,
        "forecast_regret_calibrated": fr_cal,
        "calibration": calibration,
        "acceptance": {
            "online_beats_best_static": bool(beats_static),
            "within_10pct_of_oracle": bool(within_oracle),
            "ledger_conserved": bool(conserved),
            "per_site_ledger_exact": bool(per_site_exact),
            "deterministic": bool(deterministic),
            "calibration_gap_shrinks": calibration["gap_shrinks"],
            "calibration_regret_shrinks": calibration["regret_shrinks"],
        },
        "wall_s": round(time.perf_counter() - t0, 2),
    }


def main(csv_rows, smoke: bool = False) -> None:
    print("\n== Online fleet controller: static vs oracle vs online ==")
    report: Dict = {"smoke": smoke, "scenarios": {}}
    makers = SCENARIOS[:1] if smoke else SCENARIOS
    wins = within = cal_ok = 0
    hard_ok = True
    for make in makers:
        sc = make(smoke=smoke)
        res = run_scenario(sc)
        report["scenarios"][sc.name] = res
        acc = res["acceptance"]
        wins += acc["online_beats_best_static"]
        within += acc["within_10pct_of_oracle"]
        cal_ok += (acc["calibration_gap_shrinks"]
                   and acc["calibration_regret_shrinks"])
        hard_ok &= (acc["ledger_conserved"] and acc["per_site_ledger_exact"]
                    and acc["deterministic"])
        cal = res["calibration"]
        print(f"{sc.name:17s} best-static={res['best_static']['vos']:>9.2f} "
              f"({res['best_static']['label']}) "
              f"online={res['online']['vos']:>9.2f} "
              f"cal={res['online_calibrated']['vos']:>9.2f} "
              f"oracle={res['oracle']['vos']:>9.2f} "
              f"|gap| {cal['mean_abs_gap_raw']:.2f}->"
              f"{cal['mean_abs_gap_calibrated']:.2f} "
              f"[beats={acc['online_beats_best_static']} "
              f"within10%={acc['within_10pct_of_oracle']} "
              f"det={acc['deterministic']} "
              f"cal={acc['calibration_gap_shrinks'] and acc['calibration_regret_shrinks']}]")
        csv_rows.append((f"online_{sc.name}_vos",
                         res["online"]["vos"] * 1e3,
                         res["online"]["epochs"][-1]["plan"]))
    n = len(report["scenarios"])
    need_wins = max(1, (2 * n + 2) // 3)    # ceil(2n/3): >= 2/3 of scenarios
    ok = wins >= need_wins and within == n and hard_ok and cal_ok == n
    report["acceptance"] = {"beats_best_static": wins,
                            "within_oracle": within,
                            "calibration_improves": cal_ok, "of": n,
                            "pass": bool(ok)}
    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"online beats best static {wins}/{n}, within 10% of oracle "
          f"{within}/{n}, calibration shrinks gap+regret {cal_ok}/{n} "
          f"-> {'PASS' if ok else 'FAIL'}; wrote {out}")
    if smoke:
        # CI calibration smoke gate (scripts/ci.sh): the calibrated arm
        # must not regress gap or regret on the smoke scenario
        assert cal_ok == n, "calibration smoke: calibrated arm regressed"


if __name__ == "__main__":
    import sys
    main([], smoke="--smoke" in sys.argv)
