"""Sim-to-real gap benchmark: the recorded placement scenarios replayed
through BOTH executors of the same compiled ScenarioSpec — the DES
engine (``spec.compile()``) and the live serving runtime
(``repro.serve.serve_scenario``) — plus a live drift scenario where an
``OnlineController`` re-places mid-run and a ``CalibrationLoop`` learns
from *measured* residuals. Writes BENCH_serve.json.

The two executors share every physical model (serial gateway devices,
contended uplink, migration stalls, analytic DC roofline cells), so the
residual gap isolates the serving divergences the DES abstracts away:
late upstream data (the runtime never waits on dependencies), serial
per-service operators, and measured — not clairvoyant — epoch rates.

Acceptance (asserted in --smoke, the CI gate):

  * replay gap    — |VoS_real − VoS_sim| / max(1, VoS_sim) under the
                    recorded threshold on every replayed scenario
  * determinism   — two live runs produce identical VoS + epoch records
  * conservation  — the runtime's record ledger balances exactly
  * calibration   — the live calibrating arm accumulates measured
                    residual observations (the feedback path works on
                    serving telemetry, unchanged)
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict

from repro.online import OnlineController
from repro.placement import PlacementPlan
from repro.scenario import RateSpec, ScenarioSpec, scenario
from repro.serve import serve_scenario

# Recorded ceiling on the relative engine-vs-runtime VoS gap. Measured
# 0.0 on all three bundled scenarios (the executors are physically
# equivalent when no fire misses its upstream's publish); the margin
# covers platform float-ordering jitter, not semantic drift.
GAP_THRESHOLD = 0.02


def _out_path(smoke: bool) -> str:
    default = "BENCH_serve_smoke.json" if smoke else "BENCH_serve.json"
    return os.environ.get("BENCH_SERVE_OUT", default)


def _bench_placement_path() -> str:
    return os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_placement.json")


def _lat(r) -> Dict:
    return {"p50": round(r.latency_p50, 4), "p95": round(r.latency_p95, 4),
            "p99": round(r.latency_p99, 4)}


def _replay(name: str, sc: Dict) -> Dict:
    """One recorded scenario, the recorded searched plan, both
    executors."""
    spec = ScenarioSpec.from_dict(sc["spec"])
    plan = PlacementPlan.from_dict(sc["search"]["assignments"])
    t0 = time.perf_counter()
    sim = spec.compile().run_plan(plan)
    t1 = time.perf_counter()
    real = serve_scenario(spec).run_plan(plan)
    t2 = time.perf_counter()
    gap = abs(real.vos - sim.vos) / max(1.0, abs(sim.vos))
    return {
        "plan": plan.label,
        "vos_sim": round(sim.vos, 4), "vos_real": round(real.vos, 4),
        "vos_gap_rel": round(gap, 6),
        "latency_sim": _lat(sim), "latency_real": _lat(real),
        "latency_p95_gap_s": round(abs(real.latency_p95 - sim.latency_p95),
                                   6),
        "fires": {"sim": sim.fires_total, "real": real.fires_total},
        "ledger_conserved": bool(real.ledger.conserved()),
        "gap_under_threshold": bool(gap <= GAP_THRESHOLD),
        "wall_s": {"sim": round(t1 - t0, 3), "real": round(t2 - t1, 3)},
    }


def _live_spec(smoke: bool) -> ScenarioSpec:
    """Drifting two-service pipeline with a mid-run outage: enough load
    swing that the controller actually re-places while serving."""
    horizon = 900.0 if smoke else 2400.0
    return (scenario("serve_live")
            .horizon(horizon).epochs(300.0)
            .site("gw-a", user=True)
            .site("gw-b")
            .outage("gw-b", horizon / 3, horizon / 2)
            .farm(queue="neubotspeed", n_things=6, seed=11, site="gw-a",
                  rate=RateSpec.piecewise([(0.0, 1.0), (horizon / 2, 6.0),
                                           (horizon, 1.0)]))
            .farm(queue="aux", n_things=3, seed=13, site="gw-b",
                  rate=RateSpec.constant(2.0))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=30)
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=2.0, hard_energy_j=100.0)
            .profile(flops_per_record=2e3)
            .service("aux_mean", queue="aux", column="latency_ms",
                     agg="mean", width_s=120, slide_s=60)
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=2.0, hard_energy_j=100.0)
            .profile(flops_per_record=2e3)
            .service("fuse", queue="mix", column="value", agg="mean",
                     width_s=240, slide_s=120)
            .fed_by("agg", "aux_mean")
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=2.0, hard_energy_j=100.0)
            .profile(flops_per_record=2e3)
            .build())


def _live(smoke: bool) -> Dict:
    """The live serving section: OnlineController re-placing mid-run,
    CalibrationLoop fed by measured residuals, determinism probe."""
    spec = _live_spec(smoke)

    def _run():
        ctl = OnlineController(calibrate=True)
        res = serve_scenario(spec).run(ctl)
        return res, ctl

    t0 = time.perf_counter()
    real, ctl = _run()
    real2, _ = _run()                   # determinism probe
    sim = spec.compile().run(OnlineController(calibrate=True))
    wall = round(time.perf_counter() - t0, 3)

    gap = abs(real.vos - sim.vos) / max(1.0, abs(sim.vos))
    cal = ctl.calibration
    deterministic = (real.vos == real2.vos and real.epochs == real2.epochs
                     and real.ledger == real2.ledger)
    return {
        "spec": spec.to_dict(),
        "vos_sim": round(sim.vos, 4), "vos_real": round(real.vos, 4),
        "vos_gap_rel": round(gap, 6),
        "latency_sim": _lat(sim), "latency_real": _lat(real),
        "migrations": {"sim": sim.migrations, "real": real.migrations},
        "epochs": real.epochs,
        "calibration": {
            "observations": cal.observations,
            "history_len": len(cal.history),
            "last_corrections": (cal.history[-1]["corrections"]
                                 if cal.history else None),
        },
        "ledger_conserved": bool(real.ledger.conserved()),
        "deterministic": bool(deterministic),
        "gap_under_threshold": bool(gap <= GAP_THRESHOLD),
        "wall_s": wall,
    }


def main(csv_rows, smoke: bool = False) -> None:
    print("\n== Live serving runtime: sim-to-real gap (engine vs serve) ==")
    report: Dict = {"smoke": smoke, "gap_threshold": GAP_THRESHOLD,
                    "replays": {}, "live": None}

    with open(_bench_placement_path()) as f:
        recorded = json.load(f)["scenarios"]
    names = list(recorded)[:1] if smoke else list(recorded)
    for name in names:
        rep = _replay(name, recorded[name])
        report["replays"][name] = rep
        print(f"replay {name:18s} sim={rep['vos_sim']:>9.2f} "
              f"real={rep['vos_real']:>9.2f} gap={rep['vos_gap_rel']:.4f} "
              f"p95Δ={rep['latency_p95_gap_s']:.4f}s "
              f"[conserved={rep['ledger_conserved']} "
              f"under-threshold={rep['gap_under_threshold']}]")
        csv_rows.append((f"serve_replay_{name}_vos", rep["vos_real"] * 1e3,
                         f"gap_rel={rep['vos_gap_rel']}"))

    live = _live(smoke)
    report["live"] = live
    print(f"live   {'serve_live':18s} sim={live['vos_sim']:>9.2f} "
          f"real={live['vos_real']:>9.2f} gap={live['vos_gap_rel']:.4f} "
          f"migr={live['migrations']['real']} "
          f"cal-obs={live['calibration']['observations']} "
          f"[det={live['deterministic']} "
          f"conserved={live['ledger_conserved']}]")
    csv_rows.append(("serve_live_vos", live["vos_real"] * 1e3,
                     f"gap_rel={live['vos_gap_rel']}"))

    ok = (all(r["gap_under_threshold"] and r["ledger_conserved"]
              for r in report["replays"].values())
          and live["gap_under_threshold"] and live["ledger_conserved"]
          and live["deterministic"]
          and live["calibration"]["observations"] >= 2)
    report["acceptance"] = {
        "replay_gaps_under_threshold": all(
            r["gap_under_threshold"] for r in report["replays"].values()),
        "live_gap_under_threshold": live["gap_under_threshold"],
        "ledgers_conserved": all(
            r["ledger_conserved"] for r in report["replays"].values())
        and live["ledger_conserved"],
        "deterministic": live["deterministic"],
        "calibration_fed_by_measurement":
            live["calibration"]["observations"] >= 2,
        "pass": bool(ok),
    }
    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"sim-to-real gap under {GAP_THRESHOLD} on "
          f"{len(report['replays'])} replays + live run "
          f"-> {'PASS' if ok else 'FAIL'}; wrote {out}")
    if smoke:
        # CI serving smoke gate (scripts/ci.sh): the live runtime must
        # track the engine within the recorded threshold, replay
        # deterministically, conserve records, and feed the calibration
        # loop from measured residuals
        assert ok, "serve smoke: sim-to-real acceptance failed"


if __name__ == "__main__":
    import sys
    main([], smoke="--smoke" in sys.argv)
