"""Paper Fig. 4: value gain of VPTR over the Simple heuristic on a
peak-period workload (energy value, performance value, normalized VoS)."""
from __future__ import annotations

import statistics as stats
import time

from repro.core.costmodel import CostModel
from repro.core.heuristics import HEURISTICS
from repro.core.simulator import compare_heuristics
from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator

ARCHS = ["smollm-135m", "qwen3-1.7b", "yi-6b", "olmoe-1b-7b", "mamba2-1.3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def run(n_traces: int = 4, n_jobs: int = 200, cost=None):
    cost = cost or CostModel.analytic()
    types = [TaskType(a, s) for a in ARCHS for s in SHAPES]

    def trace_fn(i):
        return WorkloadGenerator(types, cost, seed=100 + i,
                                 **PAPER_REGIME).trace(n_jobs)

    t0 = time.perf_counter()
    res = compare_heuristics([HEURISTICS["Simple"], HEURISTICS["VPTR"]],
                             cost, trace_fn, n_traces=n_traces)
    wall = time.perf_counter() - t0
    mean = lambda k, n: stats.mean(getattr(r, k) for r in res[n])
    rows = []
    for metric, paper in (("energy_value", "+50%"), ("perf_value", "+40%"),
                          ("vos_normalized", "up to +71%")):
        gain = mean(metric, "VPTR") / mean(metric, "Simple") - 1
        best = max(v / s - 1 for v, s in zip(
            [getattr(r, metric) for r in res["VPTR"]],
            [getattr(r, metric) for r in res["Simple"]]))
        rows.append((metric, gain, best, paper))
    return rows, res, wall


def main(csv_rows):
    rows, res, wall = run()
    print("\n== Fig. 4: VPTR vs Simple (peak workload, 256-chip pod) ==")
    print(f"{'metric':18s} {'mean gain':>10s} {'best trace':>11s} {'paper':>14s}")
    for metric, gain, best, paper in rows:
        print(f"{metric:18s} {gain:+10.1%} {best:+11.1%} {paper:>14s}")
        csv_rows.append((f"fig4_{metric}_gain", wall * 1e6 / 3,
                         f"{gain:+.3f}"))
    ablation_curve_shape(csv_rows)
    return rows


def ablation_curve_shape(csv_rows, n_traces=2, n_jobs=150):
    """DESIGN §8 ablation: the paper notes the linear decay 'can be
    replaced by other functions' — rerun Fig. 4 with exponential decay."""
    cost = CostModel.analytic()
    types = [TaskType(a, s) for a in ARCHS for s in SHAPES]

    def trace_fn(i):
        g = WorkloadGenerator(types, cost, seed=100 + i,
                              curve_shape="exponential", **PAPER_REGIME)
        return g.trace(n_jobs)

    res = compare_heuristics([HEURISTICS["Simple"], HEURISTICS["VPTR"]],
                             cost, trace_fn, n_traces=n_traces)
    mean = lambda n: stats.mean(r.vos_normalized for r in res[n])
    gain = mean("VPTR") / mean("Simple") - 1
    print(f"ablation (exponential value decay): VPTR VoS gain {gain:+.1%} "
          f"— the heuristic ordering is curve-shape robust")
    csv_rows.append(("fig4_ablation_exp_curve", 0.0, f"{gain:+.3f}"))


if __name__ == "__main__":
    main([])
