"""Paper Fig. 5: VPT / VPT-CPC / VPT-JSPC / Hybrid under 55/70/85% power
caps — simulation (analytic roofline cost model) vs emulation (cost model
rebuilt from real measured step times of the reduced models on this host;
§4.2 validation methodology, pattern match not magnitude match)."""
from __future__ import annotations

import statistics as stats
import time

from repro import hardware as hw
from repro.core.costmodel import CostModel
from repro.core.heuristics import HEURISTICS
from repro.core.simulator import compare_heuristics
from repro.core.tasks import PAPER_REGIME, TaskType, WorkloadGenerator

NAMES = ["VPT", "VPT-CPC", "VPT-JSPC", "Hybrid"]
ARCHS = ["smollm-135m", "qwen3-1.7b", "olmoe-1b-7b", "mamba2-1.3b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k"]


def run_grid(cost, n_traces=3, n_jobs=150):
    types = [TaskType(a, s) for a in ARCHS for s in SHAPES]

    def trace_fn(i):
        return WorkloadGenerator(types, cost, seed=200 + i,
                                 **PAPER_REGIME).trace(n_jobs)

    grid = {}
    for frac in (0.55, 0.70, 0.85):
        cap = hw.pod_power_cap_w(frac)
        res = compare_heuristics([HEURISTICS[n] for n in NAMES], cost,
                                 trace_fn, n_traces=n_traces,
                                 power_cap_w=cap)
        grid[frac] = {n: stats.mean(r.vos_normalized for r in res[n])
                      for n in NAMES}
    return grid


def main(csv_rows, emulate: bool = True):
    t0 = time.perf_counter()
    sim = run_grid(CostModel.analytic())
    print("\n== Fig. 5(a) SIMULATION: normalized VoS vs power cap ==")
    _table(sim, csv_rows, "sim")
    if emulate:
        from repro.core.emulator import measured_cost_model
        emu_cost = measured_cost_model(ARCHS, SHAPES, scale=3e4)
        emu = run_grid(emu_cost, n_traces=2)
        print("\n== Fig. 5(b) EMULATION (measured reduced-model step times) ==")
        _table(emu, csv_rows, "emu")
        # pattern agreement: concordant heuristic-pair ordering (Kendall)
        agree = []
        for frac in sim:
            conc = tot = 0
            for i, a in enumerate(NAMES):
                for b in NAMES[i + 1:]:
                    tot += 1
                    conc += (sim[frac][a] - sim[frac][b]) * \
                            (emu[frac][a] - emu[frac][b]) > 0
            agree.append(conc / tot)
        print(f"\nranking agreement sim↔emu: {stats.mean(agree):.0%} "
              f"(paper: 'similarity in the pattern', magnitudes differ)")
        csv_rows.append(("fig5_rank_agreement",
                         (time.perf_counter() - t0) * 1e6,
                         f"{stats.mean(agree):.2f}"))
    return sim


def _table(grid, csv_rows, tag):
    print(f"{'cap':>5s} " + "".join(f"{n:>10s}" for n in NAMES))
    for frac, row in grid.items():
        print(f"{frac:5.0%} " + "".join(f"{row[n]:10.3f}" for n in NAMES))
        for n in NAMES:
            csv_rows.append((f"fig5_{tag}_{int(frac*100)}_{n}", 0.0,
                             f"{row[n]:.4f}"))


if __name__ == "__main__":
    main([])
