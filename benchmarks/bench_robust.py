"""Distributionally robust planning benchmark → BENCH_robust.json.

Three blocks, each pinning one acceptance gate of the fluid-ensemble
engine (``repro.fluid``):

  agreement   — the fluid engine's nominal-trace VoS vs the exact DES
                on every recorded BENCH_placement scenario's anchor
                plans (gate: ≤ 5% relative error everywhere; in
                practice the per-bin backlog recursion reproduces the
                DES latencies exactly).
  throughput  — one jitted ensemble call (257 realizations × 32 plans)
                vs sequential DES scenario evaluations (gate: ≥ 50×
                scenario-evals/sec; measured in the thousands).
  choice      — CVaR-vs-mean plan choice from ``robust_search()`` on
                the ``correlated_bursts`` / ``ramp_outage`` adversarial
                scenarios (recorded) and on ``burst_tail``, a scenario
                built so the mean-optimal all-edge plan saturates the
                gateway on rate-tail realizations while the DC plan
                pays a flat WAN latency (gate: the CVaR objective
                strictly improves worst-quantile VoS, with exact-DES
                scores on the tail realizations confirming the ranking
                and no screen-tier mis-rank of either final winner).

Every gate asserts in ``--smoke`` (the CI path) as well as in the full
run, so the robust tier cannot rot silently.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Sequence

import numpy as np

from benchmarks.bench_online import (scenario_correlated_bursts,
                                     scenario_ramp_outage)
from benchmarks.bench_placement import (SCENARIOS as PLACEMENT_SCENARIOS)
from repro.fluid import FluidEngine, RiskSpec, ScenarioEnsemble
from repro.placement import Evaluator, PlacementPlan, robust_search
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.plan import enumerate_plans
from repro.scenario import RateSpec, ScenarioSpec, scenario

AGREEMENT_TOL = 0.05          # fluid vs DES relative VoS error
SPEEDUP_FLOOR = 50.0          # ensemble vs sequential-DES evals/sec


def _out_path(smoke: bool) -> str:
    default = "BENCH_robust_smoke.json" if smoke else "BENCH_robust.json"
    return os.environ.get("BENCH_ROBUST_OUT", default)


# ---------------------------------------------------------------------------
# Block 1: fluid vs exact-DES agreement on the recorded placement scenarios
# ---------------------------------------------------------------------------
def _anchor_plans(eng, chips_options: Sequence[int]) -> List[PlacementPlan]:
    names = list(eng.order)
    sites = list(eng.info().fleet.site_names)
    plans = [PlacementPlan.all_edge(names, site=s) for s in sites]
    plans += [PlacementPlan.all_dc(names, chips=c) for c in chips_options]
    return plans


def agreement_block() -> List[Dict]:
    rows = []
    for builder in PLACEMENT_SCENARIOS:
        sc = builder()
        eng = sc.spec.compile()
        fluid = FluidEngine.compile(eng)
        plans = _anchor_plans(eng, sc.chips_options)
        fr = fluid.evaluate(plans)
        for m, plan in enumerate(plans):
            des = eng.run_plan(plan)
            f_vos = float(fr.vos[0, m])
            d_vos = des.vos if des.feasible else float("-inf")
            if not des.feasible or not np.isfinite(f_vos):
                # both tiers must agree a plan is infeasible
                err = 0.0 if (not des.feasible
                              and not np.isfinite(f_vos)) else float("inf")
            else:
                err = abs(f_vos - d_vos) / max(abs(d_vos), 1e-9)
            rows.append({
                "scenario": sc.name, "plan": plan.label,
                "fluid_vos": (round(f_vos, 4)
                              if np.isfinite(f_vos) else None),
                "des_vos": round(d_vos, 4) if des.feasible else None,
                "rel_err": round(err, 6),
            })
    return rows


# ---------------------------------------------------------------------------
# Block 2: ensemble throughput vs sequential DES
# ---------------------------------------------------------------------------
def throughput_block(n_realizations: int = 256, n_plans: int = 32,
                     des_samples: int = 2) -> Dict:
    sc = next(b() for b in PLACEMENT_SCENARIOS
              if b().name == "heavy_analytics")
    eng = sc.spec.compile()
    names = list(eng.order)
    sites = tuple(eng.info().fleet.site_names)
    plans = list(enumerate_plans(names, (4, 8, 16), (1.0,),
                                 edge_sites=sites))[:n_plans]

    t0 = time.perf_counter()
    ens = ScenarioEnsemble.from_spec(sc.spec, n=n_realizations, engine=eng)
    setup_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    ens.evaluate(plans)                      # includes XLA trace
    first_call_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    fr = ens.evaluate(plans)                 # warm jitted call
    warm_s = time.perf_counter() - t0
    evals = fr.n_realizations * fr.n_plans

    # sequential DES baseline: one scenario-eval = compile a realization
    # spec + replay one plan through the event loop
    t0 = time.perf_counter()
    for i in range(1, 1 + des_samples):
        ens.specs[i].compile().run_plan(plans[0])
    des_per_eval_s = (time.perf_counter() - t0) / des_samples

    ens_rate = evals / warm_s
    des_rate = 1.0 / des_per_eval_s
    return {
        "realizations": fr.n_realizations, "plans": fr.n_plans,
        "scenario_evals": evals,
        "ensemble_setup_s": round(setup_s, 3),
        "first_call_s": round(first_call_s, 3),
        "warm_call_s": round(warm_s, 4),
        "ensemble_evals_per_s": round(ens_rate, 1),
        "des_s_per_eval": round(des_per_eval_s, 4),
        "des_evals_per_s": round(des_rate, 3),
        "speedup": round(ens_rate / des_rate, 1),
    }


# ---------------------------------------------------------------------------
# Block 3: CVaR-vs-mean plan choice
# ---------------------------------------------------------------------------
def scenario_burst_tail() -> ScenarioSpec:
    """Adversarial drift scenario for the robust-planning gate: a
    gateway sized so the all-edge plan rides at ~0.8 burst utilization
    on the *nominal* trace (comfortably the mean-VoS winner) but
    saturates — backlog divergence, latency past the hard SLO — on the
    upper rate tail of the drift ensemble, while DC offload pays a flat
    mid-curve WAN latency that barely moves with the rate. Mean ranking
    prefers the edge; any tail-sensitive ranking prefers the DC."""
    b = (scenario("burst_tail")
         .site("gw-a", edge=EdgeSpec(name="gw-a", throughput_rps=180.0,
                                     flops_per_s=20e9, active_power_w=0.2,
                                     energy_per_record_j=100e-6),
               link=LinkSpec(uplink_bps=1e6, downlink_bps=2e6,
                             rtt_s=6.0, record_bytes=1024.0,
                             compression=0.25))
         .horizon(1800.0).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .farm(queue="neubotspeed", n_things=8, seed=7, site="gw-a",
               rate=RateSpec.bursts(2.0, 9.0, [(300.0, 900.0),
                                               (1200.0, 1800.0)])))
    (b.service("agg", queue="neubotspeed", column="download_speed",
               agg="max", width_s=10, slide_s=5, buffer_budget=8192)
     .slo(soft_latency_s=4.0, hard_latency_s=6.5,
          soft_energy_j=5.0, hard_energy_j=50.0)
     .profile(flops_per_record=2e3)
     .service("trend", queue="agg_out", column="value", agg="mean",
              width_s=60, slide_s=30, buffer_budget=8192)
     .fed_by("agg")
     .slo(soft_latency_s=4.0, hard_latency_s=10.0,
          soft_energy_j=5.0, hard_energy_j=60.0)
     .profile(flops_per_record=2e3))
    return b.build()


def _choice_row(name: str, spec: ScenarioSpec,
                chips_options: Sequence[int], n: int = 48, seed: int = 0,
                rate_scale: float = 0.25, onset_scale: float = 0.15,
                des_tail_k: int = 0) -> Dict:
    """Run robust_search twice (mean / CVaR objective) over one shared
    ensemble; report the fluid worst-quantile VoS of both winners and,
    when ``des_tail_k`` > 0 and the winners diverge, the exact-DES
    scores of both plans on the worst tail realizations."""
    eng = spec.compile()
    sites = tuple(eng.info().fleet.site_names)
    ens = ScenarioEnsemble.from_spec(spec, n=n, seed=seed, engine=eng,
                                     rate_scale=rate_scale,
                                     onset_scale=onset_scale)
    ev = Evaluator(eng)
    srs = {m: robust_search(eng, ens, risk=m, chips_options=chips_options,
                            shortlist=16, final_k=6, evaluator=ev,
                            edge_sites=sites)
           for m in ("mean", "cvar")}
    mp, cp = srs["mean"].plan, srs["cvar"].plan
    fr = ens.evaluate([mp, cp])
    mean_v = fr.vos.mean(axis=0)
    q10 = np.quantile(fr.vos, 0.1, axis=0)
    row = {
        "scenario": name,
        "realizations": ens.n_realizations,
        "rate_scale": rate_scale,
        "mean_plan": mp.label, "cvar_plan": cp.label,
        "diverged": bool(mp.key() != cp.key()),
        "fluid": {
            "mean_plan": {"mean": round(float(mean_v[0]), 4),
                          "q10": round(float(q10[0]), 4)},
            "cvar_plan": {"mean": round(float(mean_v[1]), 4),
                          "q10": round(float(q10[1]), 4)},
        },
        "search": {m: {"agreement": sr.screen["agreement"],
                       "robust": sr.screen["robust"]}
                   for m, sr in srs.items()},
    }
    if des_tail_k > 0 and row["diverged"]:
        # exact-DES confirmation on the union of each plan's worst
        # realizations (one compile per member, both plans replayed)
        tail = sorted(int(i) for i in
                      set(np.argsort(fr.vos[:, 0])[:des_tail_k])
                      | set(np.argsort(fr.vos[:, 1])[:des_tail_k]))
        des = {}
        for i in tail:
            cs = ens.specs[int(i)].compile()
            des[int(i)] = (cs.run_plan(mp).vos, cs.run_plan(cp).vos)
        dm = [v[0] for v in des.values()]
        dc = [v[1] for v in des.values()]
        row["des_tail"] = {
            "members": tail,
            "mean_plan": {"min": round(min(dm), 4),
                          "mean": round(float(np.mean(dm)), 4)},
            "cvar_plan": {"min": round(min(dc), 4),
                          "mean": round(float(np.mean(dc)), 4)},
        }
    return row


# ---------------------------------------------------------------------------
def main(csv_rows, smoke: bool = False) -> None:
    report: Dict = {"blocks": {}}

    agreement = agreement_block()
    worst_err = max(r["rel_err"] for r in agreement)
    report["blocks"]["agreement"] = {
        "tolerance": AGREEMENT_TOL, "worst_rel_err": round(worst_err, 6),
        "plans": agreement}
    assert worst_err <= AGREEMENT_TOL, (
        f"fluid-vs-DES agreement gate: worst rel err {worst_err:.4f} "
        f"> {AGREEMENT_TOL}")

    thr = throughput_block()
    report["blocks"]["throughput"] = thr
    assert thr["speedup"] >= SPEEDUP_FLOOR, (
        f"throughput gate: {thr['speedup']}x < {SPEEDUP_FLOOR}x")

    tail_k = 3 if smoke else 5
    choice = [
        _choice_row("correlated_bursts",
                    scenario_correlated_bursts(smoke=smoke).spec,
                    (4, 8), seed=3, des_tail_k=0),
        _choice_row("ramp_outage",
                    scenario_ramp_outage(smoke=smoke).spec,
                    (4, 8), seed=3, des_tail_k=0),
        _choice_row("burst_tail", scenario_burst_tail(), (4, 8),
                    rate_scale=0.45, des_tail_k=tail_k),
    ]
    report["blocks"]["choice"] = choice

    bt = next(r for r in choice if r["scenario"] == "burst_tail")
    q10_gain = (bt["fluid"]["cvar_plan"]["q10"]
                - bt["fluid"]["mean_plan"]["q10"])
    assert bt["diverged"], "robust gate: CVaR and mean picked one plan"
    assert q10_gain > 0.0, (
        f"robust gate: CVaR q10 {bt['fluid']['cvar_plan']['q10']} <= "
        f"mean-objective q10 {bt['fluid']['mean_plan']['q10']}")
    dt = bt["des_tail"]
    assert (dt["cvar_plan"]["min"] > dt["mean_plan"]["min"]
            and dt["cvar_plan"]["mean"] > dt["mean_plan"]["mean"]), (
        f"robust gate: exact DES does not confirm the tail ranking: {dt}")
    assert all(bt["search"][m]["agreement"] for m in ("mean", "cvar")), (
        "robust gate: screen-tier mis-ranked a final winner")
    report["gates"] = {
        "agreement_tol": AGREEMENT_TOL, "worst_rel_err": round(worst_err, 6),
        "speedup_floor": SPEEDUP_FLOOR, "speedup": thr["speedup"],
        "cvar_q10_gain": round(q10_gain, 4),
        "des_tail_confirms": True,
    }

    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"bench_robust: wrote {out} "
          f"(agreement worst err {worst_err:.2e}, "
          f"speedup {thr['speedup']}x, cvar q10 gain {q10_gain:.2f})")
    csv_rows.append(("robust_ensemble_eval",
                     thr["warm_call_s"] / thr["scenario_evals"] * 1e6,
                     f"{thr['speedup']:.0f}x_vs_des"))
    csv_rows.append(("robust_cvar_q10_gain", 0.0, f"{q10_gain:.2f}"))


if __name__ == "__main__":
    rows: List = []
    main(rows, smoke="--smoke" in sys.argv)
