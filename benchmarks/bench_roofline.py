"""Roofline table from recorded dry-run reports (results/dryrun/*.json) —
the §Roofline deliverable rendered as a benchmark."""
from __future__ import annotations

import glob
import json
import os

from repro import roofline as RL


def load_reports(report_dir="results/dryrun"):
    reps = []
    for fn in sorted(glob.glob(os.path.join(report_dir, "*__16x16.json"))):
        with open(fn) as f:
            d = json.load(f)
        if "t_compute" not in d:
            continue
        reps.append(RL.RooflineReport(**d))
    return reps


def main(csv_rows, report_dir="results/dryrun"):
    reps = load_reports(report_dir)
    if not reps:
        print(f"\n== roofline: no reports in {report_dir} "
              f"(run python -m repro.launch.dryrun --all --out {report_dir}) ==")
        return
    print(f"\n== roofline baselines ({len(reps)} cells, single pod 16x16) ==")
    print(RL.format_table(reps))
    for r in reps:
        csv_rows.append((f"roofline_{r.arch}_{r.shape}",
                         r.t_step * 1e6,
                         f"{r.bottleneck};frac={r.roofline_fraction:.3f}"))


if __name__ == "__main__":
    main([])
