"""Planet-scale hierarchical fleet benchmark (BENCH_fleet.json).

One generated 500-site / 8-region scenario (seeded synthetic fleet,
staggered per-region burst drift) carried end-to-end through the whole
stack in minutes of wall clock:

  search  — the decomposed per-region screened search (``region_search``:
            block-coordinate screening over per-region candidate spaces,
            global contention priced on full-width plans, exact-DES
            re-scoring of finalists) must beat BOTH flat anchors —
            all-DC and home-edge — on the exact DES.
  online  — the warm-started online controller (per-epoch decomposed
            ``region-exact`` re-planning seeded from the incumbent) must
            beat the best static plan, including the forecast-searched
            static on whole-horizon average rates, under drift.
  determinism — the generator is a pure function of its spec (identical
            ``to_dict`` digests) and the search is deterministic per
            seed *and per worker count*: the re-search probe runs on a
            :class:`~repro.placement.parallel.ParallelEvaluator` pool
            and must reproduce the serial winner bit-identically.
  speedup — the functional drive is prewarmed and timed apart from the
            search (``drive_wall_s``), and the pure search wall is
            compared against the recorded pre-optimization walls; the
            smoke gate asserts the delta-screening + batched-exact
            search stays >= 3x faster than recorded.

``--smoke`` runs the same 500-site scenario with a single
block-coordinate sweep and skips the oracle probe; the wall-clock and
speedup gates are asserted so CI catches scaling regressions.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Dict

from repro.online.controller import (OnlineController, OracleController,
                                     StaticController, plan_on_average_rates)
from repro.placement.parallel import ParallelEvaluator
from repro.placement.plan import PlacementPlan, ServicePlacement
from repro.region import FleetGenSpec, generate_fleet, region_search

N_SITES = 500
N_REGIONS = 8
SEED = 3
WALL_GATE_S = {True: 90.0, False: 50.0}      # smoke, full
# Walls recorded by this benchmark before the parallel + incremental
# planning hot path landed (same scenario, same box class). The recorded
# search wall included the lazily-triggered functional drive; the bench
# now prewarms the drive and reports it separately, and the speedup
# block in the JSON keeps both framings honest.
RECORDED_WALL_S = {
    True: {"search": 32.06, "total": 38.09},   # smoke (1 sweep)
    False: {"search": 33.8, "total": 93.5},    # full  (2 sweeps)
}
SEARCH_SPEEDUP_GATE = 3.0


def _out_path(smoke: bool) -> str:
    name = "BENCH_fleet_smoke.json" if smoke else "BENCH_fleet.json"
    return os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), name)


def _spec_digest(spec) -> str:
    return hashlib.sha256(
        json.dumps(spec.to_dict(), sort_keys=True).encode()).hexdigest()


def _home_edge(spec) -> PlacementPlan:
    edge_of = {q: st.name for st in spec.sites for q in st.farm_queues}
    return PlacementPlan({s.name: ServicePlacement(edge_of[s.name[:3] + "-q"])
                          for s in spec.services})


def main(csv_rows, smoke: bool = False, workers: int = 2) -> None:
    print("\n== Planet-scale hierarchical fleet: decomposed search + "
          "warm-started control ==")
    t_bench = time.perf_counter()
    gen = FleetGenSpec(n_sites=N_SITES, n_regions=N_REGIONS, seed=SEED,
                       epoch_s=300.0, drift="bursts")

    t0 = time.perf_counter()
    spec = generate_fleet(gen)
    cs = spec.compile()
    t_compile = time.perf_counter() - t0
    digest = _spec_digest(spec)
    names = [s.name for s in spec.services]

    # ---- prewarm: functional drive + screening model --------------------
    # the drive (placement-independent fire trace) is shared by every
    # phase below; prewarming it keeps the search timer honest about the
    # search itself
    t0 = time.perf_counter()
    cs.screening_model()
    t_drive = time.perf_counter() - t0

    # ---- decomposed search vs flat anchors ------------------------------
    sweeps = 1 if smoke else 2
    t0 = time.perf_counter()
    sr = region_search(cs, chips_options=(4, 8), seed=0, sweeps=sweeps)
    t_search = time.perf_counter() - t0
    t0 = time.perf_counter()
    r_dc = cs.run_plan(PlacementPlan.all_dc(names, chips=8, dvfs_f=1.0))
    r_home = cs.run_plan(_home_edge(spec))
    t_base = time.perf_counter() - t0
    beats_flat = (sr.result.vos >= r_dc.vos and sr.result.vos >= r_home.vos)
    print(f"search: vos={sr.result.vos:.1f} (all-dc {r_dc.vos:.1f}, "
          f"home-edge {r_home.vos:.1f}) screened={sr.screen['screened']} "
          f"exact-evals={sr.evaluations} wall={t_search:.1f}s "
          f"[beats-flat={beats_flat}]")

    # ---- warm-started online vs statics ---------------------------------
    true_rates = cs.true_epoch_rates()
    avg = {s: sum(r[s] for r in true_rates) / len(true_rates)
           for s in cs.order}
    t0 = time.perf_counter()
    searched_avg = plan_on_average_rates(cs.info(), avg,
                                         chips_options=(4, 8))
    statics: Dict[str, Dict] = {}
    best_static = None
    for label, plan in {"all-dc": PlacementPlan.all_dc(names, 8, 1.0),
                        "home-edge": _home_edge(spec),
                        "searched-avg": searched_avg}.items():
        r = cs.run(StaticController(plan, label=f"static:{label}"))
        statics[label] = {"vos": round(r.vos, 4)}
        if best_static is None or r.vos > best_static[1].vos:
            best_static = (label, r)
    assert best_static is not None
    r_online = cs.run(OnlineController(chips_options=(4, 8), window=1,
                                       switch_margin=0.02, calibrate=True,
                                       seed=0))
    t_online = time.perf_counter() - t0
    oracle_vos = None
    if not smoke:
        r_oracle = cs.run(OracleController(chips_options=(4, 8), seed=0))
        oracle_vos = round(r_oracle.vos, 4)
    epochs = r_online.summary()["epochs"]
    methods = sorted({e.get("forecast", {}).get("search", {}).get("method")
                      for e in epochs} - {None})
    beats_static = r_online.vos > best_static[1].vos
    conserved = r_online.ledger.conserved()
    print(f"online: vos={r_online.vos:.1f} best-static "
          f"{best_static[0]}={best_static[1].vos:.1f} "
          f"oracle={oracle_vos} methods={methods} "
          f"[beats-static={beats_static} conserved={conserved}]")

    # ---- determinism + parallel agreement -------------------------------
    # one probe covers both: a re-search on the warm engine through a
    # ParallelEvaluator pool must reproduce the serial winner (plan key
    # AND exact-DES VoS, bit-identical) for any worker count
    det_gen = _spec_digest(generate_fleet(gen)) == digest
    t0 = time.perf_counter()
    with ParallelEvaluator(cs, workers=workers, spec=spec) as pev:
        sr2 = region_search(cs, chips_options=(4, 8), seed=0,
                            sweeps=sweeps, evaluator=pev)
        pool_stats = pev.stats()
    t_par = time.perf_counter() - t0
    det_search = sr2.plan.key() == sr.plan.key()
    par_match = det_search and sr2.result.vos == sr.result.vos
    print(f"determinism: generator={det_gen} search={det_search} "
          f"parallel[workers={workers}]-matches-serial={par_match} "
          f"(pool jobs={pool_stats['parallel_jobs']}, "
          f"wall={t_par:.1f}s)")

    wall = time.perf_counter() - t_bench
    wall_ok = wall <= WALL_GATE_S[smoke]
    rec = RECORDED_WALL_S[smoke]
    search_speedup = rec["search"] / max(t_search, 1e-9)
    speedup = {
        "recorded_search_wall_s": rec["search"],
        "recorded_total_wall_s": rec["total"],
        "drive_wall_s": round(t_drive, 2),
        "search_wall_s": round(t_search, 2),
        "parallel_search_wall_s": round(t_par, 2),
        "search_speedup": round(search_speedup, 1),
        "search_speedup_incl_drive": round(
            rec["search"] / max(t_drive + t_search, 1e-9), 2),
        "total_speedup": round(rec["total"] / max(wall, 1e-9), 2),
        "note": ("recorded search wall included the lazily-triggered "
                 "functional drive, now prewarmed and reported as "
                 "drive_wall_s"),
    }
    print(f"speedup: search {rec['search']:.1f}s -> {t_search:.1f}s "
          f"({search_speedup:.1f}x; incl drive "
          f"{speedup['search_speedup_incl_drive']:.1f}x) "
          f"total {rec['total']:.1f}s -> {wall:.1f}s")
    acceptance = {
        "search_beats_flat_baselines": bool(beats_flat),
        "online_beats_best_static": bool(beats_static),
        "warm_started_region_search": bool(methods == ["region-exact"]),
        "ledger_conserved": bool(conserved),
        "generator_deterministic": bool(det_gen),
        "search_deterministic": bool(det_search),
        "parallel_matches_serial": bool(par_match),
        "search_speedup_over_gate": bool(
            search_speedup >= SEARCH_SPEEDUP_GATE),
        "wall_within_gate": bool(wall_ok),
    }
    ok = all(acceptance.values())
    cum = [e.get("forecast", {}).get("search", {}) for e in epochs]
    cum = [c for c in cum if "cum_cache_hits" in c]
    report = {
        "smoke": smoke,
        "generated": {**dataclasses.asdict(gen),
                      "sites": len(spec.sites),
                      "regions": len(spec.regions),
                      "services": len(spec.services),
                      "spec_sha256": digest},
        "search": {"vos": round(sr.result.vos, 4),
                   "all_dc_vos": round(r_dc.vos, 4),
                   "home_edge_vos": round(r_home.vos, 4),
                   "stats": sr.stats(),
                   "wall_s": round(t_search, 2),
                   "baseline_wall_s": round(t_base, 2)},
        "parallel": {"workers": workers,
                     "matches_serial": bool(par_match),
                     "wall_s": round(t_par, 2),
                     "pool": pool_stats},
        "online": {"vos": round(r_online.vos, 4),
                   "statics": statics,
                   "best_static": {"label": best_static[0],
                                   "vos": round(best_static[1].vos, 4)},
                   "oracle_vos": oracle_vos,
                   "search_methods": methods,
                   "epochs": len(epochs),
                   "cross_epoch_cache": (
                       {"cum_cache_hits": cum[-1]["cum_cache_hits"],
                        "cum_cache_misses": cum[-1]["cum_cache_misses"],
                        "cache_plans": cum[-1]["cache_plans"]}
                       if cum else None),
                   "wall_s": round(t_online, 2)},
        "determinism": {"generator": bool(det_gen),
                        "search": det_search},
        "speedup": speedup,
        "acceptance": {**acceptance, "pass": bool(ok)},
        "compile_wall_s": round(t_compile, 2),
        "drive_wall_s": round(t_drive, 2),
        "wall_s": round(wall, 2),
        "wall_gate_s": WALL_GATE_S[smoke],
    }
    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    csv_rows.append(("fleet_region_search_vos", sr.result.vos * 1e3,
                     f"{N_SITES}x{N_REGIONS}"))
    csv_rows.append(("fleet_online_vos", r_online.vos * 1e3,
                     best_static[0]))
    print(f"500-site fleet end-to-end in {wall:.1f}s "
          f"(gate {WALL_GATE_S[smoke]:.0f}s) -> "
          f"{'PASS' if ok else 'FAIL'}; wrote {out}")
    if smoke:
        # CI gate: scaling or ranking regressions fail the smoke run
        assert ok, f"fleet smoke gates failed: {acceptance}"


if __name__ == "__main__":
    import sys
    rows: list = []
    wk = 2
    if "--workers" in sys.argv:
        wk = int(sys.argv[sys.argv.index("--workers") + 1])
    main(rows, smoke="--smoke" in sys.argv, workers=wk)
