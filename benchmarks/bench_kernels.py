"""Kernel microbenches: interpret-mode wall time (semantic check only — the
TPU target numbers are the §Roofline model terms) plus the XLA-path
oracle timing for reference."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import attention_reference, flash_attention
from repro.kernels.ssd_scan import ssd_scan, ssd_scan_reference
from repro.kernels.window_agg import window_aggregate, window_aggregate_reference


def _time(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(csv_rows):
    print("\n== kernel microbench (CPU: interpret-mode vs jnp oracle) ==")
    ks = jax.random.split(jax.random.PRNGKey(0), 5)

    q = jax.random.normal(ks[0], (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 64), jnp.float32)
    t_k = _time(flash_attention, q, k, v, interpret=True)
    t_r = _time(attention_reference, q, k, v)
    print(f"flash_attention 512x512 GQA: kernel {t_k:9.0f}us  oracle {t_r:9.0f}us")
    csv_rows.append(("flash_attention_512", t_k, f"oracle={t_r:.0f}us"))

    x = jax.random.normal(ks[3], (14400, 128), jnp.float32)
    t_k = _time(window_aggregate, x, agg="max", window=180, stride=60,
                interpret=True)
    t_r = _time(window_aggregate_reference, x, agg="max", window=180,
                stride=60, iters=1)
    print(f"window_agg 14400x128 w180/s60: kernel {t_k:7.0f}us  oracle {t_r:9.0f}us")
    csv_rows.append(("window_agg_day", t_k, f"oracle={t_r:.0f}us"))

    xs = jax.random.normal(ks[4], (1, 512, 4, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (1, 512, 4)))
    A = -jnp.exp(jax.random.normal(ks[1], (4,)) * 0.5)
    B_ = jax.random.normal(ks[2], (1, 512, 1, 128)) * 0.3
    C = jax.random.normal(ks[3], (1, 512, 1, 128)) * 0.3
    t_k = _time(ssd_scan, xs, dt, A, B_, C, interpret=True)
    t_r = _time(ssd_scan_reference, xs, dt, A, B_, C)
    print(f"ssd_scan 512 L, H4 P64 N128:  kernel {t_k:9.0f}us  oracle {t_r:9.0f}us")
    csv_rows.append(("ssd_scan_512", t_k, f"oracle={t_r:.0f}us"))


if __name__ == "__main__":
    main([])
