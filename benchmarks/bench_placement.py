"""Edge↔DC placement engine benchmark: all-edge vs. all-DC vs. searched
placement across three workload scenarios, written to BENCH_placement.json.

Scenarios (each a declarative ScenarioSpec — the co-sim runs through the
unified DES-bridged engine via ``spec.compile()``):

  light_windows    — small sliding windows, gateway-class edge, per-fire
                     energy SLOs that punish composing a VDC for tiny
                     aggregations (edge should win).
  heavy_analytics  — a CNN-scoring service whose window FLOPs exceed the
                     edge device by ~10×: it must offload, but its light
                     siblings should stay on the edge (hybrid wins).
  constrained_edge — a weak, RAM-starved edge where the all-edge plan is
                     infeasible and the stream must move to the DC.

The searched placement must achieve VoS >= both baselines on at least
2 of 3 scenarios (the search runs the two-tier screened path — batch
numpy screening, exact DES on the top-K survivors plus the baseline
anchors — so this holds by construction; the bench verifies it
end-to-end and records the tier stats).
The report embeds each spec (JSON round-trip checked by scripts/ci.sh)
and the searched plan in structured form, pinning the engine against
regressions (tests/test_scenario.py).

``--calibrate`` replaces the declared flops_per_record with values
measured from Pallas kernel dry-runs (repro.scenario.calibrate) and
writes BENCH_placement_calibrated.json so the canonical declared-profile
report is never clobbered.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Optional, Sequence

from repro.placement import Evaluator, PlacementPlan, search_placement
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import (KernelCalibrator, RateSpec, ScenarioSpec,
                            scenario)


def _out_path(smoke: bool, calibrate: bool = False) -> str:
    default = ("BENCH_placement_smoke.json" if smoke
               else "BENCH_placement_calibrated.json" if calibrate
               else "BENCH_placement.json")
    return os.environ.get("BENCH_PLACEMENT_OUT", default)


@dataclasses.dataclass
class Scenario:
    name: str
    spec: ScenarioSpec
    chips_options: Sequence[int] = (4, 8)


# ---------------------------------------------------------------------------
def scenario_light_windows() -> Scenario:
    """Tiny windows at modest rate: the edge absorbs everything; a VDC
    burns ~1 kW for milliseconds per fire and loses on the energy curve."""
    spec = (scenario("light_windows")
            .horizon(600.0)
            .farm(n_things=8, seed=11, rate=RateSpec.constant(2.0))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=60)
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=1.0, hard_energy_j=60.0)
            .profile(flops_per_record=2e3)
            .service("smooth", queue="agg_out", column="value",
                     agg="mean", width_s=300, slide_s=60)
            .fed_by("agg")
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=1.0, hard_energy_j=60.0)
            .profile(flops_per_record=2e3)
            .build())
    return Scenario("light_windows", spec)


def scenario_heavy_analytics() -> Scenario:
    """One CNN-scoring service needs ~10× the edge's FLOP/s: it has to be
    offloaded onto a JIT-composed VDC, while the cheap filter/trend
    services are better left on the edge (network + VDC energy)."""
    spec = (scenario("heavy_analytics")
            .horizon(600.0)
            .site("edge", link=LinkSpec(uplink_bps=40e6, compression=0.5))
            .farm(n_things=8, seed=23, rate=RateSpec.constant(4.0))
            .service("clean", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=60, slide_s=30)
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=1.0, hard_energy_j=60.0)
            .profile(flops_per_record=2e3)
            # ~10x over the 20 GFLOP/s edge at 9600-record windows: 96 s
            .service("classify", queue="neubotspeed", column="latency_ms",
                     agg="mean", width_s=300, slide_s=60,
                     buffer_budget=16384)
            .slo(soft_latency_s=5.0, hard_latency_s=15.0,
                 soft_energy_j=80.0, hard_energy_j=400.0, gamma=2.0)
            .profile(flops_per_record=2e8, bytes_per_record=16.0,
                     operator="flash_attention")
            .service("trend", queue="clean_out", column="value",
                     agg="mean", width_s=300, slide_s=60)
            .fed_by("clean")
            .slo(soft_latency_s=2.0, hard_latency_s=10.0,
                 soft_energy_j=1.0, hard_energy_j=60.0)
            .profile(flops_per_record=2e3)
            .build())
    return Scenario("heavy_analytics", spec, chips_options=(4, 8, 16))


def scenario_constrained_edge() -> Scenario:
    """A weak, RAM-starved gateway: hosting every service's buffer budget
    exceeds device RAM (all-edge infeasible) and its record pump is slow
    enough that windows blow their latency SLO on-device."""
    b = (scenario("constrained_edge")
         .horizon(600.0)
         .site("edge", edge=EdgeSpec(throughput_rps=800.0, flops_per_s=2e9,
                                     ram_bytes=4 * 2**20),
               link=LinkSpec(uplink_bps=50e6, compression=0.5))
         .farm(n_things=12, seed=37, rate=RateSpec.constant(2.0)))
    for name, queue, column, agg, width, slide, budget in (
            ("agg", "neubotspeed", "download_speed", "max", 120, 60, 32768),
            ("pctl", "neubotspeed", "latency_ms", "mean", 300, 60, 32768),
            ("trend", "agg_out", "value", "mean", 600, 120, 16384)):
        b.service(name, queue=queue, column=column, agg=agg, width_s=width,
                  slide_s=slide, buffer_budget=budget)
        b.slo(soft_latency_s=3.0, hard_latency_s=12.0,
              soft_energy_j=40.0, hard_energy_j=400.0)
        b.profile(flops_per_record=5e3)
    b.fed_by("agg")   # trend (last declared) consumes agg's agg_out
    return Scenario("constrained_edge", b.build())


SCENARIOS = (scenario_light_windows, scenario_heavy_analytics,
             scenario_constrained_edge)


# ---------------------------------------------------------------------------
def run_scenario(sc: Scenario, calibrate: bool = False) -> Dict:
    cal: Optional[KernelCalibrator] = KernelCalibrator() if calibrate else None
    engine = sc.spec.compile(calibrator=cal)
    names = list(engine.topology)
    t0 = time.perf_counter()
    # one memoized evaluator: the search reuses the baseline co-sim runs
    ev = Evaluator(engine)
    all_edge = ev(PlacementPlan.all_edge(names))
    all_dc = ev(PlacementPlan.all_dc(names, chips=sc.chips_options[0]))
    sr = search_placement(engine, chips_options=sc.chips_options,
                          dvfs_options=(1.0, 0.7), evaluator=ev)
    dt = time.perf_counter() - t0
    searched = sr.result
    base_best = max(
        [r.vos for r in (all_edge, all_dc) if r.feasible] or [float("-inf")])
    out = {
        "spec": sc.spec.to_dict(),
        "all_edge": all_edge.summary(),
        "all_dc": all_dc.summary(),
        "searched": searched.summary(),
        "search": {**sr.stats(), "plan": sr.plan.label,
                   "assignments": sr.plan.to_dict(),
                   "chips_options": list(sc.chips_options)},
        "evaluator": ev.stats(),
        "searched_beats_baselines": bool(searched.feasible
                                         and searched.vos >= base_best),
        "wall_s": round(dt, 2),
    }
    if cal is not None:
        out["calibration"] = cal.report()
    return out


def main(csv_rows, smoke: bool = False, calibrate: bool = False) -> None:
    print("\n== Edge↔DC placement: all-edge vs all-DC vs searched ==")
    report: Dict = {"scenarios": {}, "smoke": smoke, "calibrated": calibrate}
    wins = 0
    for make in (SCENARIOS[:1] if smoke else SCENARIOS):
        sc = make()
        if smoke:
            sc.spec = dataclasses.replace(sc.spec, horizon_s=300.0)
        res = run_scenario(sc, calibrate=calibrate)
        report["scenarios"][sc.name] = res
        wins += res["searched_beats_baselines"]

        def _vos(d):
            return "infeasible" if not d["feasible"] else f"{d['vos']:.2f}"
        print(f"{sc.name:18s} all-edge={_vos(res['all_edge']):>10s} "
              f"all-dc={_vos(res['all_dc']):>10s} "
              f"searched={_vos(res['searched']):>10s}  "
              f"[{res['search']['evaluations']} evals, "
              f"{res['search']['method']}]")
        print(f"{'':18s} plan: {res['search']['plan']}")
        if calibrate:
            for c in res.get("calibration", ()):
                print(f"{'':18s} calibrated {c['operator']}/{c['agg']} "
                      f"m={c['m']}: {c['flops_per_record']:.1f} "
                      f"flops/record ({c['source']})")
        sv = res["searched"]
        csv_rows.append((f"placement_{sc.name}_vos",
                         0.0 if sv["vos"] is None else sv["vos"] * 1e3,
                         res["search"]["plan"]))
    need = 1 if smoke else 2
    report["acceptance"] = {"wins": wins, "of": len(report["scenarios"]),
                            "pass": wins >= need}
    out = _out_path(smoke, calibrate)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    status = "PASS" if wins >= need else "FAIL"
    print(f"searched >= both baselines on {wins}/{len(report['scenarios'])} "
          f"scenarios -> {status}; wrote {out}")


if __name__ == "__main__":
    import sys
    main([], smoke="--smoke" in sys.argv, calibrate="--calibrate" in sys.argv)
