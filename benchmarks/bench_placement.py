"""Edge↔DC placement engine benchmark: all-edge vs. all-DC vs. searched
placement across three workload scenarios, written to BENCH_placement.json.

Scenarios:
  light_windows    — small sliding windows, gateway-class edge, per-fire
                     energy SLOs that punish composing a VDC for tiny
                     aggregations (edge should win).
  heavy_analytics  — a CNN-scoring service whose window FLOPs exceed the
                     edge device by ~10×: it must offload, but its light
                     siblings should stay on the edge (hybrid wins).
  constrained_edge — a weak, RAM-starved edge where the all-edge plan is
                     infeasible and the stream must move to the DC.

The searched placement must achieve VoS >= both baselines on at least
2 of 3 scenarios (it searches a superset of both, so with exhaustive
search this holds by construction — the bench verifies it end-to-end).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, Sequence, Tuple

from repro.pipeline import (Broker, NeubotFarm, Pipeline, ServiceConfig,
                            StreamService, WindowSpec)
from repro.placement import (CoSimConfig, CoSimulator, EdgeSpec, Evaluator,
                             LinkSpec, PlacementPlan, ServiceProfile,
                             ServiceSLO, search_placement)

def _out_path(smoke: bool) -> str:
    default = "BENCH_placement_smoke.json" if smoke else "BENCH_placement.json"
    return os.environ.get("BENCH_PLACEMENT_OUT", default)


def _svc(broker, name, queue, column, agg, width, slide, budget=4096):
    return StreamService(ServiceConfig(
        name=name, queue=queue, column=column, agg=agg,
        window=WindowSpec("sliding", width_s=width, slide_s=slide),
        buffer_budget=budget), broker)


@dataclasses.dataclass
class Scenario:
    name: str
    build: Callable[[], Pipeline]
    profiles: Dict[str, ServiceProfile]
    cfg: CoSimConfig
    chips_options: Sequence[int] = (4, 8)


# ---------------------------------------------------------------------------
def scenario_light_windows() -> Scenario:
    """Tiny windows at modest rate: the edge absorbs everything; a VDC
    burns ~1 kW for milliseconds per fire and loses on the energy curve."""
    def build():
        b = Broker()
        pipe = Pipeline(b)
        pipe.add_farm(NeubotFarm(b, n_things=8, rate_hz=2.0, seed=11))
        agg = _svc(b, "agg", "neubotspeed", "download_speed", "max", 120, 60)
        smooth = _svc(b, "smooth", "agg_out", "value", "mean", 300, 60)
        pipe.add_service(agg).add_service(smooth)
        pipe.connect(agg, "agg_out")
        return pipe

    slo = ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                     soft_energy_j=1.0, hard_energy_j=60.0)
    profiles = {"agg": ServiceProfile(slo, flops_per_record=2e3),
                "smooth": ServiceProfile(slo, flops_per_record=2e3)}
    return Scenario("light_windows", build, profiles,
                    CoSimConfig(horizon_s=600.0))


def scenario_heavy_analytics() -> Scenario:
    """One CNN-scoring service needs ~10× the edge's FLOP/s: it has to be
    offloaded onto a JIT-composed VDC, while the cheap filter/trend
    services are better left on the edge (network + VDC energy)."""
    def build():
        b = Broker()
        pipe = Pipeline(b)
        pipe.add_farm(NeubotFarm(b, n_things=8, rate_hz=4.0, seed=23))
        clean = _svc(b, "clean", "neubotspeed", "download_speed", "max",
                     60, 30)
        classify = _svc(b, "classify", "neubotspeed", "latency_ms", "mean",
                        300, 60, budget=16384)
        trend = _svc(b, "trend", "clean_out", "value", "mean", 300, 60)
        pipe.add_service(clean).add_service(classify).add_service(trend)
        pipe.connect(clean, "clean_out")
        return pipe

    light = ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                       soft_energy_j=1.0, hard_energy_j=60.0)
    heavy = ServiceSLO(soft_latency_s=5.0, hard_latency_s=15.0,
                       soft_energy_j=80.0, hard_energy_j=400.0, gamma=2.0)
    profiles = {
        "clean": ServiceProfile(light, flops_per_record=2e3),
        "trend": ServiceProfile(light, flops_per_record=2e3),
        # ~10x over the 20 GFLOP/s edge at 9600-record windows: 96 s
        "classify": ServiceProfile(heavy, flops_per_record=2e8,
                                   bytes_per_record=16.0),
    }
    cfg = CoSimConfig(horizon_s=600.0,
                      link=LinkSpec(uplink_bps=40e6, compression=0.5))
    return Scenario("heavy_analytics", build, profiles, cfg,
                    chips_options=(4, 8, 16))


def scenario_constrained_edge() -> Scenario:
    """A weak, RAM-starved gateway: hosting every service's buffer budget
    exceeds device RAM (all-edge infeasible) and its record pump is slow
    enough that windows blow their latency SLO on-device."""
    def build():
        b = Broker()
        pipe = Pipeline(b)
        pipe.add_farm(NeubotFarm(b, n_things=12, rate_hz=2.0, seed=37))
        agg = _svc(b, "agg", "neubotspeed", "download_speed", "max",
                   120, 60, budget=32768)
        pctl = _svc(b, "pctl", "neubotspeed", "latency_ms", "mean",
                    300, 60, budget=32768)
        trend = _svc(b, "trend", "agg_out", "value", "mean", 600, 120,
                     budget=16384)
        pipe.add_service(agg).add_service(pctl).add_service(trend)
        pipe.connect(agg, "agg_out")
        return pipe

    slo = ServiceSLO(soft_latency_s=3.0, hard_latency_s=12.0,
                     soft_energy_j=40.0, hard_energy_j=400.0)
    profiles = {n: ServiceProfile(slo, flops_per_record=5e3)
                for n in ("agg", "pctl", "trend")}
    edge = EdgeSpec(throughput_rps=800.0, flops_per_s=2e9,
                    ram_bytes=4 * 2**20)
    cfg = CoSimConfig(horizon_s=600.0, edge=edge,
                      link=LinkSpec(uplink_bps=50e6, compression=0.5))
    return Scenario("constrained_edge", build, profiles, cfg)


SCENARIOS = (scenario_light_windows, scenario_heavy_analytics,
             scenario_constrained_edge)


# ---------------------------------------------------------------------------
def run_scenario(sc: Scenario) -> Dict:
    cosim = CoSimulator(sc.build, sc.profiles, sc.cfg)
    names = list(cosim.topology)
    t0 = time.perf_counter()
    # one memoized evaluator: the search reuses the baseline co-sim runs
    ev = Evaluator(cosim)
    all_edge = ev(PlacementPlan.all_edge(names))
    all_dc = ev(PlacementPlan.all_dc(names, chips=sc.chips_options[0]))
    sr = search_placement(cosim, chips_options=sc.chips_options,
                          dvfs_options=(1.0, 0.7), evaluator=ev)
    dt = time.perf_counter() - t0
    searched = sr.result
    base_best = max(
        [r.vos for r in (all_edge, all_dc) if r.feasible] or [float("-inf")])
    return {
        "all_edge": all_edge.summary(),
        "all_dc": all_dc.summary(),
        "searched": searched.summary(),
        "search": {"method": sr.method, "evaluations": sr.evaluations,
                   "plan": sr.plan.label},
        "searched_beats_baselines": bool(searched.feasible
                                         and searched.vos >= base_best),
        "wall_s": round(dt, 2),
    }


def main(csv_rows, smoke: bool = False) -> None:
    print("\n== Edge↔DC placement: all-edge vs all-DC vs searched ==")
    report: Dict = {"scenarios": {}, "smoke": smoke}
    wins = 0
    for make in (SCENARIOS[:1] if smoke else SCENARIOS):
        sc = make()
        if smoke:
            sc.cfg.horizon_s = 300.0    # reduced trace length
        res = run_scenario(sc)
        report["scenarios"][sc.name] = res
        wins += res["searched_beats_baselines"]

        def _vos(d):
            return "infeasible" if not d["feasible"] else f"{d['vos']:.2f}"
        print(f"{sc.name:18s} all-edge={_vos(res['all_edge']):>10s} "
              f"all-dc={_vos(res['all_dc']):>10s} "
              f"searched={_vos(res['searched']):>10s}  "
              f"[{res['search']['evaluations']} evals, "
              f"{res['search']['method']}]")
        print(f"{'':18s} plan: {res['search']['plan']}")
        sv = res["searched"]
        csv_rows.append((f"placement_{sc.name}_vos",
                         0.0 if sv["vos"] is None else sv["vos"] * 1e3,
                         res["search"]["plan"]))
    need = 1 if smoke else 2
    report["acceptance"] = {"wins": wins, "of": len(report["scenarios"]),
                            "pass": wins >= need}
    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    status = "PASS" if wins >= need else "FAIL"
    print(f"searched >= both baselines on {wins}/{len(report['scenarios'])} "
          f"scenarios -> {status}; wrote {out}")


if __name__ == "__main__":
    import sys
    main([], smoke="--smoke" in sys.argv)
