"""Placement-search performance benchmark: legacy exact-only search vs
the two-tier screened search, written to BENCH_search.json.

Measures end-to-end search wall-clock and evaluation counts on the
three recorded placement scenarios (the exact workloads pinned by
BENCH_placement.json) and on ``big_fleet`` — a 6-gateway × 8-service
fleet whose plan space (≈10^8) dwarfs ``exhaustive_limit``, where the
legacy path must fall back to DES-driven greedy descent while the
screened search scores thousands of candidates per numpy pass and
co-simulates only the top-K survivors.

Acceptance (asserted into the report):
  * every recorded scenario: screened best-plan VoS == exact best-plan
    VoS, and wall-clock speedup >= 5x;
  * big_fleet: the screened search completes and its searched VoS >=
    the all-edge / all-DC baselines.

``--smoke`` runs one reduced-horizon scenario plus a shrunken fleet and
*asserts* screened-vs-exact best-plan agreement (the CI step in
scripts/ci.sh).

The functional drive is warmed before timing either path: it is
placement-independent and shared by both tiers by design (the engine
drives the dataflow once per scenario). The screening-model build is
*not* warmed — it is part of the screened path's cost and is included
in its wall-clock.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys
import time
from typing import Dict, Optional, Sequence

# allow standalone `python benchmarks/bench_search_perf.py` (script dir
# on sys.path, repo root not — same bootstrap as benchmarks/run.py)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks.bench_placement import (SCENARIOS as PLACEMENT_SCENARIOS,  # noqa: E402
                                        Scenario)
from repro.placement import Evaluator, PlacementPlan
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.placement.search import search_placement
from repro.scenario import RateSpec, scenario

_LIGHT_SLO = dict(soft_latency_s=3.0, hard_latency_s=12.0,
                  soft_energy_j=1.0, hard_energy_j=60.0)


def scenario_big_fleet(horizon_s: float = 900.0, n_sites: int = 6) -> Scenario:
    """6 heterogeneous gateways, 3 pinned farms, 8 services: the light
    aggregation/trend services want to stay near their farms (tight
    per-fire energy SLOs punish composing a VDC for tiny windows, and
    no single gateway can host all of them without saturating), while
    the CNN-scoring ``classify`` outgrows every edge box by >10x and
    must offload — the good plans are spread hybrids that neither the
    all-edge nor the all-DC baseline reaches."""
    b = scenario("big_fleet").horizon(horizon_s)
    for i in range(n_sites):
        b.site(f"gw{i}",
               edge=EdgeSpec(name=f"gw{i}",
                             flops_per_s=(8e9 if i % 2 else 20e9),
                             throughput_rps=25_000.0 + 10_000.0 * i,
                             ram_bytes=(32 + 16 * i) * 2**20),
               link=LinkSpec(uplink_bps=15e6 + 5e6 * i,
                             rtt_s=0.030 + 0.005 * i, compression=0.5),
               user=(i == 0))
    b.farm(queue="sensor_a", n_things=8, seed=5,
           rate=RateSpec.constant(3.0), site="gw0")
    b.farm(queue="sensor_b", n_things=6, seed=7,
           rate=RateSpec.constant(4.0), site="gw2")
    b.farm(queue="sensor_c", n_things=6, seed=9,
           rate=RateSpec.constant(2.0), site="gw4")
    light = (("agg_a", "sensor_a", "download_speed", "max", 120, 30),
             ("agg_b", "sensor_b", "latency_ms", "mean", 120, 30),
             ("agg_c", "sensor_c", "download_speed", "max", 180, 60))
    for name, q, col, agg, w, s in light:
        b.service(name, queue=q, column=col, agg=agg, width_s=w, slide_s=s)
        b.slo(**_LIGHT_SLO).profile(flops_per_record=2e3)
    b.service("classify", queue="sensor_b", column="download_speed",
              agg="mean", width_s=300, slide_s=60, buffer_budget=16384)
    b.slo(soft_latency_s=5.0, hard_latency_s=15.0, soft_energy_j=80.0,
          hard_energy_j=400.0, gamma=2.0)
    b.profile(flops_per_record=2e8, bytes_per_record=16.0,
              operator="flash_attention")
    b.service("trend_a", queue="agg_a_out", column="value", agg="mean",
              width_s=300, slide_s=60).fed_by("agg_a")
    b.slo(**_LIGHT_SLO).profile(flops_per_record=2e3)
    b.service("trend_b", queue="agg_b_out", column="value", agg="mean",
              width_s=300, slide_s=60).fed_by("agg_b")
    b.slo(**_LIGHT_SLO).profile(flops_per_record=2e3)
    b.service("fuse", queue="mix", column="value", agg="mean",
              width_s=240, slide_s=120).fed_by("trend_a", "trend_b")
    b.slo(**_LIGHT_SLO).profile(flops_per_record=4e3)
    b.service("report", queue="fuse_out", column="value", agg="mean",
              width_s=480, slide_s=120).fed_by("fuse")
    b.slo(**_LIGHT_SLO).profile(flops_per_record=1e3)
    return Scenario("big_fleet", b.build(), chips_options=(4, 8))


def _out_path(smoke: bool) -> str:
    default = "BENCH_search_smoke.json" if smoke else "BENCH_search.json"
    return os.environ.get("BENCH_SEARCH_OUT", default)


# ---------------------------------------------------------------------------
def run_recorded(sc: Scenario, dvfs: Sequence[float] = (1.0, 0.7),
                 reps: int = 3) -> Dict:
    """Time old (exact-only) vs new (screened) search on one recorded
    scenario, best of ``reps`` repetitions per path. Every repetition
    gets its *own* freshly compiled engine (so neither path inherits
    the other's — or an earlier rep's — warmed cost/ledger caches)
    with only the shared, placement-independent functional drive
    pre-warmed; the screening-model build is charged to the new path."""
    wall_old = wall_new = float("inf")
    sr_old = sr_new = None
    for _ in range(reps):
        engine = sc.spec.compile()
        engine._ensure_driven()
        ev_old = Evaluator(engine)
        t0 = time.perf_counter()
        r = search_placement(engine, chips_options=sc.chips_options,
                             dvfs_options=dvfs, evaluator=ev_old,
                             screen=False)
        wall_old = min(wall_old, time.perf_counter() - t0)
        assert sr_old is None or r.result.vos == sr_old.result.vos
        sr_old = r

        engine = sc.spec.compile()
        engine._ensure_driven()
        ev_new = Evaluator(engine)
        t0 = time.perf_counter()
        r = search_placement(engine, chips_options=sc.chips_options,
                             dvfs_options=dvfs, evaluator=ev_new)
        wall_new = min(wall_new, time.perf_counter() - t0)
        assert sr_new is None or r.result.vos == sr_new.result.vos
        sr_new = r

    identical = abs(sr_old.result.vos - sr_new.result.vos) < 1e-9
    return {
        "old": {"wall_s": round(wall_old, 4), **sr_old.stats(),
                "plan": sr_old.plan.label,
                "vos": round(sr_old.result.vos, 4)},
        "new": {"wall_s": round(wall_new, 4), **sr_new.stats(),
                "plan": sr_new.plan.label,
                "vos": round(sr_new.result.vos, 4)},
        "speedup": round(wall_old / max(wall_new, 1e-9), 2),
        "identical_best_vos": bool(identical),
    }


def run_big_fleet(sc: Scenario, run_old: bool = True) -> Dict:
    """Screened search on the fleet-scale scenario plus the exact
    all-edge / all-DC baselines; optionally also the legacy DES-greedy
    path (the 'currently intractable' number)."""
    spec = sc.spec
    names = list(spec.service_names())
    sites = tuple(s.name for s in spec.sites)

    # baselines on their own engine so neither timed path inherits a
    # warmed cost model / ledger skeleton from them
    engine_base = spec.compile()
    ev = Evaluator(engine_base)
    baselines = {}
    for lbl, plan in (("all_edge", PlacementPlan.all_edge(names,
                                                          site=sites[0])),
                      ("all_dc", PlacementPlan.all_dc(
                          names, chips=sc.chips_options[0]))):
        r = ev(plan)
        baselines[lbl] = {"vos": round(r.vos, 4) if r.feasible else None,
                          "feasible": r.feasible}

    engine_new = spec.compile()
    engine_new._ensure_driven()
    ev_new = Evaluator(engine_new)
    t0 = time.perf_counter()
    sr = search_placement(engine_new, chips_options=sc.chips_options,
                          dvfs_options=(1.0, 0.7), evaluator=ev_new,
                          edge_sites=sites)
    wall_new = time.perf_counter() - t0

    base_best = max([b["vos"] for b in baselines.values()
                     if b["vos"] is not None] or [float("-inf")])
    out = {
        "services": len(names), "sites": len(sites),
        "baselines": baselines,
        "new": {"wall_s": round(wall_new, 4), **sr.stats(),
                "plan": sr.plan.label, "vos": round(sr.result.vos, 4)},
        "searched_beats_baselines": bool(sr.result.feasible
                                         and sr.result.vos >= base_best),
    }
    if run_old:
        engine_old = spec.compile()
        engine_old._ensure_driven()
        ev_old = Evaluator(engine_old)
        t0 = time.perf_counter()
        sr_old = search_placement(engine_old,
                                  chips_options=sc.chips_options,
                                  dvfs_options=(1.0, 0.7),
                                  evaluator=ev_old, edge_sites=sites,
                                  screen=False)
        wall_old = time.perf_counter() - t0
        out["old"] = {"wall_s": round(wall_old, 4), **sr_old.stats(),
                      "plan": sr_old.plan.label,
                      "vos": round(sr_old.result.vos, 4)}
        out["speedup"] = round(wall_old / max(wall_new, 1e-9), 2)
        out["new_vos_ge_old"] = bool(sr.result.vos >= sr_old.result.vos
                                     - 1e-9)
    return out


def main(csv_rows, smoke: bool = False) -> None:
    print("\n== Placement search: exact-only vs two-tier screened ==")
    report: Dict = {"scenarios": {}, "smoke": smoke}
    speedups, identical = [], []
    makes = PLACEMENT_SCENARIOS[:1] if smoke else PLACEMENT_SCENARIOS
    for make in makes:
        sc = make()
        if smoke:
            sc.spec = dataclasses.replace(sc.spec, horizon_s=300.0)
        res = run_recorded(sc, reps=1 if smoke else 3)
        report["scenarios"][sc.name] = res
        speedups.append(res["speedup"])
        identical.append(res["identical_best_vos"])
        print(f"{sc.name:18s} old {res['old']['wall_s']:7.3f}s "
              f"({res['old']['evaluations']} evals)  "
              f"new {res['new']['wall_s']:7.3f}s "
              f"({res['new']['evaluations']} evals)  "
              f"{res['speedup']:5.1f}x  identical_vos="
              f"{res['identical_best_vos']}")
        csv_rows.append((f"search_{sc.name}_speedup",
                         res["speedup"] * 1e3, res["new"]["method"]))

    big = scenario_big_fleet(horizon_s=450.0 if smoke else 900.0,
                             n_sites=6)
    res = run_big_fleet(big, run_old=not smoke)
    report["scenarios"]["big_fleet"] = res
    msg = (f"big_fleet          new {res['new']['wall_s']:7.3f}s "
           f"({res['new']['evaluations']} evals, "
           f"{res['new']['screen']['screened']} screened of "
           f"{res['new']['screen']['space']:.1e} space)  "
           f"searched>=baselines={res['searched_beats_baselines']}")
    if "old" in res:
        msg += (f"  [old greedy {res['old']['wall_s']:.1f}s/"
                f"{res['old']['evaluations']} evals -> "
                f"{res['speedup']:.1f}x]")
    print(msg)
    csv_rows.append(("search_big_fleet_wall_ms",
                     res["new"]["wall_s"] * 1e3, res["new"]["plan"][:40]))

    need = 1.0 if smoke else 5.0   # smoke halves horizons; assert agreement
    ok = (all(identical) and all(s >= need for s in speedups)
          and res["searched_beats_baselines"])
    report["acceptance"] = {
        "identical_best_vos": all(identical),
        "min_speedup": min(speedups) if speedups else None,
        "speedup_threshold": need,
        "big_fleet_searched_beats_baselines":
            res["searched_beats_baselines"],
        "pass": bool(ok),
    }
    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"search bench: {'PASS' if ok else 'FAIL'}; wrote {out}")
    assert all(identical), \
        "screened search best-plan VoS diverged from exact search"
    assert res["searched_beats_baselines"], \
        "big_fleet screened search lost to a baseline plan"
    if not smoke:
        assert ok, report["acceptance"]


if __name__ == "__main__":
    import sys
    main([], smoke="--smoke" in sys.argv)
