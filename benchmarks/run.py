"""Benchmark harness — one module per paper table/figure.

  Fig. 4  -> bench_value_heuristics   (VPTR vs Simple value gains)
  Fig. 5  -> bench_power_capping      (power caps, sim vs emulation)
  §3 use case -> bench_pipeline       (Neubot queries, edge vs VDC offload)
  placement -> bench_placement        (edge↔DC plans, BENCH_placement.json)
  kernels -> bench_kernels            (Pallas vs jnp-oracle microbench)
  §Roofline -> bench_roofline         (dry-run derived terms per cell)

Prints ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig5,pipeline,placement,"
                         "kernels,roofline")
    ap.add_argument("--no-emulation", action="store_true")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    csv_rows: list = []
    failures = []

    def run(tag, fn, *a, **kw):
        if want is not None and tag not in want:
            return
        try:
            fn(*a, **kw)
        except Exception as e:  # keep the harness going, report at the end
            failures.append((tag, repr(e)))
            traceback.print_exc()

    from benchmarks import (bench_kernels, bench_pipeline, bench_placement,
                            bench_roofline, bench_value_heuristics,
                            bench_power_capping)
    run("fig4", bench_value_heuristics.main, csv_rows)
    run("fig5", bench_power_capping.main, csv_rows,
        emulate=not args.no_emulation)
    run("pipeline", bench_pipeline.main, csv_rows)
    run("placement", bench_placement.main, csv_rows)
    run("kernels", bench_kernels.main, csv_rows)
    run("roofline", bench_roofline.main, csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print("\nBENCH FAILURES:", failures, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
