"""Benchmark harness — one module per paper table/figure.

  Fig. 4  -> bench_value_heuristics   (VPTR vs Simple value gains)
  Fig. 5  -> bench_power_capping      (power caps, sim vs emulation)
  §3 use case -> bench_pipeline       (Neubot queries, edge vs VDC offload)
  placement -> bench_placement        (edge↔DC plans, BENCH_placement.json)
  online  -> bench_online             (fleet controller, BENCH_online.json)
  search  -> bench_search_perf        (exact vs screened, BENCH_search.json)
  robust  -> bench_robust             (fluid ensemble vs DES, CVaR-vs-mean
                                       plan choice, BENCH_robust.json)
  serve   -> bench_serve              (engine vs live runtime sim-to-real
                                       gap, BENCH_serve.json)
  fleet   -> bench_fleet              (500-site hierarchical fleet:
                                       decomposed region search +
                                       warm-started online control,
                                       BENCH_fleet.json)
  chaos   -> bench_chaos              (unplanned mid-epoch faults vs the
                                       chaos-aware controller,
                                       BENCH_chaos.json)
  kernels -> bench_kernels            (Pallas vs jnp-oracle microbench)
  §Roofline -> bench_roofline         (dry-run derived terms per cell)

``--smoke`` is the CI fast path: the stream benches (placement, online)
run 1 scenario each at reduced trace length, writing *_smoke.json so the
committed full reports aren't clobbered. Keeps the benches from rotting
without burning CI minutes.

Prints ``name,us_per_call,derived`` CSV at the end.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

# allow `python benchmarks/run.py` (script dir on sys.path, repo root not)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig5,pipeline,placement,online,"
                         "search,robust,serve,fleet,chaos,kernels,roofline")
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI mode: 1 scenario per stream bench at "
                         "reduced trace length")
    ap.add_argument("--calibrate", action="store_true",
                    help="placement bench: measure flops_per_record from "
                         "Pallas kernel dry-runs (repro.scenario.calibrate) "
                         "instead of the declared profile values")
    ap.add_argument("--no-emulation", action="store_true")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None
    if (args.smoke or args.calibrate) and want is None:
        want = {"placement", "online", "search", "robust", "serve",
                "fleet", "chaos"} if args.smoke else {"placement"}

    csv_rows: list = []
    failures = []

    def run(tag, fn, *a, **kw):
        if want is not None and tag not in want:
            return
        try:
            fn(*a, **kw)
        except Exception as e:  # keep the harness going, report at the end
            failures.append((tag, repr(e)))
            traceback.print_exc()

    from benchmarks import (bench_chaos, bench_fleet, bench_kernels,
                            bench_online, bench_pipeline, bench_placement,
                            bench_robust, bench_roofline, bench_search_perf,
                            bench_serve, bench_value_heuristics,
                            bench_power_capping)
    run("fig4", bench_value_heuristics.main, csv_rows)
    run("fig5", bench_power_capping.main, csv_rows,
        emulate=not args.no_emulation)
    run("pipeline", bench_pipeline.main, csv_rows)
    run("placement", bench_placement.main, csv_rows, smoke=args.smoke,
        calibrate=args.calibrate)
    run("online", bench_online.main, csv_rows, smoke=args.smoke)
    run("search", bench_search_perf.main, csv_rows, smoke=args.smoke)
    run("robust", bench_robust.main, csv_rows, smoke=args.smoke)
    run("serve", bench_serve.main, csv_rows, smoke=args.smoke)
    run("fleet", bench_fleet.main, csv_rows, smoke=args.smoke)
    run("chaos", bench_chaos.main, csv_rows, smoke=args.smoke)
    run("kernels", bench_kernels.main, csv_rows)
    run("roofline", bench_roofline.main, csv_rows)

    print("\nname,us_per_call,derived")
    for name, us, derived in csv_rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        print("\nBENCH FAILURES:", failures, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
