"""Chaos & migration benchmark: unplanned mid-epoch faults vs the
chaos-aware controller → BENCH_chaos.json.

Unlike ``bench_online``'s scheduled outages (forecastable maintenance
windows every controller reads through ``down_oracle``), the faults
here ride the spec's :class:`~repro.chaos.spec.ChaosSpec`: the engine
realizes them physically mid-epoch and no controller sees them coming.
Static plans ride through the fault; the
:class:`~repro.chaos.controller.ChaosController` reacts — emergency
re-planning at realized fault boundaries (``decide_fault``) with
checkpoint-aware cold/live migrations, and telemetry-steered
forecasting (``partitioned_now`` link-death, ``link_secs_window`` →
straggler slowdown) at epoch boundaries.

Scenarios (2 edge gateways + the DC; the fault always hits the site
the fault-free optimum depends on):

  crash_during_burst — the strong gateway hosts the service through a
                   flash burst and crashes mid-burst. Pinning to it
                   blocks every fire until recovery; the weak gateway
                   is latency-marginal at burst rates; the DC drops
                   fires at burst rates. The chaos controller
                   evacuates to the farm gateway at the realized crash
                   boundary (cold-local: replay from the origin log,
                   zero wire) and migrates back on the heal event
                   (cold: checkpoint bytes over the wire + replay).
  partition_heal — the farm gateway's uplink partitions mid-run while
                   its device keeps working. All-DC offload stalls for
                   the whole partition; pinning local pays the slow
                   edge fire forever. The chaos controller flips local
                   when ``decide_fault`` observes the partition (the
                   forecast marks the link dead) and offloads again
                   after the heal.
  straggler_degrade — the farm uplink degrades to ``factor``×
                   serialization without dying. Invisible to
                   ``down_now``/``partitioned_now``: only the per-site
                   uplink seconds in ``link_secs_window`` give it away,
                   after the straggler monitor accumulates evidence —
                   the controller flips local two epochs into the
                   degradation (the honest price of observing through
                   telemetry alone) and stays local: once idle, the
                   sick link emits no telemetry that could clear it.

Acceptance (ISSUE 10, asserted here in both modes):
  * the chaos controller beats EVERY static plan on every scenario;
  * exactly-once arm: record conservation holds and no ``duplicates``
    key appears; at-least-once arm: the ledger's ``duplicates`` equals
    the replay counts the migration digests declared (never silently
    lost);
  * two same-seed chaos runs are bit-identical (vos, ledger totals,
    full epoch meta);
  * chaos stays opt-in: re-running a recorded chaos-free benchmark
    scenario (bench_online diurnal_tide, static all-dc arm) reproduces
    the committed BENCH_online*.json numbers bit-identically.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Sequence

from repro.chaos import (ChaosController, ChaosSpec, LinkStraggle,
                         Partition, SiteCrash)
from repro.online import StaticController, plan_on_average_rates
from repro.placement import PlacementPlan, ServicePlacement
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import RateSpec, ScenarioBuilder, ScenarioSpec, scenario


def _out_path(smoke: bool) -> str:
    default = "BENCH_chaos_smoke.json" if smoke else "BENCH_chaos.json"
    return os.environ.get("BENCH_CHAOS_OUT", default)


@dataclasses.dataclass
class ChaosScenario:
    name: str
    spec: ScenarioSpec                  # carries the ChaosSpec
    prior_rates: Dict[str, float]
    static_plans: Dict[str, PlacementPlan]
    chips_options: Sequence[int] = (4,)
    # an extra arm re-run under at_least_once for the duplicates gate
    ledger_arm: bool = False


# ---------------------------------------------------------------------------
# Shared fabric
# ---------------------------------------------------------------------------
def _fabric(name: str, a_rps: float, b_rps: float, uplink_a_bps: float,
            uplink_b_bps: float = 12e3) -> ScenarioBuilder:
    """Ingest-bound gateways on the tide fabric; per-scenario record
    pumps (``*_rps``) set which site is latency-marginal."""
    return (scenario(name)
            .site("gw-a", edge=EdgeSpec(name="gw-a", throughput_rps=a_rps,
                                        active_power_w=1.0,
                                        energy_per_record_j=50e-6),
                  link=LinkSpec(uplink_bps=uplink_a_bps, downlink_bps=2e6,
                                rtt_s=0.040, record_bytes=64.0,
                                compression=0.25))
            .site("gw-b", edge=EdgeSpec(name="gw-b", throughput_rps=b_rps,
                                        flops_per_s=15e9, active_power_w=1.2,
                                        energy_per_record_j=60e-6),
                  link=LinkSpec(uplink_bps=uplink_b_bps, downlink_bps=2e6,
                                rtt_s=0.060, record_bytes=64.0,
                                compression=0.25)))


def _agg_service(b: ScenarioBuilder, soft_energy_j: float = 0.3,
                 hard_energy_j: float = 3.0) -> ScenarioBuilder:
    (b.service("agg", queue="neubotspeed", column="download_speed",
               agg="max", width_s=120, slide_s=30, buffer_budget=8192)
     .slo(soft_latency_s=2.0, hard_latency_s=10.0,
          soft_energy_j=soft_energy_j, hard_energy_j=hard_energy_j)
     .profile(flops_per_record=2e3))
    return b


def _statics() -> Dict[str, PlacementPlan]:
    return {
        "pin-gw-a": PlacementPlan.all_edge(["agg"], site="gw-a"),
        "pin-gw-b": PlacementPlan.all_edge(["agg"], site="gw-b"),
        "all-dc": PlacementPlan({"agg": ServicePlacement("dc", chips=4)}),
    }


def scenario_crash_during_burst(smoke: bool = False) -> ChaosScenario:
    """Strong gw-b hosts through the burst; it crashes mid-burst."""
    horizon = 1800.0 if smoke else 3600.0
    # burst starts mid-epoch so the next boundary's realized-rate
    # estimate flips the controller onto strong gw-b BEFORE it crashes
    burst = (450.0, 1200.0) if smoke else (1350.0, 2400.0)
    crash = (750.0, 1050.0) if smoke else (1650.0, 2250.0)
    ch = ChaosSpec(crashes=(SiteCrash(site="gw-b", at_s=crash[0],
                                      recover_s=crash[1]),),
                   migration="cold", ledger_mode="exactly_once")
    b = (_agg_service(_fabric("crash_during_burst", a_rps=1600.0,
                              b_rps=6000.0, uplink_a_bps=200e3),
                      soft_energy_j=1.0, hard_energy_j=8.0)
         .horizon(horizon).epochs(300.0).dc(dc_step_floor_s=0.5)
         .farm(n_things=8, seed=11, site="gw-a",
               rate=RateSpec.bursts(2.0, 11.0, [burst]))
         .chaos(ch))
    return ChaosScenario("crash_during_burst", b.build(),
                         prior_rates={"agg": 8.0},
                         static_plans=_statics(), ledger_arm=True)


def scenario_partition_heal(smoke: bool = False) -> ChaosScenario:
    """Farm gateway partitions: offload stalls, local work survives.
    The DC is the fault-free optimum; pinning local pays a slow,
    power-hungry edge fire forever; all-DC defers every fire for the
    whole partition. The chaos controller flips local at the observed
    partition (cold-local: replay from the origin log, zero wire) and
    offloads again at the heal."""
    horizon = 1800.0 if smoke else 3600.0
    part = (630.0, 1230.0) if smoke else (1530.0, 2430.0)
    ch = ChaosSpec(partitions=(Partition(site="gw-a", at_s=part[0],
                                         heal_s=part[1]),),
                   migration="cold", ledger_mode="exactly_once")
    b = (_agg_service(_fabric("partition_heal", a_rps=825.0, b_rps=1000.0,
                              uplink_a_bps=1e6),
                      soft_energy_j=3.0, hard_energy_j=60.0)
         .horizon(horizon).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .farm(n_things=8, seed=17, site="gw-a",
               rate=RateSpec.constant(8.0))
         .chaos(ch))
    return ChaosScenario("partition_heal", b.build(),
                         prior_rates={"agg": 64.0},
                         static_plans=_statics())


def scenario_straggler_degrade(smoke: bool = False) -> ChaosScenario:
    """Farm uplink straggles ×24: alive but slow — invisible to
    ``down_now``/``partitioned_now``; only the realized per-transfer
    uplink seconds (``link_secs_window``) betray it, after the
    straggler monitor accumulates two epochs of evidence. The flip to
    local therefore lags the onset — the honest price of observing
    through telemetry alone."""
    # the ×2 detection lag needs ~3 clean DC epochs before onset and a
    # few flipped epochs after to amortize, so smoke only shortens the
    # tail, not the onset
    horizon = 2700.0 if smoke else 3600.0
    strag = (930.0, horizon)
    ch = ChaosSpec(straggles=(LinkStraggle(site="gw-a", at_s=strag[0],
                                           until_s=strag[1], factor=24.0),),
                   migration="cold", ledger_mode="exactly_once")
    b = (_agg_service(_fabric("straggler_degrade", a_rps=825.0,
                              b_rps=1000.0, uplink_a_bps=50e3),
                      soft_energy_j=3.0, hard_energy_j=60.0)
         .horizon(horizon).epochs(300.0).dc(dc_step_floor_s=2e-3)
         .farm(n_things=8, seed=23, site="gw-a",
               rate=RateSpec.constant(8.0))
         .chaos(ch))
    return ChaosScenario("straggler_degrade", b.build(),
                         prior_rates={"agg": 64.0},
                         static_plans=_statics())


SCENARIOS = (scenario_crash_during_burst, scenario_partition_heal,
             scenario_straggler_degrade)


# ---------------------------------------------------------------------------
def _chaos_ctrl(sc: ChaosScenario, seed: int = 0) -> ChaosController:
    return ChaosController(chips_options=sc.chips_options, window=1,
                           switch_margin=0.02, seed=seed,
                           prior_rates=sc.prior_rates)


def _replans(summary: Dict) -> List[Dict]:
    return [e for ep in summary["epochs"] for e in ep.get("chaos", ())]


def run_scenario(sc: ChaosScenario, seed: int = 0) -> Dict:
    t0 = time.perf_counter()
    cs = sc.spec.compile()
    true_rates = cs.true_epoch_rates()
    avg_rates = {s: sum(r[s] for r in true_rates) / len(true_rates)
                 for s in cs.order}

    # Static arms ride through the same chaos schedule: the physics
    # (deferred fires, stalled transfers, slowed links) applies to
    # every controller; only the chaos arm may re-plan around it.
    statics: Dict[str, Dict] = {}
    candidates = dict(sc.static_plans)
    candidates.setdefault("searched-avg", plan_on_average_rates(
        cs.info(), avg_rates, chips_options=sc.chips_options, seed=seed))
    best_static = None
    for label, plan in candidates.items():
        r = cs.run(StaticController(plan, label=f"static:{label}"))
        statics[label] = r.summary()
        if best_static is None or r.vos > best_static[1].vos:
            best_static = (label, r)
    assert best_static is not None

    r_chaos = cs.run(_chaos_ctrl(sc, seed))
    r_repeat = cs.run(_chaos_ctrl(sc, seed))    # determinism probe

    replans = _replans(r_chaos.summary())
    # reacted to the fault: an emergency mid-epoch re-plan, or (for
    # faults only telemetry betrays, like stragglers) a boundary flip
    # to a different plan once the evidence accumulated
    adapted = bool(replans) or len(
        {e["plan"] for e in r_chaos.summary()["epochs"]}) > 1
    conserved = r_chaos.ledger.conserved()
    totals = r_chaos.ledger.totals()
    exactly_once = sc.spec.chaos.ledger_mode == "exactly_once"
    ledger_clean = (("duplicates" not in totals) if exactly_once
                    else totals.get("duplicates", 0) >= 0)
    deterministic = (r_chaos.vos == r_repeat.vos
                     and totals == r_repeat.ledger.totals()
                     and r_chaos.summary()["epochs"]
                     == r_repeat.summary()["epochs"])
    beats_all = all(r_chaos.vos > s["vos"] for s in statics.values())

    out = {
        "spec": sc.spec.to_dict(),
        "statics": statics,
        "best_static": {"label": best_static[0],
                        "vos": round(best_static[1].vos, 4)},
        "chaos": r_chaos.summary(),
        "replans": replans,
        "migrations": [m for e in replans for m in e["migrations"]],
        "avg_rates": {k: round(v, 3) for k, v in avg_rates.items()},
        "acceptance": {
            "chaos_beats_every_static": bool(beats_all),
            "adapted_to_fault": adapted,
            "replanned_mid_epoch": bool(replans),
            "ledger_conserved": bool(conserved),
            "ledger_mode_clean": bool(ledger_clean),
            "deterministic": bool(deterministic),
        },
        "wall_s": round(time.perf_counter() - t0, 2),
    }

    if sc.ledger_arm:
        # the same fault schedule under at-least-once cutover: replayed
        # records are double-processed and accounted exactly
        spec_alo = dataclasses.replace(
            sc.spec, chaos=dataclasses.replace(sc.spec.chaos,
                                               ledger_mode="at_least_once"))
        r_alo = spec_alo.compile().run(_chaos_ctrl(sc, seed))
        alo_replans = _replans(r_alo.summary())
        declared = sum(m["replay_records"] for e in alo_replans
                       for m in e["migrations"] if m["duplicates"])
        alo_totals = r_alo.ledger.totals()
        out["at_least_once"] = {
            "vos": round(r_alo.vos, 4),
            "declared_replays": declared,
            "ledger_duplicates": alo_totals.get("duplicates", 0),
            "conserved": bool(r_alo.ledger.conserved()),
        }
        out["acceptance"]["duplicates_accounted"] = bool(
            declared > 0
            and alo_totals.get("duplicates", 0) == declared
            and r_alo.ledger.conserved())
    return out


def _baseline_reproduces(smoke: bool) -> Dict:
    """Chaos must be opt-in: a chaos-free recorded benchmark scenario
    re-runs bit-identically against its committed report."""
    from benchmarks import bench_online
    path = "BENCH_online_smoke.json" if smoke else "BENCH_online.json"
    rec_path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), path)
    if not os.path.exists(rec_path):
        return {"checked": False, "reason": f"{path} not recorded"}
    with open(rec_path) as f:
        recorded = json.load(f)["scenarios"]["diurnal_tide"]["statics"]
    sc = bench_online.scenario_diurnal_tide(smoke=smoke)
    r = sc.spec.compile().run(
        StaticController(sc.static_plans["all-dc"], label="static:all-dc"))
    s = r.summary()
    ok = (s["vos"] == recorded["all-dc"]["vos"]
          and s["records"] == recorded["all-dc"]["records"]
          and not any(ep.get("chaos") for ep in s["epochs"]))
    return {"checked": True, "scenario": "diurnal_tide", "arm": "all-dc",
            "recorded_vos": recorded["all-dc"]["vos"],
            "replayed_vos": s["vos"], "identical": bool(ok)}


def main(csv_rows, smoke: bool = False) -> None:
    print("\n== Chaos & migration: static plans vs chaos-aware controller ==")
    report: Dict = {"smoke": smoke, "scenarios": {}}
    makers = SCENARIOS[:1] if smoke else SCENARIOS
    wins = 0
    n_replans = 0
    hard_ok = True
    dup_ok = True
    for make in makers:
        sc = make(smoke=smoke)
        res = run_scenario(sc)
        report["scenarios"][sc.name] = res
        acc = res["acceptance"]
        wins += acc["chaos_beats_every_static"]
        n_replans += len(res["replans"])
        hard_ok &= (acc["ledger_conserved"] and acc["ledger_mode_clean"]
                    and acc["deterministic"] and acc["adapted_to_fault"])
        if "duplicates_accounted" in acc:
            dup_ok &= acc["duplicates_accounted"]
        migs = res["migrations"]
        kinds = ",".join(sorted({m["kind"] for m in migs})) or "-"
        print(f"{sc.name:18s} best-static={res['best_static']['vos']:>9.2f} "
              f"({res['best_static']['label']}) "
              f"chaos={res['chaos']['vos']:>9.2f} "
              f"replans={len(res['replans'])} migs={kinds} "
              f"[beats-all={acc['chaos_beats_every_static']} "
              f"ledger={acc['ledger_conserved'] and acc['ledger_mode_clean']} "
              f"det={acc['deterministic']}]")
        csv_rows.append((f"chaos_{sc.name}_vos",
                         res["chaos"]["vos"] * 1e3,
                         res["chaos"]["epochs"][-1]["plan"]))
    baseline = _baseline_reproduces(smoke)
    report["baseline_reproduces"] = baseline
    base_ok = (not baseline["checked"]) or baseline["identical"]
    n = len(report["scenarios"])
    ok = (wins == n and hard_ok and dup_ok and base_ok
          and n_replans >= 1)
    report["acceptance"] = {"beats_every_static": wins, "of": n,
                            "mid_epoch_replans": n_replans,
                            "duplicates_accounted": bool(dup_ok),
                            "baseline_identical": bool(base_ok),
                            "pass": bool(ok)}
    out = _out_path(smoke)
    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"chaos beats every static {wins}/{n}, duplicates accounted: "
          f"{dup_ok}, chaos-free baseline identical: {base_ok} "
          f"-> {'PASS' if ok else 'FAIL'}; wrote {out}")
    # chaos gate (scripts/ci.sh): survival must not come at the cost of
    # accounting — the chaos arm wins, ledgers stay exact, and a
    # chaos-free run of a recorded scenario is untouched bit-for-bit
    assert ok, "chaos gate failed (see report acceptance block)"


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main([], smoke="--smoke" in sys.argv)
