"""Edge↔DC placement engine: plan validation, co-sim record conservation
and determinism, search optimality vs the baseline plans, and the
PodGrid.compose validation regression (power of two >= 4)."""
import pytest

from repro.core.vdc import PodGrid
from repro.pipeline import (Broker, NeubotFarm, Pipeline, ServiceConfig,
                            StreamService, WindowSpec)
from repro.pipeline.store import TimeSeriesStore
from repro.placement import (CoSimConfig, CoSimulator, EdgeSpec, LinkSpec,
                             NetworkModel, PlacementPlan, ServicePlacement,
                             ServiceProfile, ServiceSLO, search_placement)


# --------------------------------------------------------------- fixtures
def _build_pipeline(tight_buffers=False, with_store=False):
    """Two-stage DAG: raw -> agg -> smooth, plus a parallel raw -> pctl."""
    b = Broker()
    pipe = Pipeline(b)
    pipe.add_farm(NeubotFarm(b, n_things=4, rate_hz=2.0, seed=3))
    budget = 64 if tight_buffers else 4096
    store = TimeSeriesStore("spill", chunk_seconds=60.0) if with_store \
        else None
    agg = StreamService(ServiceConfig(
        name="agg", queue="neubotspeed", column="download_speed", agg="max",
        window=WindowSpec("sliding", 120.0, 30.0), buffer_budget=budget,
        store=store), b)
    pctl = StreamService(ServiceConfig(
        name="pctl", queue="neubotspeed", column="latency_ms", agg="mean",
        window=WindowSpec("sliding", 60.0, 30.0), buffer_budget=budget), b)
    smooth = StreamService(ServiceConfig(
        name="smooth", queue="agg_out", column="value", agg="mean",
        window=WindowSpec("sliding", 120.0, 60.0)), b)
    pipe.add_service(agg).add_service(pctl).add_service(smooth)
    pipe.connect(agg, "agg_out")
    return pipe


def _cosim(horizon=300.0, tight_buffers=False, with_store=False, **slo_kw):
    slo = ServiceSLO(soft_latency_s=slo_kw.pop("soft", 2.0),
                     hard_latency_s=slo_kw.pop("hard", 10.0),
                     soft_energy_j=2.0, hard_energy_j=100.0)
    profiles = {n: ServiceProfile(slo, flops_per_record=2e3)
                for n in ("agg", "pctl", "smooth")}
    cfg = CoSimConfig(horizon_s=horizon)
    return CoSimulator(
        lambda: _build_pipeline(tight_buffers, with_store), profiles, cfg)


NAMES = ["agg", "pctl", "smooth"]


# ---------------------------------------------------------------- topology
def test_pipeline_records_topology():
    topo = _build_pipeline().topology()
    assert topo == {"agg": [], "pctl": [], "smooth": ["agg"]}


# -------------------------------------------------------------------- plan
def test_plan_validation():
    topo = {"a": [], "b": ["a"]}
    PlacementPlan.all_edge(["a", "b"]).validate(topo)
    PlacementPlan.all_dc(["a", "b"], chips=8).validate(topo)
    with pytest.raises(ValueError):        # missing service
        PlacementPlan.all_edge(["a"]).validate(topo)
    with pytest.raises(ValueError):        # chips not a power of two >= 4
        PlacementPlan({"a": ServicePlacement("dc", chips=2),
                       "b": ServicePlacement("edge")}).validate(topo)
    with pytest.raises(ValueError):        # unknown site
        PlacementPlan({"a": ServicePlacement("cloud"),
                       "b": ServicePlacement("edge")}).validate(topo)
    with pytest.raises(ValueError):        # dvfs out of range
        PlacementPlan({"a": ServicePlacement("dc", chips=8, dvfs_f=1.5),
                       "b": ServicePlacement("edge")}).validate(topo)


def test_plan_cuts():
    topo = {"a": [], "b": ["a"], "c": ["b"]}
    plan = PlacementPlan({"a": ServicePlacement("edge"),
                          "b": ServicePlacement("dc"),
                          "c": ServicePlacement("edge")})
    assert sorted(plan.cuts(topo)) == [("a", "b"), ("b", "c")]


# ----------------------------------------------------------- edge/network
def test_network_accounting():
    net = NetworkModel(LinkSpec(uplink_bps=10e6, rtt_s=0.1,
                                record_bytes=100.0, compression=0.5))
    t = net.uplink(1000)
    assert t == pytest.approx(0.05 + 1000 * 100 * 0.5 / 10e6)
    assert net.bytes_up == 50_000
    assert net.energy_j > 0


# ----------------------------------------------------- conservation property
@pytest.mark.parametrize("plan_fn", [
    lambda: PlacementPlan.all_edge(NAMES),
    lambda: PlacementPlan.all_dc(NAMES, chips=4),
    lambda: PlacementPlan({"agg": ServicePlacement("edge"),
                           "pctl": ServicePlacement("dc", chips=4),
                           "smooth": ServicePlacement("dc", chips=8)}),
])
def test_record_conservation(plan_fn):
    """Every produced record is accounted for as edge-processed,
    DC-processed, in-flight, or dropped — under eviction pressure (tiny
    buffers, one service spilling to a store) and mixed placements."""
    cs = _cosim(tight_buffers=True, with_store=True)
    res = cs.run(plan_fn())
    assert res.feasible
    assert res.ledger.conserved()
    for sl in res.ledger.services.values():
        # the four categories partition production exactly
        assert sl.produced == (sl.processed_edge + sl.processed_dc
                               + sl.in_flight + sl.dropped)
    # eviction pressure actually happened (the test is not vacuous)
    tot = res.ledger.totals()
    assert tot["evicted_stored"] + tot["evicted_lost"] > 0


def test_conservation_with_dc_drops():
    """An SLO no DC task can meet forces scheduler drops; the dropped
    records must show up in the ledger, not vanish."""
    slo = ServiceSLO(soft_latency_s=1e-5, hard_latency_s=2e-5,
                     soft_energy_j=2.0, hard_energy_j=100.0)
    profiles = {n: ServiceProfile(slo, flops_per_record=2e3)
                for n in ("agg", "pctl", "smooth")}
    cs = CoSimulator(lambda: _build_pipeline(), profiles,
                     CoSimConfig(horizon_s=300.0))
    res = cs.run(PlacementPlan.all_dc(NAMES, chips=4))
    assert res.feasible
    assert res.fires_dropped > 0
    assert res.ledger.conserved()
    assert res.ledger.totals()["dropped_dc"] > 0


# ---------------------------------------------------------------- determinism
def test_cosim_determinism():
    """Same seed + same plan -> bit-identical VoS and accounting."""
    plan = PlacementPlan({"agg": ServicePlacement("edge"),
                          "pctl": ServicePlacement("dc", chips=4),
                          "smooth": ServicePlacement("edge")})
    r1 = _cosim().run(plan)
    r2 = _cosim().run(plan)
    assert r1.vos == r2.vos
    assert r1.latency_p95 == r2.latency_p95
    assert r1.energy_total_j == r2.energy_total_j
    assert r1.ledger.totals() == r2.ledger.totals()


# --------------------------------------------------------------------- search
def test_evaluator_counts_hits_misses_and_screened():
    """`evaluations` used to silently conflate cached and fresh runs;
    the counters split them, and screened plans are tracked separately
    from exact co-simulations."""
    from repro.placement import Evaluator

    cs = _cosim()
    ev = Evaluator(cs)
    p1 = PlacementPlan.all_edge(NAMES)
    p2 = PlacementPlan.all_dc(NAMES, chips=4)
    ev(p1)
    ev(p1)          # cached
    ev(p2)
    assert (ev.hits, ev.misses, ev.evaluations) == (1, 2, 2)
    assert ev.stats() == {"evaluations": 2, "cache_hits": 1,
                          "cache_misses": 2, "screened": 0}
    # the deprecated shim exposes no screening model -> no screen tier
    assert ev.screener is None
    with pytest.raises(ValueError, match="screening"):
        ev.screen_batch([p1])


def test_search_forecast_scorer_uses_legacy_path():
    """Scorers without a screening model (the online ForecastModel
    shape) must keep working through the exact-only search and report
    the hit/miss split."""
    sr = search_placement(_cosim(), chips_options=(4, 8))
    assert sr.screen is None
    assert sr.method in ("exhaustive", "greedy+hillclimb")
    assert sr.cache_misses == sr.evaluations > 0


def test_search_no_worse_than_baselines():
    cs = _cosim()
    sr = search_placement(cs, chips_options=(4, 8))
    all_edge = cs.run(PlacementPlan.all_edge(NAMES))
    all_dc = cs.run(PlacementPlan.all_dc(NAMES, chips=4))
    assert sr.result.feasible
    assert sr.result.vos >= all_edge.vos
    assert sr.result.vos >= all_dc.vos
    assert sr.evaluations > 2


def test_infeasible_edge_ram():
    cs = _cosim()
    cs.cfg.edge = EdgeSpec(ram_bytes=1024.0)   # nothing fits
    res = cs.run(PlacementPlan.all_edge(NAMES))
    assert not res.feasible and "RAM" in res.infeasible_reason
    # but a fully offloaded plan is still fine
    assert cs.run(PlacementPlan.all_dc(NAMES, chips=4)).feasible


# ------------------------------------------------------------- cut semantics
def test_dc_to_dc_handoff_ships_nothing():
    """In a DC→DC chain only the edge→DC cut pays uplink bytes: the
    downstream service consumes results that never left the DC."""
    def build():
        b = Broker()
        pipe = Pipeline(b)
        pipe.add_farm(NeubotFarm(b, n_things=4, rate_hz=2.0, seed=3))
        agg = StreamService(ServiceConfig(
            name="agg", queue="neubotspeed", column="download_speed",
            agg="max", window=WindowSpec("sliding", 120.0, 30.0)), b)
        smooth = StreamService(ServiceConfig(
            name="smooth", queue="agg_out", column="value", agg="mean",
            window=WindowSpec("sliding", 120.0, 60.0)), b)
        pipe.add_service(agg).add_service(smooth)
        pipe.connect(agg, "agg_out")
        return pipe

    slo = ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                     soft_energy_j=2.0, hard_energy_j=100.0)
    profiles = {n: ServiceProfile(slo, flops_per_record=2e3)
                for n in ("agg", "smooth")}
    cs = CoSimulator(build, profiles, CoSimConfig(horizon_s=300.0))
    res = cs.run(PlacementPlan.all_dc(["agg", "smooth"], chips=4))
    assert res.feasible and res.fires_completed == res.fires_total
    sl = res.ledger.services["agg"]
    spec = cs.cfg.link
    # uplink carries exactly agg's source records, none of smooth's input
    expected = sl.covered * spec.record_bytes * spec.compression
    assert res.bytes_up == pytest.approx(expected)
    # every completed DC fire surfaces its result edge-side exactly once
    assert res.bytes_down == pytest.approx(
        res.fires_completed * spec.result_bytes)


# --------------------------------------------------- evaluator memoization
def test_evaluator_memoizes_on_canonical_key():
    """Identical plans under permuted service order (and permuted dict
    insertion) are ONE cache entry — the search must never re-co-sim a
    plan it has already scored."""
    import itertools

    from repro.placement import Evaluator

    cs = _cosim(horizon=120.0)
    ev = Evaluator(cs)
    assignments = {"agg": ServicePlacement("edge"),
                   "pctl": ServicePlacement("dc", chips=4),
                   "smooth": ServicePlacement("dc", chips=8, dvfs_f=0.7)}
    ref = ev(PlacementPlan(dict(assignments)))
    for perm in itertools.permutations(assignments):
        plan = PlacementPlan({n: assignments[n] for n in perm})
        assert plan.key() == PlacementPlan(assignments).key()
        res = ev(plan)
        assert res is ref                 # cache hit, same object
    assert ev.evaluations == 1
    assert len(ev.history) == 1
    # a genuinely different plan is a new entry
    ev(PlacementPlan(dict(assignments, agg=ServicePlacement("dc", chips=4))))
    assert ev.evaluations == 2


def test_evaluator_key_distinguishes_hints():
    """chips / DVFS hints are part of the identity (same sites, different
    VDC sizing must re-evaluate)."""
    a = PlacementPlan({"x": ServicePlacement("dc", chips=4)})
    b = PlacementPlan({"x": ServicePlacement("dc", chips=8)})
    c = PlacementPlan({"x": ServicePlacement("dc", chips=4, dvfs_f=0.7)})
    assert len({a.key(), b.key(), c.key()}) == 3


# ---------------------------------------------------------- multi-site plans
def test_multi_site_plans():
    from repro.placement.plan import service_options, enumerate_plans

    topo = {"a": [], "b": ["a"]}
    plan = PlacementPlan({"a": ServicePlacement("gw-1"),
                          "b": ServicePlacement("dc", chips=4)})
    # default site universe rejects fleet names; the widened one accepts
    with pytest.raises(ValueError):
        plan.validate(topo)
    plan.validate(topo, sites=("gw-1", "gw-2", "dc"))
    assert plan.is_edge("a") and not plan.is_edge("b")
    assert plan.placement("a").label == "gw-1"
    assert sorted(plan.cuts(topo)) == [("a", "b")]

    opts = service_options(chips_options=(4,), dvfs_options=(1.0,),
                           edge_sites=("gw-1", "gw-2"))
    assert [o.site for o in opts] == ["gw-1", "gw-2", "dc"]
    plans = list(enumerate_plans(["a", "b"], chips_options=(4,),
                                 edge_sites=("gw-1", "gw-2")))
    assert len(plans) == 9                # (2 sites + 1 dc option)^2
    assert PlacementPlan.all_edge(["a"], site="gw-2").site("a") == "gw-2"


def test_value_spec_shift_keeps_absolute_decay():
    """A shift beyond the soft deadline must leave the task *inside* the
    decay ramp (regression: clamping soft to ~0 re-spread the decay and
    over-credited slow offloads)."""
    from repro.placement import ServiceSLO
    from repro.core.value import task_value

    slo = ServiceSLO(soft_latency_s=2.0, hard_latency_s=10.0,
                     soft_energy_j=1.0, hard_energy_j=60.0)
    spec = slo.value_spec(shift_s=5.0)    # 5 s already burned pre-DC
    assert spec.perf_curve.th_soft == pytest.approx(-3.0)
    assert spec.perf_curve.th_hard == pytest.approx(5.0)
    # instant DC execution still only earns the 7s-total-latency value
    v_shifted = spec.perf_curve.value(0.0)
    v_absolute = slo.value_spec().perf_curve.value(5.0)
    assert v_shifted == pytest.approx(v_absolute)
    # and past the shifted hard threshold nothing is earned
    assert task_value(spec, 5.1, 0.5) == 0.0


# ------------------------------------------------- PodGrid.compose regression
def test_compose_rejects_non_power_of_two_and_small():
    """Docstring promises power-of-two >= 4; validation must agree."""
    grid = PodGrid()
    for bad in (0, 1, 2, 3, 5, 6, 24, 257):
        with pytest.raises(ValueError):
            grid.compose(bad, 1.0, 0)
    vdc = grid.compose(4, 1.0, 0)
    assert vdc is not None and vdc.chips == 4
    grid.release(vdc)
    assert grid.free_chips == grid.total_chips
