"""Flash-attention kernel: shape/dtype sweep vs the pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention_reference, flash_attention

SWEEP = [
    # (B, Sq, Skv, H, KV, d, causal, dtype, tol)
    (2, 256, 256, 4, 2, 64, True, jnp.float32, 2e-5),
    (1, 200, 200, 4, 4, 64, True, jnp.float32, 2e-5),       # ragged pad
    (2, 128, 384, 8, 2, 128, False, jnp.float32, 2e-5),     # cross-ish
    (1, 256, 256, 2, 1, 32, True, jnp.float32, 2e-5),       # MQA
    (1, 384, 384, 3, 3, 64, True, jnp.float32, 2e-5),       # odd heads
    (2, 256, 256, 4, 2, 64, True, jnp.bfloat16, 2e-2),
    (1, 128, 256, 8, 8, 128, True, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("B,Sq,Skv,H,KV,d,causal,dtype,tol", SWEEP)
def test_flash_vs_ref(B, Sq, Skv, H, KV, d, causal, dtype, tol):
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, d)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, KV, d)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, KV, d)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    ref = attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_flash_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 4, 64))
    v = jax.random.normal(ks[2], (1, 256, 4, 64))
    o1 = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    o2 = flash_attention(q, k, v, block_q=64, block_k=256, interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)
