"""Property-based tests for the tier-1 vectorized plan screen
(repro.scenario.screen.ScreeningModel): score_batch purity, permutation
invariance over plan batches, and monotonicity — inflating a service's
record rate (its per-fire trace counts) or a link's latency never
*increases* a DC-offloaded plan's screened score."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.placement import PlacementPlan, ServicePlacement
from repro.placement.edge import EdgeSpec
from repro.placement.network import LinkSpec
from repro.scenario import RateSpec, ScenarioSpec, ScreeningModel, scenario

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=0.5, hard_energy_j=10.0)


def _spec(rtt_mult: float = 1.0, uplink_div: float = 1.0) -> ScenarioSpec:
    """Two heterogeneous gateways + chained services on a short horizon
    (the drive is link-independent, so link knobs rescale latency only)."""
    return (scenario("screen-prop")
            .horizon(240.0)
            .site("gw-a", edge=EdgeSpec(name="gw-a"),
                  link=LinkSpec(uplink_bps=1e5 / uplink_div,
                                rtt_s=0.05 * rtt_mult, record_bytes=256.0))
            .site("gw-b", edge=EdgeSpec(name="gw-b", flops_per_s=15e9),
                  link=LinkSpec(uplink_bps=8e4 / uplink_div,
                                rtt_s=0.08 * rtt_mult, record_bytes=256.0))
            .farm(n_things=4, seed=5, rate=RateSpec.constant(4.0),
                  site="gw-a")
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=60, slide_s=30)
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .service("smooth", queue="agg_out", column="value", agg="mean",
                     width_s=120, slide_s=60)
            .fed_by("agg")
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .build())


@pytest.fixture(scope="module")
def engine():
    return _spec().compile()


def _plans(names):
    """A diverse fixed plan batch over both gateways and the DC."""
    return [
        PlacementPlan.all_edge(names, site="gw-a"),
        PlacementPlan.all_edge(names, site="gw-b"),
        PlacementPlan.all_dc(names, chips=4),
        PlacementPlan.all_dc(names, chips=8),
        PlacementPlan({"agg": ServicePlacement("gw-a"),
                       "smooth": ServicePlacement("dc", chips=4)}),
        PlacementPlan({"agg": ServicePlacement("dc", chips=4),
                       "smooth": ServicePlacement("gw-b")}),
    ]


# ------------------------------------------------------------------ purity
def test_score_batch_is_pure(engine):
    """Scoring is stateless: repeated batch scoring is bit-identical,
    and batch scores equal one-by-one scores."""
    plans = _plans(list(engine.order))
    s1 = engine.screening_model().score_batch(plans)
    s2 = engine.screening_model().score_batch(plans)
    assert (s1 == s2).all()
    singles = np.array([float(engine.screening_model().score_batch([p])[0])
                        for p in plans])
    assert s1 == pytest.approx(singles)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_score_batch_permutation_invariance(engine, seed):
    """A plan's screened score does not depend on its batch position or
    companions: scores commute with any permutation of the batch."""
    plans = _plans(list(engine.order))
    base = engine.screening_model().score_batch(plans)
    perm = np.random.default_rng(seed).permutation(len(plans))
    shuffled = engine.screening_model().score_batch(
        [plans[i] for i in perm])
    assert shuffled == pytest.approx(base[perm])


# ------------------------------------------------------------ monotonicity
def _inflate_rate(engine, svc: str, factor: float) -> ScreeningModel:
    """A fresh screener whose trace pretends ``svc``'s record rate was
    ``factor``x: every per-fire window size and per-origin newly-covered
    count scales up (what a hotter farm produces for the same fires)."""
    m = ScreeningModel(engine)
    sv = m._svc[svc]
    sv["nw"] = sv["nw"] * factor
    sv["origins"] = {k: v * factor for k, v in sv["origins"].items()}
    return m


@settings(max_examples=25, deadline=None)
@given(factor=st.floats(1.0, 8.0),
       svc_idx=st.integers(0, 1),
       chips=st.sampled_from([4, 8]))
def test_rate_inflation_never_raises_dc_score(engine, factor, svc_idx, chips):
    """More records can only mean longer DC steps, more uplink bytes and
    more energy: a DC-offloaded plan's screened score is monotone
    non-increasing in any service's record rate."""
    names = list(engine.order)
    svc = names[svc_idx]
    plan = PlacementPlan.all_dc(names, chips=chips)
    base = float(ScreeningModel(engine).score_batch([plan])[0])
    inflated = float(_inflate_rate(engine, svc, factor)
                     .score_batch([plan])[0])
    assert inflated <= base + 1e-9


@settings(max_examples=12, deadline=None)
@given(rtt_mult=st.floats(1.0, 20.0), uplink_div=st.floats(1.0, 10.0),
       chips=st.sampled_from([4, 8]))
def test_link_inflation_never_raises_dc_score(rtt_mult, uplink_div, chips):
    """Slower last-mile links (higher RTT, thinner uplink) can only
    delay a DC offload's records and results: the DC plan's screened
    score is monotone non-increasing in link latency. (The functional
    drive is link-independent, so both engines replay one trace.)"""
    base_e = _spec().compile()
    slow_e = _spec(rtt_mult=rtt_mult, uplink_div=uplink_div).compile()
    names = list(base_e.order)
    plan = PlacementPlan.all_dc(names, chips=chips)
    base = float(base_e.screening_model().score_batch([plan])[0])
    slow = float(slow_e.screening_model().score_batch([plan])[0])
    assert slow <= base + 1e-9


def test_corrections_do_not_break_purity(engine):
    """Calibration corrections are part of the screener state, not the
    call: with corrections installed, scoring stays pure and clearing
    them restores the raw scores exactly."""
    from repro.scenario import ServiceCalibration, ServiceCorrection
    plans = _plans(list(engine.order))
    m = ScreeningModel(engine)
    raw = m.score_batch(plans)
    corr = {s: ServiceCalibration(
        dc=ServiceCorrection(q_mult=1.5, lat_bias_s=1.0, drop_offset=0.3))
        for s in engine.order}
    m.set_corrections(corr)
    c1 = m.score_batch(plans)
    c2 = m.score_batch(plans)
    assert (c1 == c2).all()
    m.set_corrections(None)
    assert (m.score_batch(plans) == raw).all()
