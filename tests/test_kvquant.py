"""int8 KV cache: quantization error bounds and end-to-end decode accuracy
vs the bf16 cache path."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, don't crash collection
from hypothesis import given, settings, strategies as st

from repro.models.kvquant import (attend_quant, dequantize_kv,
                                  init_quant_kv_cache, quantize_kv,
                                  update_quant_cache)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.01, 100.0))
def test_quant_roundtrip_error_bound(seed, scale_mag):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, 64)) * scale_mag
    q, s = quantize_kv(x)
    err = jnp.max(jnp.abs(dequantize_kv(q, s, jnp.float32) - x))
    bound = jnp.max(jnp.abs(x)) / 127.0  # half-ULP of absmax scaling × 2
    assert float(err) <= float(bound) + 1e-6


def test_quant_decode_matches_fp_attention():
    """Quantized decode attention ≈ exact attention (softmax smooths the
    ~0.4% per-element quantization noise)."""
    B, S, H, KV, dh = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, dh), jnp.float32)
    k_hist = jax.random.normal(ks[1], (B, S, KV, dh), jnp.float32)
    v_hist = jax.random.normal(ks[2], (B, S, KV, dh), jnp.float32)

    cache = init_quant_kv_cache(B, S, KV, dh)
    for t in range(S):
        cache = update_quant_cache(cache, k_hist[:, t:t + 1],
                                   v_hist[:, t:t + 1], t)
    out_q = attend_quant(q, cache, pos=S - 1, dtype=jnp.float32)

    # exact reference
    rep = H // KV
    kr = jnp.repeat(k_hist, rep, axis=2)
    vr = jnp.repeat(v_hist, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / math.sqrt(dh)
    probs = jax.nn.softmax(scores, axis=-1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", probs, vr)

    rel = float(jnp.max(jnp.abs(out_q - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.03, rel


def test_cache_bytes_halved():
    B, S, KV, dh = 8, 1024, 8, 128
    qc = init_quant_kv_cache(B, S, KV, dh)
    q_bytes = sum(np.prod(v.shape) * v.dtype.itemsize for v in qc.values())
    bf16_bytes = 2 * B * S * KV * dh * 2
    assert q_bytes < 0.6 * bf16_bytes  # int8 + scales ≈ 0.53×
