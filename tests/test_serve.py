"""Live serving runtime: the DES engine's twin executing real records.

Pins the contract ISSUE PR 6 introduces: (a) seeded determinism — two
live runs of the same spec produce bit-identical ledgers, epochs and
telemetry; (b) backpressure — bounded inter-stage queues never exceed
their capacity under a bursty upstream; (c) engine-vs-runtime
equivalence — on the recorded ``BENCH_placement.json`` scenarios the
live VoS agrees with the simulated VoS within tolerance; (d) the
calibration loop ingests *measured* residuals through the unchanged
feedback path; and (e) the broker ``Queue`` capacity semantics the
runtime's accounting rides on (drop-oldest, ``set_capacity``,
``backlog``, explicit ``Broker.queue`` capacity)."""
import json
import os

import pytest

from repro.online import OnlineController
from repro.pipeline.streams import Broker, Queue, Record
from repro.placement.plan import PlacementPlan
from repro.scenario import RateSpec, ScenarioSpec, scenario
from repro.serve import ServeConfig, serve_scenario

_SLO_KW = dict(soft_latency_s=2.0, hard_latency_s=10.0,
               soft_energy_j=2.0, hard_energy_j=100.0)


def _mini_spec(horizon: float = 600.0, epoch_s: float = 150.0):
    return (scenario("mini")
            .horizon(horizon).epochs(epoch_s)
            .farm(n_things=4, seed=3, rate=RateSpec.constant(2.0))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=120, slide_s=30)
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .service("smooth", queue="agg_out", column="value", agg="mean",
                     width_s=120, slide_s=60)
            .fed_by("agg")
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .build())


def _burst_spec():
    """Fast upstream (slide 15) feeding a slow downstream (slide 120):
    eight records pile up between downstream fires when unbounded."""
    return (scenario("burst")
            .horizon(600.0)
            .farm(n_things=6, seed=5, rate=RateSpec.constant(4.0))
            .service("agg", queue="neubotspeed", column="download_speed",
                     agg="max", width_s=60, slide_s=15)
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .service("smooth", queue="agg_out", column="value", agg="mean",
                     width_s=240, slide_s=120)
            .fed_by("agg")
            .slo(**_SLO_KW).profile(flops_per_record=2e3)
            .build())


class _Flipper:
    """Alternates all-edge / all-DC each epoch to force migrations."""

    def bind(self, info):
        self.names = list(info.topology)

    def decide(self, obs):
        if obs.epoch % 2 == 0:
            return PlacementPlan.all_edge(self.names, "edge")
        return PlacementPlan.all_dc(self.names)


def _fire_tuples(telemetry):
    return {svc: [(f.state, f.site, f.n_window, f.n_new,
                   round(f.value, 9), round(f.lat_s, 9)
                   if f.lat_s == f.lat_s else None)
                  for f in grid]
            for svc, grid in telemetry.fires.items()}


# ----------------------------------------------------------- basic runs
def test_run_plan_edge_and_dc_conserved():
    spec = _mini_spec()
    names = spec.service_names()
    edge = serve_scenario(spec).run_plan(
        PlacementPlan.all_edge(names, "edge"), label="all-edge")
    assert edge.feasible and edge.ledger.conserved()
    assert edge.fires_completed > 0 and edge.vos > 0
    dc = serve_scenario(spec).run_plan(PlacementPlan.all_dc(names),
                                       label="all-dc")
    assert dc.feasible and dc.ledger.conserved()
    assert dc.dc_energy_j > 0 and dc.bytes_up > 0


# -------------------------------------------------- seeded determinism
def test_seeded_determinism_identical_ledgers_and_telemetry():
    """Two live runs of the same spec + controller must be replays:
    identical VoS, epoch records, conservation ledgers, per-fire
    telemetry and calibration history."""
    runs = []
    for _ in range(2):
        ctl = OnlineController(calibrate=True)
        rt = serve_scenario(_mini_spec())
        res = rt.run(ctl)
        runs.append((res, _fire_tuples(rt.last_telemetry),
                     ctl.calibration.history))
    (r1, t1, h1), (r2, t2, h2) = runs
    assert r1.vos == r2.vos
    assert r1.epochs == r2.epochs
    assert r1.ledger == r2.ledger
    assert r1.per_service == r2.per_service
    assert t1 == t2
    assert h1 == h2


# --------------------------------------------------------- backpressure
def test_backpressure_bounds_inter_stage_backlog():
    """With ``stage_capacity`` set, the downstream stage's input backlog
    observed at every dispatch never exceeds the bound, even under a
    burst that piles up 8 records when unbounded — and conservation
    still holds (parked publishers delay fires, they don't lose
    records)."""
    free = serve_scenario(_burst_spec())
    res_free = free.run_plan(PlacementPlan.all_edge(["agg", "smooth"],
                                                    "edge"))
    unbounded = max(f.backlog for f in free.last_telemetry.fires["smooth"])
    assert unbounded > 2        # the burst actually piles up

    cap = 2
    bounded = serve_scenario(_burst_spec(),
                             serve=ServeConfig(stage_capacity=cap))
    res_cap = bounded.run_plan(PlacementPlan.all_edge(["agg", "smooth"],
                                                      "edge"))
    assert max(f.backlog
               for f in bounded.last_telemetry.fires["smooth"]) <= cap
    assert res_free.ledger.conserved() and res_cap.ledger.conserved()


# ------------------------------------------- engine-vs-runtime agreement
def _bench_path() -> str:
    return os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_placement.json")


@pytest.mark.skipif(not os.path.exists(_bench_path()),
                    reason="no recorded BENCH_placement.json")
def test_runtime_matches_engine_on_recorded_scenario():
    """One recorded placement scenario, same searched plan through both
    executors: the live runtime's VoS must agree with the DES within
    tolerance (the two share every physical model; the residual gap is
    late-data/serial-stage divergence, which this scenario's load does
    not excite)."""
    with open(_bench_path()) as f:
        rep = json.load(f)
    sc = rep["scenarios"]["light_windows"]
    spec = ScenarioSpec.from_dict(sc["spec"])
    plan = PlacementPlan.from_dict(sc["search"]["assignments"])
    sim = spec.compile().run_plan(plan)
    real = serve_scenario(spec).run_plan(plan)
    assert real.ledger.conserved()
    assert real.vos == pytest.approx(sim.vos, abs=1e-3)
    assert real.fires_total == sim.fires_total


def test_runtime_matches_engine_under_live_replacement():
    """Same controller, both executors, with forced epoch-boundary
    migrations: VoS and the per-epoch migration records (service, src,
    dst, stall seconds) must agree."""
    sim = _mini_spec().compile().run(_Flipper())
    real = serve_scenario(_mini_spec()).run(_Flipper())
    assert real.ledger.conserved()
    assert real.migrations == sim.migrations > 0
    assert real.vos == pytest.approx(sim.vos, abs=1e-3)
    for m_real, m_sim in zip(real.epochs, sim.epochs):
        assert m_real["migrations"] == m_sim["migrations"]
        assert m_real["plan"] == m_sim["plan"]


# ------------------------------------------------- measured calibration
def test_calibration_loop_ingests_measured_residuals():
    """A calibrating controller run live accumulates one observation per
    completed epoch through the unchanged feedback path, and every
    observed residual carries the measured schema (completed counts,
    realized vos)."""
    ctl = OnlineController(calibrate=True)
    res = serve_scenario(_mini_spec()).run(ctl)
    assert res.ledger.conserved()
    n_epochs = len(res.epochs)
    assert ctl.calibration is not None
    # epochs are observed once realized — the final epoch's residuals
    # freeze after the last boundary, so at least all interior epochs land
    assert ctl.calibration.observations >= n_epochs - 1 >= 2
    for entry in ctl.calibration.history:
        assert entry["observed"], entry
        for svc, ob in entry["observed"].items():
            assert svc in ("agg", "smooth")
            assert ob["tier"] in ("edge", "dc")
            assert ob["completed"] >= 0 and ob["vos"] is not None


def test_epoch_meta_reports_measured_rates():
    res = serve_scenario(_mini_spec()).run(OnlineController())
    for meta in res.epochs:
        assert set(meta["rates_measured"]) == {"agg", "smooth"}
        # the source farm feeds agg directly; measured coverage is live
        assert meta["rates_measured"]["agg"] > 0


# -------------------------------------------------------- load shedding
def test_shed_after_migration_stall_accounts_drops():
    """With a tight shed bound, fires dispatched inside a migration
    stall are shed: counted dropped, no value, records roll into later
    windows — and the ledger still conserves."""
    rt = serve_scenario(_mini_spec(),
                        serve=ServeConfig(shed_after_s=1.0))
    res = rt.run(_Flipper())        # stalls ~2 s at each epoch boundary
    assert res.fires_dropped > 0
    assert res.ledger.conserved()
    shed = [f for grid in rt.last_telemetry.fires.values()
            for f in grid if f.shed]
    assert shed and all(f.value == 0.0 for f in shed)


# ------------------------------------- satellite: broker queue capacity
def _rec(ts: float) -> Record:
    return Record(ts=ts, values={"v": ts})


def test_queue_capacity_validation_and_drop_oldest():
    q = Queue("q", capacity=2)
    with pytest.raises(ValueError):
        Queue("bad", capacity=0)
    for i in range(4):
        q.publish(_rec(float(i)))
    assert len(q.buf) == 2 and q.dropped == 2
    # oldest two were dropped; a fresh consumer reads only the survivors
    assert [r.ts for r in q.fetch("c")] == [2.0, 3.0]
    assert q.base_seq == 2


def test_queue_set_capacity_shrink_drops_oldest():
    q = Queue("q", capacity=8)
    for i in range(6):
        q.publish(_rec(float(i)))
    q.fetch("seen")                 # consumer at offset 6
    q.set_capacity(2)
    assert len(q.buf) == 2 and q.dropped == 4 and q.base_seq == 4
    with pytest.raises(ValueError):
        q.set_capacity(0)
    # late consumer only sees the retained suffix
    assert [r.ts for r in q.fetch("late")] == [4.0, 5.0]


def test_queue_backlog_per_consumer():
    q = Queue("q", capacity=4)
    for i in range(3):
        q.publish(_rec(float(i)))
    assert q.backlog("c") == 3
    q.fetch("c")
    assert q.backlog("c") == 0
    for i in range(6):              # overflow drops oldest past capacity
        q.publish(_rec(float(3 + i)))
    assert q.backlog("c") == 4      # never reports more than retained


def test_broker_queue_explicit_capacity_applies():
    b = Broker()
    q = b.queue("x")                # default capacity
    for i in range(5):
        q.publish(_rec(float(i)))
    q2 = b.queue("x", capacity=3)   # explicit capacity now enforced
    assert q2 is q and q.capacity == 3
    assert len(q.buf) == 3 and q.dropped == 2
    assert b.queue("x") is q and q.capacity == 3   # None leaves it alone
