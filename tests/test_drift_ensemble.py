"""Seeded ensemble sampling over drift scenarios: DriftScenario.sample
(repro.online.drift) and sample_specs (repro.fluid.ensemble) must be
deterministic per seed, structurally jittered (not just amplitude-
scaled), and leave the base scenario untouched."""
import random

import pytest

from repro.online.drift import (DriftScenario, diurnal, perturb_curve,
                                perturb_outages, poisson_bursts,
                                step_bursts)


def _scenario() -> DriftScenario:
    return DriftScenario(
        name="drifty",
        curves={
            "q_diurnal": diurnal(4.0, amplitude=0.5, period_s=600.0),
            "q_bursts": step_bursts(2.0, 9.0, [(100.0, 200.0)]),
            "q_poisson": poisson_bursts(2.0, 8.0, 600.0, 120.0, 40.0,
                                        seed=3),
        },
        outages={"gw-a": ((120.0, 180.0), (400.0, 460.0))})


def _fingerprint(ds: DriftScenario, ts=(0.0, 50.0, 130.0, 333.3, 599.0)):
    rates = tuple((q, tuple(c(t) for t in ts))
                  for q, c in sorted(ds.curves.items()))
    return ds.name, rates, tuple(sorted(ds.outages.items()))


def test_sample_deterministic_per_seed():
    base = _scenario()
    a = base.sample(7, 5)
    b = base.sample(7, 5)
    assert [_fingerprint(x) for x in a] == [_fingerprint(x) for x in b]
    c = base.sample(8, 5)
    assert [_fingerprint(x) for x in a] != [_fingerprint(x) for x in c]


def test_sample_accepts_rng_instance():
    base = _scenario()
    a = base.sample(random.Random(11), 3)
    b = base.sample(random.Random(11), 3)
    assert [_fingerprint(x) for x in a] == [_fingerprint(x) for x in b]


def test_realizations_are_distinct_and_base_untouched():
    base = _scenario()
    before = _fingerprint(base)
    out = base.sample(0, 4)
    assert _fingerprint(base) == before
    prints = [_fingerprint(x) for x in out]
    assert len(set(prints)) == len(prints)
    assert all(x.name == f"drifty#{k}" for k, x in enumerate(out))


def test_diurnal_jitter_is_structural():
    """Phase/amplitude move, not just the base rate: the perturbed
    curve is not a constant multiple of the original."""
    rng = random.Random(5)
    c0 = diurnal(4.0, amplitude=0.5, period_s=600.0)
    c1 = perturb_curve(c0, rng)
    ts = [0.0, 100.0, 250.0, 420.0]
    ratios = [c1(t) / c0(t) for t in ts]
    assert max(ratios) - min(ratios) > 1e-6
    assert c1.drift_params["period_s"] == 600.0


def test_poisson_bursts_resample_arrival_times():
    """Perturbation re-seeds the arrival process: the burst *timing*
    pattern differs, not merely the rate heights."""
    rng = random.Random(9)
    c0 = poisson_bursts(2.0, 8.0, 600.0, 120.0, 40.0, seed=3)
    c1 = perturb_curve(c0, rng)
    assert c1.drift_params["seed"] != c0.drift_params["seed"]
    grid = [t * 2.5 for t in range(240)]
    hi0, hi1 = max(c0(t) for t in grid), max(c1(t) for t in grid)
    ind0 = [abs(c0(t) - hi0) < 1e-9 for t in grid]
    ind1 = [abs(c1(t) - hi1) < 1e-9 for t in grid]
    assert ind0 != ind1


def test_outage_jitter_preserves_durations():
    rng = random.Random(2)
    outages = {"gw-a": ((120.0, 180.0), (400.0, 460.0))}
    out = perturb_outages(outages, rng, onset_scale=0.2)
    assert set(out) == {"gw-a"}
    durs0 = sorted(round(u - d, 9) for d, u in outages["gw-a"])
    durs1 = sorted(round(u - d, 9) for d, u in out["gw-a"])
    assert durs0 == durs1
    assert all(d >= 0.0 for d, _ in out["gw-a"])
    assert list(out["gw-a"]) == sorted(out["gw-a"])


def test_sample_specs_deterministic_and_valid():
    """fluid.ensemble.sample_specs: realizations are full ScenarioSpecs
    (JSON round-trip clean) and bit-deterministic per seed."""
    from benchmarks.bench_placement import scenario_light_windows
    from repro.fluid import sample_specs
    spec = scenario_light_windows().spec
    a = sample_specs(spec, 4, seed=5)
    b = sample_specs(spec, 4, seed=5)
    assert [s.to_json() for s in a] == [s.to_json() for s in b]
    c = sample_specs(spec, 4, seed=6)
    assert [s.to_json() for s in a] != [s.to_json() for s in c]
    for s in a:
        assert type(spec).from_json(s.to_json()) == s
